"""Pipeline-parallel dry-run: chameleon-34b's 48-layer stack as a
16-stage GPipe pipeline on the production (data=16, model=16) mesh —
3 layers/stage, 64 microbatches (bubble fraction 15/79 ~= 19%).

Demonstrates the PP alternative to tensor parallelism compiling at
production scale (stage-to-stage ppermute traffic only).

  PYTHONPATH=src python examples/pipeline_dryrun.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402

from repro.configs import ARCHS                # noqa: E402
from repro.dist import shardings as sh         # noqa: E402
from repro.dist.pipeline import pipeline_lm_forward  # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402
from repro.models import lm                    # noqa: E402

cfg = ARCHS["chameleon-34b"]                   # 48 layers = 16 stages x 3
mesh = make_production_mesh()
B, S, M = 256, 4096, 64

params_shapes = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
p_sh = sh.params_shardings(mesh, params_shapes)
tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)

with sh.use_mesh(mesh):
    lowered = jax.jit(
        lambda p, t: pipeline_lm_forward(cfg, p, t, mesh, n_micro=M)
    ).lower(params_shapes, tokens)
    compiled = lowered.compile()

cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):
    cost = cost[0]
print(f"pipeline forward compiled OK on {mesh.devices.size} chips")
print(f"  flops/device (per HLO, scan counted once): "
      f"{cost.get('flops', 0):.3e}")
mem = compiled.memory_analysis()
if mem is not None:
    print(f"  args {getattr(mem, 'argument_size_in_bytes', 0)/2**30:.2f} "
          f"GiB/dev, temps {getattr(mem, 'temp_size_in_bytes', 0)/2**30:.2f}"
          " GiB/dev")
print(f"  bubble fraction: {(16-1)/(M+16-1):.1%} (M={M} microbatches)")
