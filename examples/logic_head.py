"""Convert an LM's classification head to fixed-function logic.

Where the full NullaNet-Tiny flow is infeasible at LM widths (2^(K·b)
blowup — DESIGN.md §4), it IS feasible for the narrow task heads that
ride on top of frozen backbones: this example pools hidden states from
the hymba smoke backbone, trains a fanin-constrained quantized MLP head
on a synthetic 4-class task, compiles the head to truth tables, verifies
bit-exactness, and prices it in LUTs — sub-microsecond on-chip routing
decisions (domain classification, early-exit gates, safety filters)
driven directly by LM states.

  PYTHONPATH=src python examples/logic_head.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.logic_infer import hardware_report
from repro.models import lm
from repro.models.mlp import MLPConfig, mlp_forward, to_logic
from repro.train.jsc_trainer import train_jsc

# 1) frozen backbone features: mean-pooled hidden states
cfg = get_arch("hymba-1.5b", smoke=True)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)


def featurize(tokens):
    hidden, _, _ = lm.forward(cfg, params, tokens=jnp.asarray(tokens))
    return np.asarray(jnp.mean(hidden, axis=1), np.float32)


print("1) extracting pooled LM features ...")
N_TRAIN, N_TEST, S = 3000, 800, 32
all_tokens = rng.integers(0, cfg.vocab_size, (N_TRAIN + N_TEST, S),
                          dtype=np.int32)
feats = np.concatenate([featurize(all_tokens[i:i + 250])
                        for i in range(0, len(all_tokens), 250)])
feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)
# synthetic 4-class task: random linear teacher over the features
teacher = np.random.default_rng(7).normal(size=(feats.shape[1], 4))
labels = (feats @ teacher).argmax(-1).astype(np.int32)

head_cfg = MLPConfig(
    name="lm-head", n_inputs=feats.shape[1],
    features=(24, 12, 4), fanins=(4, 4, 4),
    act_bits=(2, 2, 3), in_bits=2, n_classes=4, alpha=1.0)

print("2) QAT+FCP training of the head ...")
data = ((feats[:N_TRAIN], labels[:N_TRAIN]),
        (feats[N_TRAIN:], labels[N_TRAIN:]))
res = train_jsc(head_cfg, steps=500, data=data)
print(f"   head test acc: {res.test_acc:.4f} "
      f"(float ref {res.float_test_acc:.4f}, chance 0.25)")

print("3) compiling the head to combinational logic ...")
net = to_logic(head_cfg, res.params, res.masks, res.bn_state)
x = jnp.asarray(feats[N_TRAIN:N_TRAIN + 512])
scores, _ = mlp_forward(head_cfg, res.params, res.masks, res.bn_state, x)
assert (np.asarray(jnp.argmax(scores[:, :4], -1))
        == np.asarray(jnp.argmax(net(x)[:, :4], -1))).all()
print("   bit-exact: OK")

rep, _ = hardware_report(net)
print(f"4) hardware: {rep.luts} LUTs, {rep.ffs} FFs, "
      f"fmax {rep.fmax_mhz:.0f} MHz "
      f"-> {(head_cfg.n_layers + 1) * 1e3 / rep.fmax_mhz:.1f} ns latency")
