"""End-to-end serving driver (the paper's deployment scenario).

Trains the JSC-S classifier, compiles it to fixed-function logic, and
serves batched classification requests through the LogicEngine with
latency percentiles — the software twin of the sub-microsecond FPGA
pipeline, including the Pallas lut_layer execution path.

  PYTHONPATH=src python examples/serve_logic.py
"""
import dataclasses

import numpy as np

from repro.configs.jsc import JSC_DEMO
from repro.data.jsc import train_test
from repro.models.mlp import to_logic
from repro.serving.engine import LogicEngine
from repro.train.jsc_trainer import train_jsc

cfg = JSC_DEMO
data = train_test(8000, 2000, seed=0)

print("training + compiling ...")
res = train_jsc(cfg, steps=500, data=data)
net = to_logic(cfg, res.params, res.masks, res.bn_state)

for use_pallas in (False, True):
    eng = LogicEngine(net, cfg.n_classes, max_batch=256,
                      use_pallas=use_pallas)
    xte, yte = data[1]
    requests = [xte[i * 128: (i + 1) * 128] for i in range(12)]
    results, stats = eng.serve_queue(requests)
    acc = float(np.mean(np.concatenate(results) == yte[: 12 * 128]))
    tag = "pallas" if use_pallas else "jnp   "
    print(f"[{tag}] 12 requests x128: acc={acc:.4f} "
          f"p50={stats['p50_us']:.0f}us p95={stats['p95_us']:.0f}us")
