"""Continuous-batching LM serving on a smoke config: prefill + slot pool
+ per-tick decode, the same decode_step the multi-pod dry-run lowers at
(arch x decode_32k/long_500k).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import lm
from repro.serving.engine import LMEngine, LMRequest

cfg = get_arch("hymba-1.5b", smoke=True)  # hybrid attn+SSM, ring cache
params = lm.init_params(cfg, jax.random.PRNGKey(0))
eng = LMEngine(cfg, params, n_slots=4, max_seq=160)

rng = np.random.default_rng(0)
reqs = [LMRequest(prompt=rng.integers(0, cfg.vocab_size,
                                      rng.integers(8, 32),
                                      dtype=np.int32),
                  max_new_tokens=12) for _ in range(10)]
t0 = time.perf_counter()
done = eng.run(reqs)
dt = time.perf_counter() - t0
tokens = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens/dt:.1f} tok/s on 1 CPU core)")
for i, r in enumerate(done[:3]):
    print(f"  req{i}: prompt_len={len(r.prompt)} -> {r.out_tokens}")
