"""The paper's technique inside an LM: QAT + FCP as first-class config
knobs on a transformer, trained end-to-end with the fault-tolerant
Trainer (checkpoint + resume + straggler watchdog).

  PYTHONPATH=src python examples/train_lm_qat.py
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import lm_batch
from repro.train.loop import Trainer, init_state, make_train_step
from repro.train.optim import AdamW
from repro.train.schedules import warmup_cosine

STEPS = 150

# nemotron smoke: squared-ReLU MLP -> non-negative activations -> the
# paper's activation-selection rule picks the PACT branch for QAT.
cfg = dataclasses.replace(
    get_arch("nemotron-4-340b", smoke=True),
    quant_bits=4,       # PACT 4-bit activations inside the MLP
    quant_weights=4,    # DoReFa 4-bit weights
)
print(f"config: {cfg.name} quant_bits={cfg.quant_bits} "
      f"quant_weights={cfg.quant_weights} act={cfg.act}")

opt = AdamW(lr=warmup_cosine(1e-3, 15, STEPS), weight_decay=0.01)
step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
state = init_state(cfg, opt, jax.random.PRNGKey(0))


def batches():
    t = 0
    while True:
        toks, labels = lm_batch(cfg, 8, 128, 0, t)
        t += 1
        yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = Trainer(step, state, ckpt_dir=ckpt_dir, ckpt_every=50)
    final = trainer.run(batches(), STEPS, log_every=25)
    print(f"trained {STEPS} steps with 4-bit QAT; final loss "
          f"{final['loss']:.3f}")

    # float baseline for comparison
    cfg_f = dataclasses.replace(cfg, quant_bits=0, quant_weights=0)
    step_f = jax.jit(make_train_step(cfg_f, opt), donate_argnums=0)
    state_f = init_state(cfg_f, opt, jax.random.PRNGKey(0))
    tr = Trainer(step_f, state_f)
    final_f = tr.run(batches(), STEPS, log_every=1000,
                     log_fn=lambda *_: None)
    print(f"float baseline loss {final_f['loss']:.3f} "
          f"(QAT gap: {final['loss'] - final_f['loss']:+.3f})")
