"""Quickstart: the full NullaNet Tiny flow in ~60 lines.

Train a JSC MLP with QAT (per-layer activation selection) + FCP, compile
every neuron into fixed-function combinational logic, verify the logic
network is bit-exact vs the quantized model, and report the mapped
hardware cost (LUTs / FFs / fmax) vs the LogicNets-style baseline.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.jsc import JSC_DEMO
from repro.core.logic_infer import hardware_report
from repro.core.netlist import emit_network
from repro.data.jsc import train_test
from repro.models.mlp import mlp_forward, to_logic
from repro.train.jsc_trainer import train_jsc

# a reduced JSC so the demo runs in ~a minute on CPU
cfg = JSC_DEMO
data = train_test(8000, 2000, seed=0)

print("1) QAT + fanin-constrained-pruning training ...")
res = train_jsc(cfg, steps=500, data=data)
print(f"   test accuracy: {res.test_acc:.4f} "
      f"(float reference: {res.float_test_acc:.4f})")

print("2) compiling neurons to truth tables (MAC+BN+act -> logic) ...")
net = to_logic(cfg, res.params, res.masks, res.bn_state)

print("3) verifying bit-exact equivalence on the test set ...")
x = jnp.asarray(data[1][0][:1000])
scores, _ = mlp_forward(cfg, res.params, res.masks, res.bn_state, x)
pred_mlp = np.asarray(jnp.argmax(scores[:, :5], -1))
pred_logic = np.asarray(jnp.argmax(net(x)[:, :5], -1))
assert (pred_mlp == pred_logic).all(), "logic network diverged!"
print("   bit-exact: OK")

print("4) two-level minimization + 6-LUT mapping ...")
mini, _ = hardware_report(net, minimize_logic=True)
base, _ = hardware_report(net, minimize_logic=False)
print(f"   NullaNet Tiny : {mini.luts:5d} LUTs  {mini.ffs:4d} FFs  "
      f"fmax {mini.fmax_mhz:7.1f} MHz")
print(f"   LogicNets-ish : {base.luts:5d} LUTs  {base.ffs:4d} FFs  "
      f"fmax {base.fmax_mhz:7.1f} MHz")
print(f"   -> {base.luts / max(mini.luts, 1):.2f}x fewer LUTs")

print("5) emitting Verilog netlist -> /tmp/nullanet_tiny.v")
with open("/tmp/nullanet_tiny.v", "w") as f:
    f.write(emit_network(net))
print("done.")
