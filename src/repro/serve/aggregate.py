"""Bitplane request aggregation: concurrent requests fill uint32 lanes.

``repro.synth``'s executor packs 32 *samples* per uint32 word and
evaluates the whole mapped 6-LUT netlist once per pack. Here the lanes
are filled with 32 concurrent *requests* instead: the scheduler's batch
(row-concatenated request payloads) is quantized to input codes, each
code bit scattered into its wire's bitplane with request r in bit r%32
of word r//32, and one netlist evaluation over the precompiled plan
serves the entire pack — the paper's bit-level parallelism turned
into a request-throughput mechanism. Per-request argmaxes are sliced
back out of the output planes, bit-identical to ``classify`` on the
gather and Pallas paths.

The netlist executor is whatever engine the ``BitplaneNetwork`` was
built with (``repro.synth.executors`` registry): under the device
engines (``"pallas"``, ``"pallas-streamed"``) the packed words are
handed straight to the kernel and only the scattered argmax labels come
back — pack → all levels → complement → argmax is one fused jit, so
between enqueue and verdict nothing touches the host. The numpy engine
keeps the host fold (``execute_packed``) + decode. Aggregation itself
is engine-agnostic; ``classify_packed`` dispatches.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs.trace import NULL_TRACER
from repro.synth.executor import BitplaneNetwork
from repro.synth.simulate import WORD_BITS, pack_bits


class BitplaneAggregator:
    """Scheduler executor: one netlist evaluation per request pack.

    Satisfies the ``MicroBatchScheduler`` executor contract
    ``(B, n_features) -> (B,)``; every 32 rows of the batch share one
    uint32 lane-word through the whole netlist.

    Not thread-safe by design — the scheduler serializes executor calls
    on one dispatch thread, so the ``n_*`` counters need no lock and
    carry no ``_GUARDED_BY`` annotation. Wrap in ``ReplicaSet`` for
    concurrent dispatch.
    """

    def __init__(self, bitnet: BitplaneNetwork, n_classes: int,
                 pad_rows: Optional[int] = None):
        self.bitnet = bitnet
        self.n_classes = n_classes
        self.lanes_per_word = WORD_BITS
        self.pad_rows = pad_rows
        self.tracer = NULL_TRACER
        # online-profiling hook: called with (measured device µs, rows)
        # after each netlist evaluation when set (see
        # repro.obs.online.OnlineProfiler.observe)
        self.on_device_us: Optional[callable] = None
        self.n_features = bitnet.net.n_inputs   # admission width check
        self.n_evals = 0            # lane-words carrying >= 1 real request
        self.n_rows = 0             # request rows served
        self.n_pad_rows = 0         # shape-stability padding rows added
        self.n_partial_packs = 0    # flushes whose last lane-word is partial
        if pad_rows:                # warm the single quantizer shape
            self(np.zeros((1, bitnet.net.n_inputs), np.float32))
            self.n_evals = self.n_rows = 0
            self.n_pad_rows = self.n_partial_packs = 0

    def pack_requests(self, x: np.ndarray) -> np.ndarray:
        """(B, n_features) real inputs -> (n_pi_wires, ceil(B/32)) words.

        With ``pad_rows`` set, short batches are zero-padded to that row
        count first: the input quantizer is (eager) jax, and a fixed
        batch shape keeps it compiled once instead of once per distinct
        flush size.
        """
        bn = self.bitnet
        if self.pad_rows and x.shape[0] < self.pad_rows:
            x = np.concatenate(
                [x, np.zeros((self.pad_rows - x.shape[0], x.shape[1]),
                             x.dtype)])
        codes = np.asarray(bn.net.quantize_inputs(x), np.int64)
        planes = np.empty((codes.shape[1] * bn.in_bits, codes.shape[0]),
                          np.uint8)
        for b in range(bn.in_bits):     # wire i*in_bits+b = bit b of code i
            planes[b::bn.in_bits] = ((codes >> b) & 1).T
        return pack_bits(planes)

    def __call__(self, x: np.ndarray,
                 deadline_us: Optional[float] = None) -> np.ndarray:
        """Evaluate one request pack. ``deadline_us`` (the tightest
        absolute SLO deadline in the batch, forwarded by the scheduler)
        is what triggers partial-pack flushes upstream: the scheduler
        dispatches before the lane-word is full whenever that deadline
        cannot absorb further fill-wait, and ``n_partial_packs`` counts
        how often the pack went out with idle lanes as a result."""
        x = np.asarray(x)
        true_rows = x.shape[0]
        with self.tracer.span("aggregate_pack", cat="pack", args={
                "rows": true_rows,
                "lane_words": -(-true_rows // self.lanes_per_word)}):
            pi_words = self.pack_requests(x)
        # engine dispatch happens inside classify_packed: the pallas
        # engine ships the words to the device and returns only the
        # scattered per-request argmax; numpy is the host fold + decode.
        if self.on_device_us is not None:
            # timed with wall perf_counter, not the tracer clock: the
            # profiler wants real device µs even under a FakeClock
            import time
            t0 = time.perf_counter()
            with self.tracer.span("device_exec", cat="exec", args={
                    "rows": true_rows, "engine": self.bitnet.engine}):
                labels = self.bitnet.classify_packed(pi_words, true_rows,
                                                     self.n_classes)
            self.on_device_us((time.perf_counter() - t0) * 1e6, true_rows)
        else:
            with self.tracer.span("device_exec", cat="exec", args={
                    "rows": true_rows, "engine": self.bitnet.engine}):
                labels = self.bitnet.classify_packed(pi_words, true_rows,
                                                     self.n_classes)
        # occupancy is accounted against *real* request rows: lane-words
        # that exist only because of pad_rows shape-stability padding
        # are tracked separately, not counted as served capacity.
        self.n_evals += -(-true_rows // self.lanes_per_word)
        self.n_rows += true_rows
        if self.pad_rows and true_rows < self.pad_rows:
            self.n_pad_rows += self.pad_rows - true_rows
        if true_rows % self.lanes_per_word:
            self.n_partial_packs += 1
        return labels

    def set_tracer(self, tracer) -> None:
        """Adopt ``tracer`` (propagated to the underlying network so
        device spans nest inside ``device_exec``); the scheduler calls
        this automatically when constructed with one."""
        self.tracer = tracer
        self.bitnet.tracer = tracer

    def stats(self) -> dict:
        occ = self.mean_lane_occupancy
        return {"n_evals": self.n_evals, "n_rows": self.n_rows,
                "n_pad_rows": self.n_pad_rows,
                "n_partial_packs": self.n_partial_packs,
                "engine": self.bitnet.engine,
                "mean_lane_occupancy": occ}

    def publish(self, registry, name: str = "aggregate") -> None:
        """Expose the occupancy counters through a
        ``repro.obs.MetricsRegistry`` snapshot provider."""
        registry.register(name, self.stats)

    @property
    def mean_lane_occupancy(self) -> Optional[float]:
        """Fraction of uint32 lanes (in lane-words carrying at least one
        real request) filled by a real request; shape-stability pad rows
        are excluded (see ``n_pad_rows``)."""
        if self.n_evals == 0:
            return None
        return self.n_rows / (self.n_evals * self.lanes_per_word)
