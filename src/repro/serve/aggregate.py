"""Bitplane request aggregation: concurrent requests fill uint32 lanes.

``repro.synth``'s executor packs 32 *samples* per uint32 word and
evaluates the whole mapped 6-LUT netlist once per pack. Here the lanes
are filled with 32 concurrent *requests* instead: the scheduler's batch
(row-concatenated request payloads) is quantized to input codes, each
code bit scattered into its wire's bitplane with request r in bit r%32
of word r//32, and one netlist evaluation over the precompiled plan
serves the entire pack — the paper's bit-level parallelism turned
into a request-throughput mechanism. Per-request argmaxes are sliced
back out of the output planes, bit-identical to ``classify`` on the
gather and Pallas paths.

With ``BitplaneNetwork(engine="pallas")`` the packed words are handed
straight to the device (``kernels.lut_eval``) and only the scattered
argmax labels come back — pack → all levels → complement → argmax is
one fused jit, so between enqueue and verdict nothing touches the host.
The numpy engine keeps the host fold (``execute_packed``) + decode.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.synth.executor import BitplaneNetwork
from repro.synth.simulate import WORD_BITS, pack_bits


class BitplaneAggregator:
    """Scheduler executor: one netlist evaluation per request pack.

    Satisfies the ``MicroBatchScheduler`` executor contract
    ``(B, n_features) -> (B,)``; every 32 rows of the batch share one
    uint32 lane-word through the whole netlist.
    """

    def __init__(self, bitnet: BitplaneNetwork, n_classes: int,
                 pad_rows: Optional[int] = None):
        self.bitnet = bitnet
        self.n_classes = n_classes
        self.lanes_per_word = WORD_BITS
        self.pad_rows = pad_rows
        self.n_evals = 0            # netlist evaluations issued
        self.n_rows = 0             # request rows served
        if pad_rows:                # warm the single quantizer shape
            self(np.zeros((1, bitnet.net.n_inputs), np.float32))
            self.n_evals = self.n_rows = 0

    def pack_requests(self, x: np.ndarray) -> np.ndarray:
        """(B, n_features) real inputs -> (n_pi_wires, ceil(B/32)) words.

        With ``pad_rows`` set, short batches are zero-padded to that row
        count first: the input quantizer is (eager) jax, and a fixed
        batch shape keeps it compiled once instead of once per distinct
        flush size.
        """
        bn = self.bitnet
        if self.pad_rows and x.shape[0] < self.pad_rows:
            x = np.concatenate(
                [x, np.zeros((self.pad_rows - x.shape[0], x.shape[1]),
                             x.dtype)])
        codes = np.asarray(bn.net.quantize_inputs(x), np.int64)
        planes = np.empty((codes.shape[1] * bn.in_bits, codes.shape[0]),
                          np.uint8)
        for b in range(bn.in_bits):     # wire i*in_bits+b = bit b of code i
            planes[b::bn.in_bits] = ((codes >> b) & 1).T
        return pack_bits(planes)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x)
        pi_words = self.pack_requests(x)
        # engine dispatch happens inside classify_packed: the pallas
        # engine ships the words to the device and returns only the
        # scattered per-request argmax; numpy is the host fold + decode.
        labels = self.bitnet.classify_packed(pi_words, x.shape[0],
                                             self.n_classes)
        self.n_evals += pi_words.shape[1]       # one eval per lane-word
        self.n_rows += x.shape[0]
        return labels

    @property
    def mean_lane_occupancy(self) -> Optional[float]:
        """Fraction of uint32 lanes carrying a real request."""
        if self.n_evals == 0:
            return None
        return self.n_rows / (self.n_evals * self.lanes_per_word)
