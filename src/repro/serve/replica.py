"""N-replica dispatch: round-robin / least-loaded / least-slack over
engine replicas.

A ``ReplicaSet`` is itself a scheduler executor — it picks a healthy
replica per batch, retries the batch on the next replica when one
raises (failover), and only surfaces an error once every replica is
down. It accepts the scheduler's ``deadline_us`` (the tightest absolute
SLO deadline in the batch): the ``least_slack`` policy routes to the
replica with the smallest expected completion time (in-flight load x
smoothed per-replica execution time — the choice that preserves the
most slack), and on failover the remaining budget is re-stamped — if
the deadline passed while a replica was failing, the retry is abandoned
with a typed ``RequestRejected(DEADLINE_EXCEEDED)`` instead of burning
another replica on a result nobody can use.

Replicas are data-parallel copies of the serving function; when a
``repro.dist`` mesh is active their input batches are placed through
``dist.shardings.batch_shardings`` so the same partitioning rules that
lay out training batches lay out serving batches.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.obs.trace import NULL_TRACER

from .clock import SystemClock
from .sched import RejectReason, RequestRejected


class AllReplicasDown(RuntimeError):
    pass


@dataclasses.dataclass
class Replica:
    fn: Callable[[np.ndarray], np.ndarray]
    rid: int
    healthy: bool = True
    inflight: int = 0
    served: int = 0
    failures: int = 0
    ewma_us: float = 0.0            # smoothed per-batch execution time
    ewma_seeded: bool = False       # calibrated seed in ewma_us (keep it)


class ReplicaSet:
    """Dispatch policy over replica callables (``policy``: ``"rr"`` |
    ``"least_loaded"`` | ``"least_slack"``)."""

    # enforced by repro.check's concurrency lint: the round-robin cursor
    # is shared by every dispatching thread
    _GUARDED_BY = {"_rr": "_lock"}

    def __init__(self, fns: Sequence[Callable], policy: str = "rr",
                 clock=None, n_features: Optional[int] = None,
                 exec_seed_us: Optional[float] = None):
        if policy not in ("rr", "least_loaded", "least_slack"):
            raise ValueError(f"unknown dispatch policy {policy!r}")
        assert len(fns) >= 1
        self.replicas = [Replica(fn=f, rid=i) for i, f in enumerate(fns)]
        if exec_seed_us is not None:
            # calibrated per-batch execution estimate (kernelprof
            # LatencyTable) — least_slack starts informed instead of
            # treating every replica as free until its first batch
            for r in self.replicas:
                r.ewma_us = float(exec_seed_us)
                r.ewma_seeded = True
        self.policy = policy
        self.clock = clock or SystemClock()
        self.tracer = NULL_TRACER
        if n_features is None:      # propagate the width admission check
            n_features = next(
                (getattr(f, "n_features") for f in fns
                 if getattr(f, "n_features", None) is not None), None)
        self.n_features = n_features
        self._rr = 0
        self._lock = threading.Lock()

    def _pick(self) -> Optional[Replica]:
        with self._lock:
            healthy = [r for r in self.replicas if r.healthy]
            if not healthy:
                return None
            if self.policy == "least_loaded":
                r = min(healthy, key=lambda r: (r.inflight, r.rid))
            elif self.policy == "least_slack":
                # expected completion = queued-behind work x smoothed
                # exec time; the replica minimizing it eats the least of
                # the batch's remaining deadline budget
                r = min(healthy, key=lambda r: ((r.inflight + 1) * r.ewma_us,
                                                r.inflight, r.rid))
            else:
                r = healthy[self._rr % len(healthy)]
                self._rr += 1
            r.inflight += 1
            return r

    def reseed_exec_estimate(self, us: float) -> None:
        """Re-seed every replica's execution-time EWMA with a fresher
        calibration (the online profiler's blended live estimate);
        per-replica measurements keep blending in on top."""
        with self._lock:
            for r in self.replicas:
                r.ewma_us = float(us)
                r.ewma_seeded = True

    def mark_down(self, rid: int) -> None:
        with self._lock:
            self.replicas[rid].healthy = False

    def mark_up(self, rid: int) -> None:
        with self._lock:
            self.replicas[rid].healthy = True

    def __call__(self, x: np.ndarray,
                 deadline_us: Optional[float] = None) -> np.ndarray:
        """Run one batch with failover: a raising replica is marked down
        and the batch retried elsewhere — unless ``deadline_us`` (the
        batch's tightest absolute deadline) has already passed, in which
        case the retry is shed with a typed reject."""
        last_exc: Optional[BaseException] = None
        for attempt in range(len(self.replicas)):
            if (attempt > 0 and deadline_us is not None
                    and math.isfinite(deadline_us)
                    and self.clock.now_us() > deadline_us):
                # failover budget re-stamp: the failed attempt consumed
                # the whole budget — reject instead of serving late
                raise RequestRejected(
                    RejectReason.DEADLINE_EXCEEDED,
                    f"budget exhausted during failover (attempt "
                    f"{attempt + 1})") from last_exc
            r = self._pick()
            if r is None:
                break
            t0 = self.clock.now_us()
            try:
                with self.tracer.span("replica_dispatch", cat="dispatch",
                                      args={"rid": r.rid,
                                            "attempt": attempt,
                                            "policy": self.policy}):
                    out = r.fn(x)
                dt = self.clock.now_us() - t0
                with self._lock:
                    r.inflight -= 1
                    r.served += 1
                    # first real measurement replaces a cold 0.0 but
                    # only blends into a calibrated kernelprof seed
                    r.ewma_us = (dt if r.served == 1 and not r.ewma_seeded
                                 else 0.8 * r.ewma_us + 0.2 * dt)
                return out
            except Exception as e:
                last_exc = e
                with self._lock:
                    r.inflight -= 1
                    r.failures += 1
                    r.healthy = False
                self.tracer.instant("replica_failover", cat="dispatch",
                                    args={"rid": r.rid,
                                          "error": type(e).__name__})
        raise AllReplicasDown(
            f"no healthy replica left (of {len(self.replicas)})"
        ) from last_exc

    def set_tracer(self, tracer) -> None:
        """Adopt ``tracer``; replica callables that themselves support
        ``set_tracer`` (e.g. aggregators) are wired through too, so
        device spans nest inside ``replica_dispatch``."""
        self.tracer = tracer
        for r in self.replicas:
            if hasattr(r.fn, "set_tracer"):
                r.fn.set_tracer(tracer)

    def stats(self) -> List[dict]:
        with self._lock:
            return [{"rid": r.rid, "healthy": r.healthy, "served": r.served,
                     "failures": r.failures, "inflight": r.inflight,
                     "ewma_us": r.ewma_us, "ewma_seeded": r.ewma_seeded}
                    for r in self.replicas]

    def publish(self, registry, name: str = "replicas") -> None:
        """Expose per-replica dispatch stats through a
        ``repro.obs.MetricsRegistry`` snapshot provider."""
        registry.register(
            name, lambda: {"policy": self.policy, "replicas": self.stats()})


# ---------------------------------------------------------------------------
# dist-placed logic-engine replicas
# ---------------------------------------------------------------------------

def mesh_placed(fn: Callable, mesh) -> Callable:
    """Wrap an executor so its batch is device_put with the repro.dist
    batch partitioning rules before evaluation (no-op without a mesh)."""
    if mesh is None:
        return fn

    import jax
    import jax.numpy as jnp

    from repro.dist import shardings

    def placed(x: np.ndarray) -> np.ndarray:
        arr = jnp.asarray(x)
        sh = shardings.batch_shardings(
            mesh, jax.ShapeDtypeStruct(arr.shape, arr.dtype))
        return np.asarray(fn(jax.device_put(arr, sh)))

    placed.n_features = getattr(fn, "n_features", None)
    if hasattr(fn, "set_tracer"):       # keep tracer wiring reachable
        placed.set_tracer = fn.set_tracer
    return placed


def build_logic_replicas(net, n_classes: int, n_replicas: int = 1,
                         backend: str = "gather", max_batch: int = 256,
                         policy: str = "rr", mesh=None,
                         engine: str = "numpy",
                         exec_seed_us: Optional[float] = None) -> ReplicaSet:
    """Data-parallel ``LogicEngine`` replicas behind one dispatch point.

    Each replica owns its own engine (own jit cache / synthesized
    netlist); with a mesh active, batches route through the
    ``repro.dist`` sharding rules on their way in. ``engine`` selects
    the bitplane backend's netlist executor (numpy fold or the
    ``kernels.lut_eval`` device pipeline). ``exec_seed_us`` seeds every
    replica's execution-time EWMA with a calibrated kernelprof estimate.
    """
    from repro.serving.engine import LogicEngine

    fns = []
    for _ in range(n_replicas):
        eng = LogicEngine(net, n_classes, max_batch=max_batch,
                          backend=backend, engine=engine)
        fns.append(mesh_placed(eng.scheduler_executor(), mesh))
    return ReplicaSet(fns, policy=policy, n_features=net.n_inputs,
                      exec_seed_us=exec_seed_us)
