"""repro.serve — async micro-batching serving substrate.

The throughput layer over the paper's fixed-function logic inference:

  sched     — event-driven micro-batch scheduler (injectable clock,
              per-lane SLO deadlines with EDF batch formation and
              expiry shedding, deadline/size flush, priority lanes,
              typed backpressure);
  aggregate — bitplane request aggregation: 32 concurrent requests per
              uint32 lane through one ``repro.synth`` netlist eval;
  replica   — round-robin / least-loaded / least-slack dispatch with
              deadline-aware failover over data-parallel replicas
              placed via ``repro.dist``;
  metrics   — enqueue→complete latency histograms, per-lane
              deadline-miss rates, slack histograms, shed counts,
              queue depth, batch occupancy and QPS;
  clock     — SystemClock / FakeClock so the whole engine is
              deterministic under test.

``benchmarks/loadgen.py`` drives the stack end-to-end (open-loop
Poisson + closed-loop) and writes ``BENCH_serve.json``.
"""
from .aggregate import BitplaneAggregator
from .clock import FakeClock, SystemClock
from .metrics import LatencyHistogram, ServeMetrics
from .replica import (AllReplicasDown, ReplicaSet, build_logic_replicas,
                      mesh_placed)
from .sched import (BoundedPriorityQueue, MicroBatchScheduler, RejectReason,
                    RequestRejected, SchedConfig, ServeFuture, ServeRequest)

__all__ = [
    "BitplaneAggregator", "FakeClock", "SystemClock", "LatencyHistogram",
    "ServeMetrics", "AllReplicasDown", "ReplicaSet", "build_logic_replicas",
    "mesh_placed", "BoundedPriorityQueue", "MicroBatchScheduler",
    "RejectReason", "RequestRejected", "SchedConfig", "ServeFuture",
    "ServeRequest",
]
