"""Event-driven micro-batch scheduler with an injectable clock.

The core is a deterministic discrete-event engine: ``submit`` enqueues
into bounded priority lanes (typed reject on overflow), ``poll`` forms
and executes batches — flush when ``max_batch`` rows are waiting, when
the oldest request has aged past ``max_wait_us``, or when the tightest
SLO deadline in the queue can no longer absorb further fill-wait,
whichever comes first. Nothing inside reads wall time except through
the injected clock, so a ``FakeClock`` test steps the exact same code
path production runs.

Deadlines are first-class: every request carries an absolute
``deadline_us`` (explicit per-request budget, or defaulted from the
per-lane SLO table ``SchedConfig.lane_slo_us`` — e.g. lane 0 = 100 µs,
lane 1 = 1 ms). Batch formation is earliest-deadline-first within each
priority lane, and a request that is already past its deadline is
*shed*: its future fails with a typed
``RequestRejected(DEADLINE_EXCEEDED)`` instead of silently riding a
late batch — under overload the paper's fixed-latency story demands a
fast "no" over a slow "yes".

Two drivers sit on top of the core:
  * synchronous — ``poll``/``drain`` called by the owner (tests, the
    ``serve_queue`` compatibility wrapper, simulated loadgen);
  * threaded — ``start()`` spawns a flush loop that sleeps until the
    earliest flush obligation (SLO deadline or age cap) and wakes on
    submit (real-time open-loop serving).

The executor contract is one callable ``(B, ...) -> (B,)``: it receives
the concatenated rows of every request in the batch and returns one
result row per input row. Executors may additionally accept a
``deadline_us`` keyword (the tightest absolute deadline in the batch;
detected by signature inspection) and may expose ``n_features`` so
admission can reject wrong-width payloads before they poison a batch.
``repro.serve.aggregate.BitplaneAggregator`` and
``repro.serve.replica.ReplicaSet`` both satisfy the extended contract.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import inspect
import math
import threading
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import NULL_TRACER

from .clock import SystemClock
from .metrics import ServeMetrics

# ---------------------------------------------------------------------------
# Futures + typed rejection
# ---------------------------------------------------------------------------


class RejectReason:
    QUEUE_FULL = "queue_full"
    SHUTDOWN = "shutdown"
    TOO_LARGE = "too_large"
    BAD_PRIORITY = "bad_priority"
    BAD_SHAPE = "bad_shape"
    DEADLINE_EXCEEDED = "deadline_exceeded"
    DEGRADED = "degraded"       # burn-rate degradation shed (loosest lane)


class RequestRejected(RuntimeError):
    """Admission-control reject; ``reason`` is a ``RejectReason`` value."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"request rejected ({reason}){': ' if detail else ''}"
                         f"{detail}")
        self.reason = reason


class ServeFuture:
    """Thread-safe single-assignment result slot for one request."""

    def __init__(self):
        self._ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None
        self.t_enqueue_us: float = 0.0
        self.t_done_us: float = 0.0
        self.trace_id: Optional[int] = None

    def done(self) -> bool:
        return self._ev.is_set()

    def set_result(self, value) -> None:
        self._result = value
        self._ev.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._ev.set()

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("request still pending")
        if self._exc is not None:
            raise self._exc
        return self._result

    @property
    def latency_us(self) -> float:
        return self.t_done_us - self.t_enqueue_us


@dataclasses.dataclass
class ServeRequest:
    x: object                   # (rows, ...) payload (or an LMRequest)
    rows: int
    priority: int
    t_enqueue_us: float
    future: ServeFuture
    deadline_us: float = math.inf   # absolute SLO deadline (inf = none)
    seq: int = 0                    # admission order (EDF tie-break)
    queued: bool = False            # live in a BoundedPriorityQueue lane
    trace_id: Optional[int] = None  # async-span id (None when untraced)

    def slack_us(self, now_us: float) -> float:
        """Remaining budget; negative once the deadline has passed."""
        return self.deadline_us - now_us


# ---------------------------------------------------------------------------
# Bounded priority lanes (shared with LMEngine admission)
# ---------------------------------------------------------------------------

class BoundedPriorityQueue:
    """EDF-within-lane priority queue with bounded total occupancy.

    Lane 0 is the highest priority. Within a lane, requests are held in
    earliest-deadline-first order (ties broken by admission order, so
    deadline-free traffic stays FIFO). ``push`` raises
    ``RequestRejected`` instead of blocking — backpressure is the
    caller's signal to shed load, the serving analogue of the paper's
    fixed-capacity fabric.
    """

    def __init__(self, max_queue: int, n_priorities: int = 2):
        assert n_priorities >= 1
        self.max_queue = max_queue
        self.lanes: List[List[ServeRequest]] = [
            [] for _ in range(n_priorities)]
        self._len = 0
        self._rows = 0
        self._seq = 0
        # min-heap of (t_enqueue_us, seq, req) with lazy deletion (dead
        # entries skipped via req.queued), so the oldest-arrival peek
        # stays O(log n) amortized while lanes hold EDF order
        self._arrivals: List[Tuple[float, int, ServeRequest]] = []

    def __len__(self) -> int:
        return self._len

    @property
    def rows(self) -> int:
        return self._rows

    def push(self, req: ServeRequest) -> None:
        if not 0 <= req.priority < len(self.lanes):
            raise RequestRejected(
                RejectReason.BAD_PRIORITY,
                f"priority {req.priority} not in [0, {len(self.lanes)})")
        if self._len >= self.max_queue:
            raise RequestRejected(
                RejectReason.QUEUE_FULL,
                f"{self._len} requests already queued (max {self.max_queue})")
        req.seq = self._seq
        self._seq += 1
        bisect.insort(self.lanes[req.priority], req,
                      key=lambda r: (r.deadline_us, r.seq))
        req.queued = True
        heapq.heappush(self._arrivals, (req.t_enqueue_us, req.seq, req))
        self._len += 1
        self._rows += req.rows

    def _unlink(self, lane: List[ServeRequest], idx: int) -> ServeRequest:
        req = lane.pop(idx)
        req.queued = False
        self._len -= 1
        self._rows -= req.rows
        return req

    def oldest_enqueue_us(self) -> Optional[float]:
        h = self._arrivals
        while h and not h[0][2].queued:     # lazy-delete popped requests
            heapq.heappop(h)
        return h[0][0] if h else None

    def earliest_flush_us(self, max_wait_us: float,
                          margin_us: float = 0.0) -> Optional[float]:
        """Earliest instant any queued request must be dispatched: the
        oldest arrival's age cap (``t_enqueue + max_wait_us``) or the
        tightest SLO deadline minus ``margin_us`` (the execution-time
        estimate — the last moment a flush can still complete in
        budget), whichever is sooner. None when idle. O(lanes) plus the
        amortized arrival-heap peek — lanes are EDF-sorted, so each
        lane's tightest deadline is its head."""
        oldest = self.oldest_enqueue_us()
        if oldest is None:
            return None
        best = oldest + max_wait_us
        for lane in self.lanes:
            if lane and math.isfinite(lane[0].deadline_us):
                best = min(best, lane[0].deadline_us - margin_us)
        return best

    def shed_expired(self, now_us: float) -> List[ServeRequest]:
        """Remove and return every request already past its deadline.

        EDF order puts expired requests at the front of each lane, so
        this is a prefix pop per lane."""
        out: List[ServeRequest] = []
        for lane in self.lanes:
            while lane and now_us > lane[0].deadline_us:
                out.append(self._unlink(lane, 0))
        return out

    def pop_batch(self, max_rows: int) -> List[ServeRequest]:
        """Highest-priority-first batch of whole requests, EDF within
        each lane, up to ``max_rows`` total rows; stops at the first
        head-of-line request that does not fit (no within-lane
        reordering past the deadline order)."""
        out: List[ServeRequest] = []
        rows = 0
        for lane in self.lanes:
            while lane and rows + lane[0].rows <= max_rows:
                req = self._unlink(lane, 0)
                out.append(req)
                rows += req.rows
            if lane and out and rows + lane[0].rows > max_rows:
                break
        return out

    def pop_all(self) -> List[ServeRequest]:
        out: List[ServeRequest] = []
        for lane in self.lanes:
            out.extend(lane)
            lane.clear()
        for req in out:
            req.queued = False
        self._arrivals.clear()
        self._len = 0
        self._rows = 0
        return out


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SchedConfig:
    max_batch: int = 256          # flush at this many rows ...
    max_wait_us: float = 200.0    # ... or when the oldest waits this long
    max_queue: int = 4096         # admission bound, in requests
    n_priorities: int = 2
    # Per-lane SLO table: lane i's default deadline budget (µs from
    # enqueue), e.g. (100.0, 1000.0) = lane 0 must complete in 100 µs,
    # lane 1 in 1 ms. None (or a missing lane entry) = no deadline;
    # an explicit ``submit(..., deadline_us=...)`` always wins.
    lane_slo_us: Optional[Tuple[float, ...]] = None
    # Calibrated batch-execution estimate (µs) seeding the flush-margin
    # EWMA, e.g. ``LatencyTable.estimate_plan_us`` from
    # ``repro.obs.kernelprof`` — without it the first deadline-margin
    # flush decisions run on a cold 0 µs estimate.
    exec_estimate_us: Optional[float] = None

    def slo_for_lane(self, lane: int) -> float:
        if self.lane_slo_us is None or lane >= len(self.lane_slo_us):
            return math.inf
        return float(self.lane_slo_us[lane])


class MicroBatchScheduler:
    """Deadline-aware micro-batching over an executor callable.

    ``executor(x_batch) -> results`` is called with the row-concatenated
    payloads of a batch; results are scattered back to each request's
    future, stamped with true enqueue→complete latency. Executors that
    accept a ``deadline_us`` keyword receive the tightest absolute
    deadline in the batch (least-slack replica dispatch, failover
    budget re-stamping); executors exposing ``n_features`` get
    wrong-width payloads rejected at admission instead of poisoning a
    whole batch.
    """

    # lock-discipline contract, enforced by repro.check's concurrency
    # lint: these fields may only be touched under ``with self._cond:``
    # (outside __init__). _exec_ewma_us/_n_execs are deliberately not
    # listed: they are written by whichever single thread drives poll()
    # and only read under the lock as a flush-timing *estimate*, where a
    # stale value is harmless.
    _GUARDED_BY = {
        "_stopping": "_cond",
        "_shutdown": "_cond",
        "_n_features": "_cond",
        "_monitor_next_us": "_cond",
    }
    # helpers that require _cond already held by the caller
    _LOCKED_METHODS = ("_degraded_check",)

    def __init__(self, executor: Callable[[np.ndarray], Sequence],
                 cfg: Optional[SchedConfig] = None, clock=None,
                 metrics: Optional[ServeMetrics] = None, tracer=None,
                 slo_monitor=None):
        self.executor = executor
        self.cfg = cfg or SchedConfig()
        self.clock = clock or SystemClock()
        self.metrics = metrics or ServeMetrics(max_batch=self.cfg.max_batch)
        # optional degradation hook (repro.obs.slo.BurnRateMonitor): the
        # monitor is fed as a metrics sink; admission evaluates its
        # multi-window rule (rate-limited) and, while any lane's alert
        # is active, sheds the *loosest* lane with a typed
        # RequestRejected(DEGRADED) — breaking the cheapest latency
        # promise to free capacity for the lanes burning budget.
        # Monitor alert callbacks run on the admitting thread, possibly
        # under self._cond: they must never call back into this
        # scheduler.
        self.slo_monitor = slo_monitor
        self._degrade_lane = self._loosest_lane()
        self._monitor_next_us = -math.inf
        self._monitor_interval_us = (
            max(slo_monitor.short_window_us / 8.0, 100.0)
            if slo_monitor is not None else 0.0)
        if slo_monitor is not None:
            self.metrics.add_sink(slo_monitor)
        # tracer and scheduler should share a clock so span timestamps
        # line up with enqueue stamps; callers constructing a
        # SpanTracer(clock=...) around the same clock get exact nesting
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None and hasattr(executor, "set_tracer"):
            executor.set_tracer(tracer)
        self.queue = BoundedPriorityQueue(self.cfg.max_queue,
                                          self.cfg.n_priorities)
        self._cond = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._shutdown = False
        # smoothed batch execution time; a calibrated kernelprof
        # estimate seeds it so the first flush margins aren't cold
        self._exec_ewma_us = float(self.cfg.exec_estimate_us or 0.0)
        self._ewma_seeded = self.cfg.exec_estimate_us is not None
        self._n_execs = 0
        self._n_features = getattr(executor, "n_features", None)
        try:
            params = inspect.signature(executor).parameters
            self._pass_deadline = "deadline_us" in params
        except (TypeError, ValueError):
            self._pass_deadline = False

    # -- admission ---------------------------------------------------------
    def _loosest_lane(self) -> int:
        """The degradation victim: the lane with the largest SLO budget
        (deadline-free lanes count as infinitely loose; ties go to the
        lower-priority index)."""
        budgets = [(self.cfg.slo_for_lane(i), i)
                   for i in range(self.cfg.n_priorities)]
        return max(budgets)[1]

    def _degraded_check(self, now_us: float, priority: int) -> bool:
        """Evaluate the burn-rate monitor (rate-limited) and decide
        whether this submit is shed by degradation. Caller holds
        ``self._cond``."""
        mon = self.slo_monitor
        if mon is None:
            return False
        if now_us >= self._monitor_next_us:
            self._monitor_next_us = now_us + self._monitor_interval_us
            mon.check(now_us)
        return priority == self._degrade_lane and bool(
            mon.alerting_lanes())

    def _payload_width(self, x: np.ndarray) -> int:
        return 1 if x.ndim == 0 else int(x.shape[-1])

    def _note_reject(self, reason: str) -> None:
        """Count an admission reject and mark it in the trace (a
        rejected request never gets an async span — the instant is its
        whole story)."""
        self.metrics.record_reject(reason)
        self.tracer.instant("reject", cat="admission",
                            args={"reason": reason})

    def submit(self, x, priority: int = 0,
               deadline_us: Optional[float] = None) -> ServeFuture:
        """Admit one request (a single sample or a (B, ...) row block).

        ``deadline_us`` is the request's latency budget in µs *from
        enqueue* (its absolute deadline is ``now + deadline_us``); when
        omitted, the lane's ``SchedConfig.lane_slo_us`` entry applies
        (no deadline if the table is unset).

        Raises ``RequestRejected`` — typed, never blocks — when the
        queue is full, the payload exceeds one batch or has the wrong
        feature width, the budget is already spent, or the scheduler is
        shut down.
        """
        x = np.asarray(x)
        rows = 1 if x.ndim <= 1 else x.shape[0]
        if rows > self.cfg.max_batch:
            self._note_reject(RejectReason.TOO_LARGE)
            raise RequestRejected(
                RejectReason.TOO_LARGE,
                f"{rows} rows > max_batch {self.cfg.max_batch}")
        if x.ndim > 2:
            self._note_reject(RejectReason.BAD_SHAPE)
            raise RequestRejected(
                RejectReason.BAD_SHAPE,
                f"payload rank {x.ndim} > 2 (want (features,) or "
                f"(rows, features))")
        budget = (self.cfg.slo_for_lane(priority)
                  if deadline_us is None else float(deadline_us))
        if budget <= 0:
            self._note_reject(RejectReason.DEADLINE_EXCEEDED)
            raise RequestRejected(
                RejectReason.DEADLINE_EXCEEDED,
                f"non-positive deadline budget {budget} µs")
        width = self._payload_width(x)
        fut = ServeFuture()
        now = self.clock.now_us()
        fut.t_enqueue_us = now
        req = ServeRequest(x=x, rows=rows, priority=priority,
                           t_enqueue_us=now, future=fut,
                           deadline_us=now + budget)
        tracer = self.tracer
        if tracer.enabled:
            req.trace_id = fut.trace_id = tracer.new_id()
        with self._cond:
            if self._shutdown:
                self._note_reject(RejectReason.SHUTDOWN)
                raise RequestRejected(RejectReason.SHUTDOWN)
            if self._degraded_check(now, priority):
                self._note_reject(RejectReason.DEGRADED)
                raise RequestRejected(
                    RejectReason.DEGRADED,
                    f"lane {priority} shed while SLO burn rate is over "
                    f"threshold on lane(s) "
                    f"{self.slo_monitor.alerting_lanes()}")
            # width check + first-payload pinning share the lock, so two
            # concurrent first submits cannot both pass with different
            # widths and poison the same batch's concatenation
            if self._n_features is not None and width != self._n_features:
                self._note_reject(RejectReason.BAD_SHAPE)
                raise RequestRejected(
                    RejectReason.BAD_SHAPE,
                    f"payload width {width} != executor width "
                    f"{self._n_features}")
            try:
                self.queue.push(req)
            except RequestRejected as e:
                self._note_reject(e.reason)
                raise
            if self._n_features is None and x.ndim > 0:
                self._n_features = width
            self.metrics.record_enqueue(len(self.queue), now)
            self._cond.notify_all()
        return fut

    def update_exec_estimate(self, us: float) -> None:
        """Re-seed the batch-execution estimate with a fresher
        calibration (``repro.obs.online.OnlineProfiler`` pushes the
        blended live-device estimate here). Subsequent measured batches
        keep blending into it through the normal EWMA."""
        self._exec_ewma_us = float(us)
        self._ewma_seeded = True

    # -- event engine ------------------------------------------------------
    def next_deadline_us(self) -> Optional[float]:
        """Earliest instant a flush is owed: the tightest queued SLO
        deadline (minus the batch-execution estimate) or the oldest
        request's ``max_wait_us`` age cap (None if idle)."""
        with self._cond:
            return self.queue.earliest_flush_us(self.cfg.max_wait_us,
                                                self._exec_ewma_us)

    def _trace_begin(self, tracer, r: "ServeRequest") -> None:
        """Open the request's async spans retroactively at its enqueue
        timestamp. Begins are recorded here on the scheduler-side paths
        (dispatch / shed / drain) rather than in ``submit`` so the
        client fast path — 64 threads contending inside ``_cond`` —
        records nothing but an id; every span still carries the exact
        enqueue time the submit path stamped on the request."""
        dl = (None if not math.isfinite(r.deadline_us)
              else r.deadline_us)
        tracer.abegin_nested("request", "queue_wait", r.trace_id,
                             r.t_enqueue_us,
                             args={"lane": r.priority, "rows": r.rows,
                                   "deadline_us": dl})

    def _shed(self, expired: List[ServeRequest], now_us: float) -> None:
        tracer = self.tracer
        for r in expired:
            r.future.t_done_us = now_us
            self.metrics.record_shed(r.priority, now_us=now_us)
            if r.trace_id is not None:
                self._trace_begin(tracer, r)
                tracer.aend("queue_wait", r.trace_id,
                            args={"flush_reason": "shed"})
                tracer.aend("request", r.trace_id,
                            args={"outcome": "shed", "lane": r.priority})
            r.future.set_exception(RequestRejected(
                RejectReason.DEADLINE_EXCEEDED,
                f"deadline missed by {now_us - r.deadline_us:.1f} µs "
                f"before dispatch (lane {r.priority})"))

    def _due_batch(self, now_us: float, force: bool
                   ) -> Tuple[List[ServeRequest], List[ServeRequest], str]:
        """(expired-to-shed, batch-to-run, flush-reason) at ``now_us``.
        Expired requests are always removed — on the forced shutdown
        drain too, a late result is still a wrong result.

        The flush reason records *which* trigger fired: ``size`` (the
        batch is row-full), ``max_wait`` (oldest request hit the age
        cap), ``deadline`` (tightest SLO deadline minus the execution
        estimate), ``drain`` (forced flush). Size wins ties — it is the
        trigger that would have fired regardless of time."""
        with self._cond:
            expired = self.queue.shed_expired(now_us)
            if len(self.queue) == 0:
                return expired, [], ""
            full = self.queue.rows >= self.cfg.max_batch
            oldest = self.queue.oldest_enqueue_us()
            age_due = (oldest is not None
                       and now_us >= oldest + self.cfg.max_wait_us)
            flush_at = self.queue.earliest_flush_us(self.cfg.max_wait_us,
                                                    self._exec_ewma_us)
            due = flush_at is not None and now_us >= flush_at
            if not (full or due or force):
                return expired, [], ""
            reason = ("size" if full else
                      "max_wait" if age_due else
                      "deadline" if due else "drain")
            return expired, self.queue.pop_batch(self.cfg.max_batch), reason

    def _run_batch(self, batch: List[ServeRequest],
                   reason: str = "drain") -> None:
        tracer = self.tracer
        rows = sum(r.rows for r in batch)
        t_form = self.clock.now_us()
        if tracer.enabled:
            for r in batch:
                if r.trace_id is not None:
                    # open both spans at the enqueue ts, close the
                    # queue phase at exactly t_form; the flush reason
                    # lives on the batch_form span
                    self._trace_begin(tracer, r)
                    tracer.aend("queue_wait", r.trace_id, ts_us=t_form)
        xs = [r.x if r.x.ndim > 1 else r.x[None] for r in batch]
        tightest = min(r.deadline_us for r in batch)
        xcat = np.concatenate(xs, axis=0) if len(xs) > 1 else xs[0]
        if tracer.enabled:
            # explicit endpoints so batch formation covers everything
            # from t_form (queue_wait ends) through the concat — the
            # per-request close loop and payload staging included;
            # otherwise that work is an unattributed reconciliation gap
            tracer.complete("batch_form", t_form, self.clock.now_us(),
                            cat="batch",
                            args={"flush_reason": reason, "rows": rows,
                                  "n_requests": len(batch)})
        t0 = self.clock.now_us()
        try:
            with tracer.span("exec", cat="exec", args={"rows": rows}):
                if self._pass_deadline:
                    res = self.executor(xcat, deadline_us=tightest)
                else:
                    res = self.executor(xcat)
        except Exception as e:              # fail the whole batch, keep serving
            now = self.clock.now_us()
            self.metrics.record_error(len(batch))
            for r in batch:
                r.future.t_done_us = now
                if r.trace_id is not None:
                    tracer.aend("request", r.trace_id,
                                args={"outcome": "error",
                                      "error": type(e).__name__})
                r.future.set_exception(e)
            return
        now = self.clock.now_us()
        self.metrics.record_batch(rows, now - t0, now_us=now)
        dt = now - t0
        self._n_execs += 1
        self._exec_ewma_us = (dt if self._n_execs == 1
                              and not self._ewma_seeded
                              else 0.8 * self._exec_ewma_us + 0.2 * dt)
        res = np.asarray(res)
        assert res.shape[0] == rows, (
            f"executor returned {res.shape[0]} rows for a {rows}-row batch")
        with tracer.span("scatter", cat="sched",
                         args={"n_requests": len(batch)}):
            off = 0
            for r in batch:
                out = res[off: off + r.rows]
                off += r.rows
                r.future.t_done_us = now
                self.metrics.record_done(now - r.t_enqueue_us, now,
                                         lane=r.priority,
                                         deadline_us=r.deadline_us)
                if r.trace_id is not None:
                    tracer.aend("request", r.trace_id, args={
                        "outcome": "ok",
                        "latency_us": now - r.t_enqueue_us})
                r.future.set_result(out[0] if r.x.ndim <= 1 else out)

    def poll(self, now_us: Optional[float] = None, force: bool = False) -> int:
        """Run every batch due at ``now_us`` (clock-now if omitted);
        ``force`` flushes regardless of deadlines. Returns requests
        resolved — completed, shed past-deadline, or failed with the
        executor's error."""
        done = 0
        while True:
            now = self.clock.now_us() if now_us is None else now_us
            expired, batch, reason = self._due_batch(now, force)
            self._shed(expired, now)
            done += len(expired)
            if not batch:
                if expired:
                    continue        # shedding may have exposed a due batch
                return done
            self._run_batch(batch, reason)
            done += len(batch)

    def drain(self) -> int:
        """Synchronously flush everything queued (partial batches too);
        already-expired requests are shed, not served late."""
        return self.poll(force=True)

    def pending(self) -> int:
        with self._cond:
            return len(self.queue)

    # -- threaded driver ---------------------------------------------------
    def start(self) -> "MicroBatchScheduler":
        assert self._thread is None, "scheduler already started"
        with self._cond:
            self._stopping = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="microbatch-sched")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            with self._cond:
                while (not self._stopping and len(self.queue) == 0):
                    self._cond.wait(timeout=0.05)
                if self._stopping and len(self.queue) == 0:
                    return
                now = self.clock.now_us()
                full = self.queue.rows >= self.cfg.max_batch
                flush_at = self.queue.earliest_flush_us(
                    self.cfg.max_wait_us, self._exec_ewma_us)
                wait_us = (0.0 if full or flush_at is None or self._stopping
                           else flush_at - now)
                if wait_us > 0:
                    self._cond.wait(timeout=wait_us * 1e-6)
                    continue
                stopping = self._stopping   # snapshot under the lock
            self.poll(force=stopping)

    def stop(self, drain: bool = True) -> None:
        """Stop the driver thread, reject all further submissions, then
        resolve what is queued (flush by default, typed shutdown-reject
        with ``drain=False``).

        Shutdown is latched *before* the final flush: a submit racing
        with ``stop`` gets a typed ``RequestRejected(SHUTDOWN)`` instead
        of being accepted into a queue nobody will ever serve again (the
        old order accepted it after the drain and its future hung
        forever).
        """
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        with self._cond:
            self._shutdown = True       # latch before the final flush
        if drain:
            self.drain()
        now = self.clock.now_us()
        with self._cond:
            leftovers = self.queue.pop_all()
        for r in leftovers:             # drain=False (or raced remnants)
            r.future.t_done_us = now
            self.metrics.record_reject(RejectReason.SHUTDOWN)
            if r.trace_id is not None:
                self._trace_begin(self.tracer, r)
                self.tracer.aend("queue_wait", r.trace_id,
                                 args={"flush_reason": "drain"})
                self.tracer.aend("request", r.trace_id,
                                 args={"outcome": "shutdown"})
            r.future.set_exception(RequestRejected(
                RejectReason.SHUTDOWN, "scheduler stopped before dispatch"))
