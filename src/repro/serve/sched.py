"""Event-driven micro-batch scheduler with an injectable clock.

The core is a deterministic discrete-event engine: ``submit`` enqueues
into bounded priority lanes (typed reject on overflow), ``poll`` forms
and executes batches — flush when ``max_batch`` rows are waiting or the
oldest request has aged past ``max_wait_us``, whichever comes first.
Nothing inside reads wall time except through the injected clock, so a
``FakeClock`` test steps the exact same code path production runs.

Two drivers sit on top of the core:
  * synchronous — ``poll``/``drain`` called by the owner (tests, the
    ``serve_queue`` compatibility wrapper, simulated loadgen);
  * threaded — ``start()`` spawns a flush loop that sleeps until the
    next deadline and wakes on submit (real-time open-loop serving).

The executor contract is one callable ``(B, ...) -> (B,)``: it receives
the concatenated rows of every request in the batch and returns one
result row per input row. ``repro.serve.aggregate.BitplaneAggregator``
and ``repro.serve.replica.ReplicaSet`` both satisfy it.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence

import numpy as np

from .clock import SystemClock
from .metrics import ServeMetrics

# ---------------------------------------------------------------------------
# Futures + typed rejection
# ---------------------------------------------------------------------------


class RejectReason:
    QUEUE_FULL = "queue_full"
    SHUTDOWN = "shutdown"
    TOO_LARGE = "too_large"
    BAD_PRIORITY = "bad_priority"


class RequestRejected(RuntimeError):
    """Admission-control reject; ``reason`` is a ``RejectReason`` value."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"request rejected ({reason}){': ' if detail else ''}"
                         f"{detail}")
        self.reason = reason


class ServeFuture:
    """Thread-safe single-assignment result slot for one request."""

    def __init__(self):
        self._ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None
        self.t_enqueue_us: float = 0.0
        self.t_done_us: float = 0.0

    def done(self) -> bool:
        return self._ev.is_set()

    def set_result(self, value) -> None:
        self._result = value
        self._ev.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._ev.set()

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("request still pending")
        if self._exc is not None:
            raise self._exc
        return self._result

    @property
    def latency_us(self) -> float:
        return self.t_done_us - self.t_enqueue_us


@dataclasses.dataclass
class ServeRequest:
    x: object                   # (rows, ...) payload (or an LMRequest)
    rows: int
    priority: int
    t_enqueue_us: float
    future: ServeFuture


# ---------------------------------------------------------------------------
# Bounded priority lanes (shared with LMEngine admission)
# ---------------------------------------------------------------------------

class BoundedPriorityQueue:
    """FIFO-within-lane priority queue with bounded total occupancy.

    Lane 0 is the highest priority. ``push`` raises ``RequestRejected``
    instead of blocking — backpressure is the caller's signal to shed
    load, the serving analogue of the paper's fixed-capacity fabric.
    """

    def __init__(self, max_queue: int, n_priorities: int = 2):
        assert n_priorities >= 1
        self.max_queue = max_queue
        self.lanes: List[Deque[ServeRequest]] = [
            deque() for _ in range(n_priorities)]
        self._len = 0
        self._rows = 0

    def __len__(self) -> int:
        return self._len

    @property
    def rows(self) -> int:
        return self._rows

    def push(self, req: ServeRequest) -> None:
        if not 0 <= req.priority < len(self.lanes):
            raise RequestRejected(
                RejectReason.BAD_PRIORITY,
                f"priority {req.priority} not in [0, {len(self.lanes)})")
        if self._len >= self.max_queue:
            raise RequestRejected(
                RejectReason.QUEUE_FULL,
                f"{self._len} requests already queued (max {self.max_queue})")
        self.lanes[req.priority].append(req)
        self._len += 1
        self._rows += req.rows

    def oldest_enqueue_us(self) -> Optional[float]:
        ts = [lane[0].t_enqueue_us for lane in self.lanes if lane]
        return min(ts) if ts else None

    def pop_batch(self, max_rows: int) -> List[ServeRequest]:
        """Highest-priority-first batch of whole requests, up to
        ``max_rows`` total rows; stops at the first head-of-line request
        that does not fit (no within-lane reordering)."""
        out: List[ServeRequest] = []
        rows = 0
        for lane in self.lanes:
            while lane and rows + lane[0].rows <= max_rows:
                req = lane.popleft()
                out.append(req)
                rows += req.rows
                self._len -= 1
                self._rows -= req.rows
            if lane and out and rows + lane[0].rows > max_rows:
                break
        return out

    def pop_all(self) -> List[ServeRequest]:
        out: List[ServeRequest] = []
        for lane in self.lanes:
            out.extend(lane)
            lane.clear()
        self._len = 0
        self._rows = 0
        return out


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SchedConfig:
    max_batch: int = 256          # flush at this many rows ...
    max_wait_us: float = 200.0    # ... or when the oldest waits this long
    max_queue: int = 4096         # admission bound, in requests
    n_priorities: int = 2


class MicroBatchScheduler:
    """Deadline-based micro-batching over an executor callable.

    ``executor(x_batch) -> results`` is called with the row-concatenated
    payloads of a batch; results are scattered back to each request's
    future, stamped with true enqueue→complete latency.
    """

    def __init__(self, executor: Callable[[np.ndarray], Sequence],
                 cfg: Optional[SchedConfig] = None, clock=None,
                 metrics: Optional[ServeMetrics] = None):
        self.executor = executor
        self.cfg = cfg or SchedConfig()
        self.clock = clock or SystemClock()
        self.metrics = metrics or ServeMetrics(max_batch=self.cfg.max_batch)
        self.queue = BoundedPriorityQueue(self.cfg.max_queue,
                                          self.cfg.n_priorities)
        self._cond = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._shutdown = False

    # -- admission ---------------------------------------------------------
    def submit(self, x, priority: int = 0) -> ServeFuture:
        """Admit one request (a single sample or a (B, ...) row block).

        Raises ``RequestRejected`` — typed, never blocks — when the
        queue is full, the payload exceeds one batch, or the scheduler
        is shut down.
        """
        x = np.asarray(x)
        rows = 1 if x.ndim <= 1 else x.shape[0]
        if rows > self.cfg.max_batch:
            self.metrics.record_reject(RejectReason.TOO_LARGE)
            raise RequestRejected(
                RejectReason.TOO_LARGE,
                f"{rows} rows > max_batch {self.cfg.max_batch}")
        fut = ServeFuture()
        now = self.clock.now_us()
        fut.t_enqueue_us = now
        req = ServeRequest(x=x, rows=rows, priority=priority,
                           t_enqueue_us=now, future=fut)
        with self._cond:
            if self._shutdown:
                self.metrics.record_reject(RejectReason.SHUTDOWN)
                raise RequestRejected(RejectReason.SHUTDOWN)
            try:
                self.queue.push(req)
            except RequestRejected as e:
                self.metrics.record_reject(e.reason)
                raise
            self.metrics.record_enqueue(len(self.queue), now)
            self._cond.notify_all()
        return fut

    # -- event engine ------------------------------------------------------
    def next_deadline_us(self) -> Optional[float]:
        """When the oldest queued request must flush (None if idle)."""
        with self._cond:
            oldest = self.queue.oldest_enqueue_us()
        if oldest is None:
            return None
        return oldest + self.cfg.max_wait_us

    def _due_batch(self, now_us: float,
                   force: bool) -> List[ServeRequest]:
        with self._cond:
            if len(self.queue) == 0:
                return []
            full = self.queue.rows >= self.cfg.max_batch
            oldest = self.queue.oldest_enqueue_us()
            aged = oldest is not None and (
                now_us - oldest >= self.cfg.max_wait_us)
            if not (full or aged or force):
                return []
            return self.queue.pop_batch(self.cfg.max_batch)

    def _run_batch(self, batch: List[ServeRequest]) -> None:
        rows = sum(r.rows for r in batch)
        xs = [r.x if r.x.ndim > 1 else r.x[None] for r in batch]
        xcat = np.concatenate(xs, axis=0) if len(xs) > 1 else xs[0]
        t0 = self.clock.now_us()
        try:
            res = self.executor(xcat)
        except Exception as e:              # fail the whole batch, keep serving
            now = self.clock.now_us()
            self.metrics.record_error(len(batch))
            for r in batch:
                r.future.t_done_us = now
                r.future.set_exception(e)
            return
        now = self.clock.now_us()
        self.metrics.record_batch(rows, now - t0)
        res = np.asarray(res)
        assert res.shape[0] == rows, (
            f"executor returned {res.shape[0]} rows for a {rows}-row batch")
        off = 0
        for r in batch:
            out = res[off: off + r.rows]
            off += r.rows
            r.future.t_done_us = now
            self.metrics.record_done(now - r.t_enqueue_us, now)
            r.future.set_result(out[0] if r.x.ndim <= 1 else out)

    def poll(self, now_us: Optional[float] = None, force: bool = False) -> int:
        """Run every batch due at ``now_us`` (clock-now if omitted);
        ``force`` flushes regardless of deadlines. Returns requests
        resolved — completed or failed with the executor's error."""
        done = 0
        while True:
            now = self.clock.now_us() if now_us is None else now_us
            batch = self._due_batch(now, force)
            if not batch:
                return done
            self._run_batch(batch)
            done += len(batch)

    def drain(self) -> int:
        """Synchronously flush everything queued (partial batches too)."""
        return self.poll(force=True)

    def pending(self) -> int:
        with self._cond:
            return len(self.queue)

    # -- threaded driver ---------------------------------------------------
    def start(self) -> "MicroBatchScheduler":
        assert self._thread is None, "scheduler already started"
        self._stopping = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="microbatch-sched")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            with self._cond:
                while (not self._stopping and len(self.queue) == 0):
                    self._cond.wait(timeout=0.05)
                if self._stopping and len(self.queue) == 0:
                    return
                now = self.clock.now_us()
                full = self.queue.rows >= self.cfg.max_batch
                oldest = self.queue.oldest_enqueue_us()
                wait_us = (0.0 if full or oldest is None or self._stopping
                           else (oldest + self.cfg.max_wait_us) - now)
                if wait_us > 0:
                    self._cond.wait(timeout=wait_us * 1e-6)
                    continue
            self.poll(force=self._stopping)

    def stop(self, drain: bool = True) -> None:
        """Stop the driver thread; by default flush what is queued first,
        then reject all further submissions."""
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        if drain:
            self.drain()
        with self._cond:
            self._shutdown = True
