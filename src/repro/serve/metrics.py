"""Serving metrics: latency histograms, queue depth, occupancy, QPS.

``ServeMetrics`` is the one object every scheduler/engine records into;
``snapshot()`` is the one dict every benchmark and launcher reports.
Latencies are enqueue→complete (the number a client actually sees),
never bare execution time — hiding head-of-line queueing is exactly the
bug the legacy ``serve_queue`` stats had.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional

import numpy as np

# log-spaced histogram bucket edges: 0.1 µs .. ~100 s, 10 buckets/decade
_BUCKET_LO_US = 0.1
_BUCKETS_PER_DECADE = 10
_N_BUCKETS = 9 * _BUCKETS_PER_DECADE + 1


def _bucket_of(us: float) -> int:
    if us <= _BUCKET_LO_US:
        return 0
    b = int(math.log10(us / _BUCKET_LO_US) * _BUCKETS_PER_DECADE)
    return min(b, _N_BUCKETS - 1)


class LatencyHistogram:
    """Log-bucketed latency histogram with exact-sample percentiles.

    Bucket counts give a bounded-memory view for dashboards; raw samples
    (bounded reservoir) keep p50/p95/p99 exact at benchmark scale.
    """

    def __init__(self, max_samples: int = 200_000):
        self.counts = np.zeros(_N_BUCKETS, np.int64)
        self.samples: List[float] = []
        # <= 0 means counts-only (no reservoir, percentiles report 0.0)
        # rather than a ZeroDivisionError on the first overflow write
        self.max_samples = max(int(max_samples), 0)
        self.n = 0
        self.total_us = 0.0

    def record(self, us: float) -> None:
        self.counts[_bucket_of(us)] += 1
        self.n += 1
        self.total_us += us
        if self.max_samples <= 0:
            return
        if len(self.samples) < self.max_samples:
            self.samples.append(us)
        else:  # reservoir: deterministic stride keep (no RNG in hot path)
            i = self.n % self.max_samples
            self.samples[i] = us

    def percentile(self, p: float) -> float:
        """Exact sample percentile; ``p`` is clamped to [0, 100] (p0 =
        min, p100 = max) and an empty reservoir reports 0.0."""
        if not self.samples:
            return 0.0
        p = min(max(float(p), 0.0), 100.0)
        return float(np.percentile(np.asarray(self.samples), p))

    def mean(self) -> float:
        return self.total_us / self.n if self.n else 0.0

    def buckets(self) -> Dict[str, int]:
        """Non-empty buckets keyed by their lower edge (µs)."""
        out = {}
        for b in np.nonzero(self.counts)[0]:
            lo = _BUCKET_LO_US * 10 ** (b / _BUCKETS_PER_DECADE)
            out[f"{lo:.3g}us"] = int(self.counts[b])
        return out


@dataclasses.dataclass
class BatchStat:
    rows: int
    occupancy: float
    exec_us: float


class LaneStats:
    """Per-priority-lane deadline accounting.

    A lane's deadline-miss rate counts both kinds of SLO failure:
    requests *shed* before dispatch (expired in queue) and requests
    *served late* (completed past their deadline). The denominator is
    deadline-carrying traffic only, and a lane that carried none omits
    ``deadline_miss_rate`` / ``slo_attainment`` from its snapshot
    entirely — a fake perfect score would otherwise seed benchmark
    baselines with a metric that was never measured.
    """

    def __init__(self):
        self.completed = 0              # all completions on this lane
        self.with_deadline = 0          # completions that carried an SLO
        self.missed = 0                 # completed but past deadline
        self.shed = 0                   # expired before dispatch
        self.lat = LatencyHistogram()
        self.slack = LatencyHistogram()     # positive slack at completion
        self.slack_sum_us = 0.0             # signed, over with_deadline

    def snapshot(self) -> Dict[str, float]:
        slo_n = self.with_deadline + self.shed
        out = {
            "completed": self.completed,
            "completed_with_deadline": self.with_deadline,
            "missed": self.missed,
            "shed": self.shed,
            "p50_us": self.lat.percentile(50),
            "p95_us": self.lat.percentile(95),
            "p99_us": self.lat.percentile(99),
            "slack_p50_us": self.slack.percentile(50),
            "slack_p10_us": self.slack.percentile(10),
            "mean_slack_us": (self.slack_sum_us / self.with_deadline
                              if self.with_deadline else 0.0),
            "slack_buckets": self.slack.buckets(),
        }
        if slo_n:        # only lanes that carried deadlines get a rate:
            # a deadline-free lane reporting attainment 1.0 would seed
            # regression baselines with a score that was never measured
            miss = (self.missed + self.shed) / slo_n
            out["deadline_miss_rate"] = miss
            out["slo_attainment"] = 1.0 - miss
        return out


class ServeMetrics:
    """Thread-safe accumulator for one scheduler (or engine) lifetime.

    Beyond accumulating, it fans events out to registered **sinks**
    (``add_sink``): streaming aggregators like
    ``repro.obs.window.WindowedMetrics`` and the burn-rate monitor in
    ``repro.obs.slo`` receive ``record_done(lane, latency_us, now_us,
    ok, rows, deadline_us)`` / ``record_shed(lane, now_us)`` /
    ``record_batch(rows, exec_us, now_us, occupancy)`` pushes with the
    scheduler-clock timestamp. Forwarding happens *outside* this
    object's lock — sinks take their own locks, and a sink must never
    call back into the scheduler (the scheduler records while holding
    its own state)."""

    def __init__(self, max_batch: int = 0):
        self._sinks: List = []
        self.max_batch = max_batch
        self.lat = LatencyHistogram()
        self.batches: List[BatchStat] = []
        self.completed = 0
        self.rejected: Dict[str, int] = {}
        self.errors = 0
        self.lanes: Dict[int, LaneStats] = {}
        self.queue_depth_sum = 0
        self.queue_depth_n = 0
        self.max_queue_depth = 0
        self.t_first_enqueue_us: Optional[float] = None
        self.t_last_done_us: Optional[float] = None
        self._lock = threading.Lock()

    def _lane(self, lane: int) -> LaneStats:
        if lane not in self.lanes:
            self.lanes[lane] = LaneStats()
        return self.lanes[lane]

    def add_sink(self, sink) -> "ServeMetrics":
        """Register a streaming consumer of recorded events. The sink
        may implement any subset of ``record_done`` / ``record_shed`` /
        ``record_batch`` (missing methods are skipped)."""
        self._sinks.append(sink)
        return self

    def _fan_out(self, method: str, /, **kw) -> None:
        for s in self._sinks:
            fn = getattr(s, method, None)
            if fn is not None:
                fn(**kw)

    # -- recording ---------------------------------------------------------
    def record_enqueue(self, depth: int, now_us: float) -> None:
        with self._lock:
            self.queue_depth_sum += depth
            self.queue_depth_n += 1
            self.max_queue_depth = max(self.max_queue_depth, depth)
            if self.t_first_enqueue_us is None:
                self.t_first_enqueue_us = now_us

    def record_reject(self, reason: str) -> None:
        with self._lock:
            self.rejected[reason] = self.rejected.get(reason, 0) + 1

    def record_batch(self, rows: int, exec_us: float,
                     now_us: Optional[float] = None) -> None:
        occ = rows / self.max_batch if self.max_batch else 1.0
        with self._lock:
            self.batches.append(BatchStat(rows, occ, exec_us))
        if self._sinks and now_us is not None:
            self._fan_out("record_batch", rows=rows, exec_us=exec_us,
                          now_us=now_us, occupancy=occ)

    def record_done(self, latency_us: float, now_us: float, lane: int = 0,
                    deadline_us: float = math.inf) -> None:
        with self._lock:
            self.lat.record(latency_us)
            self.completed += 1
            self.t_last_done_us = now_us
            ls = self._lane(lane)
            ls.completed += 1
            ls.lat.record(latency_us)
            has_deadline = math.isfinite(deadline_us)
            ok = True
            if has_deadline:
                slack = deadline_us - now_us
                ls.with_deadline += 1
                ls.slack_sum_us += slack
                if slack >= 0:
                    ls.slack.record(slack)
                else:
                    ls.missed += 1      # served, but past its deadline
                    ok = False
        if self._sinks:
            self._fan_out("record_done", lane=lane, latency_us=latency_us,
                          now_us=now_us, ok=ok,
                          deadline_us=(deadline_us if has_deadline
                                       else None))

    def record_shed(self, lane: int = 0,
                    now_us: Optional[float] = None) -> None:
        """An expired request rejected before dispatch (SLO shed)."""
        with self._lock:
            self._lane(lane).shed += 1
            self.rejected["deadline_exceeded"] = (
                self.rejected.get("deadline_exceeded", 0) + 1)
        if self._sinks and now_us is not None:
            self._fan_out("record_shed", lane=lane, now_us=now_us)

    def record_error(self, n_requests: int = 1) -> None:
        with self._lock:
            self.errors += n_requests

    # -- reporting ---------------------------------------------------------
    def publish(self, registry, name: str = "serve") -> None:
        """Expose this accumulator through a
        ``repro.obs.MetricsRegistry``: ``registry.snapshot()[name]`` is
        this object's ``snapshot()``, evaluated lazily."""
        registry.register(name, self.snapshot)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            span_us = 0.0
            if (self.t_first_enqueue_us is not None
                    and self.t_last_done_us is not None):
                span_us = self.t_last_done_us - self.t_first_enqueue_us
            occ = [b.occupancy for b in self.batches]
            rows = [b.rows for b in self.batches]
            shed = sum(ls.shed for ls in self.lanes.values())
            missed = sum(ls.missed for ls in self.lanes.values())
            slo_n = shed + sum(ls.with_deadline
                               for ls in self.lanes.values())
            return {
                "completed": self.completed,
                "rejected": int(sum(self.rejected.values())),
                "rejected_by_reason": dict(self.rejected),
                "errors": self.errors,
                "shed": shed,
                "deadline_missed": missed,
                "deadline_miss_rate": ((missed + shed) / slo_n
                                       if slo_n else 0.0),
                "lanes": {str(k): ls.snapshot()
                          for k, ls in sorted(self.lanes.items())},
                "p50_us": self.lat.percentile(50),
                "p95_us": self.lat.percentile(95),
                "p99_us": self.lat.percentile(99),
                "mean_us": self.lat.mean(),
                "qps": (self.completed / (span_us * 1e-6)
                        if span_us > 0 else 0.0),
                "span_us": span_us,
                "n_batches": len(self.batches),
                "mean_batch_rows": float(np.mean(rows)) if rows else 0.0,
                "mean_batch_occupancy": float(np.mean(occ)) if occ else 0.0,
                "mean_queue_depth": (self.queue_depth_sum
                                     / self.queue_depth_n
                                     if self.queue_depth_n else 0.0),
                "max_queue_depth": self.max_queue_depth,
                "latency_buckets": self.lat.buckets(),
            }
