"""Injectable clocks: the scheduler never reads wall time directly.

Everything time-dependent in ``repro.serve`` (batch deadlines, latency
stamps, Poisson arrival pacing) goes through a ``Clock`` so tests drive
the scheduler deterministically with ``FakeClock`` while production uses
``SystemClock``. All times are microseconds — the unit the paper's
sub-microsecond story is told in.
"""
from __future__ import annotations

import time


class SystemClock:
    """Monotonic wall clock (perf_counter) in microseconds."""

    def now_us(self) -> float:
        return time.perf_counter() * 1e6

    def sleep_us(self, us: float) -> None:
        if us > 0:
            time.sleep(us * 1e-6)


class FakeClock:
    """Deterministic test clock: time moves only via ``advance``/``sleep``."""

    def __init__(self, start_us: float = 0.0):
        self._now = float(start_us)

    def now_us(self) -> float:
        return self._now

    def advance_us(self, us: float) -> None:
        assert us >= 0, "time cannot move backwards"
        self._now += us

    def sleep_us(self, us: float) -> None:
        self.advance_us(max(0.0, us))
