"""QAT + FCP training for the JSC MLPs (the paper's training module).

Implements the full Fig. 1 left box: quantization-aware training with
per-layer activation selection, plus fanin-constrained pruning on either
the gradual (Zhu–Gupta) or ADMM schedule, ending with hard projection to
the fanin budget.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fcp import AdmmFCP, GradualFCP, project_fanin, topk_row_mask
from repro.data import jsc as jsc_data
from repro.models import mlp as mlpm
from repro.train.optim import AdamW


@dataclasses.dataclass
class JSCTrainResult:
    params: Dict
    masks: List
    bn_state: Dict
    train_acc: float
    test_acc: float
    float_test_acc: float  # unquantized-width reference


def evaluate(cfg, params, masks, bn_state, x, y) -> float:
    scores, _ = mlpm.mlp_forward(cfg, params, masks, bn_state,
                                 jnp.asarray(x), train=False)
    pred = jnp.argmax(scores[:, : cfg.n_classes], axis=-1)
    return float(jnp.mean(pred == jnp.asarray(y)))


def train_jsc(cfg: mlpm.MLPConfig, steps: int = 1500, batch: int = 256,
              lr: float = 2e-3, seed: int = 0, fcp: str = "gradual",
              fcp_begin_frac: float = 0.25, fcp_end_frac: float = 0.7,
              n_train: int = 20000, n_test: int = 5000,
              data=None) -> JSCTrainResult:
    if data is None:
        (xtr, ytr), (xte, yte) = jsc_data.train_test(n_train, n_test, seed)
    else:
        (xtr, ytr), (xte, yte) = data
    key = jax.random.PRNGKey(seed)
    params = mlpm.init_mlp_params(cfg, key)
    bn_state = mlpm.init_bn_state(cfg)
    masks = mlpm.init_masks(cfg)
    opt = AdamW(lr=lr, weight_decay=1e-4, grad_clip=1.0)
    opt_state = opt.init(params)

    sched = GradualFCP(target_fanin=0,  # per-layer target set in update
                       begin_step=int(steps * fcp_begin_frac),
                       end_step=int(steps * fcp_end_frac), freq=25)
    admm = {i: AdmmFCP(cfg.fanins[i], rho=5e-3, dual_freq=50)
            for i in range(cfg.n_layers)} if fcp == "admm" else None
    admm_state = None
    if admm:
        admm_state = [admm[i].init_state(params["layers"][i]["w"])
                      for i in range(cfg.n_layers)]

    @jax.jit
    def step_fn(params, opt_state, bn_state, masks, x, y, zs, us):
        def loss_fn(p):
            loss, new_bn = mlpm.mlp_loss(cfg, p, masks, bn_state, x, y)
            if zs is not None:
                for i in range(cfg.n_layers):
                    a = AdmmFCP(cfg.fanins[i], rho=5e-3)
                    loss = loss + a.penalty(p["layers"][i]["w"],
                                            zs[i], us[i])
            return loss, new_bn

        (loss, new_bn), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, new_bn, loss

    it = jsc_data.batches(xtr, ytr, batch, seed)
    zs = [s[0] for s in admm_state] if admm_state else None
    us = [s[1] for s in admm_state] if admm_state else None
    for t in range(steps):
        xb, yb = next(it)
        params, opt_state, bn_state, loss = step_fn(
            params, opt_state, bn_state, masks,
            jnp.asarray(xb), jnp.asarray(yb), zs, us)
        if fcp == "gradual" and t >= sched.begin_step and t % sched.freq == 0:
            masks = [jnp.asarray(m) for m in
                     mlpm.update_masks_gradual(cfg, params, t, sched)]
        if admm and t % 50 == 49:
            for i in range(cfg.n_layers):
                zs[i], us[i] = admm[i].dual_update(
                    params["layers"][i]["w"], zs[i], us[i])

    # hard projection to the fanin budget + short fine-tune
    masks = mlpm.final_masks(cfg, params)
    for i, lp in enumerate(params["layers"]):
        lp["w"] = jnp.where(masks[i], lp["w"], 0.0)
    for t in range(steps // 5):
        xb, yb = next(it)
        params, opt_state, bn_state, loss = step_fn(
            params, opt_state, bn_state, masks,
            jnp.asarray(xb), jnp.asarray(yb), None, None)

    train_acc = evaluate(cfg, params, masks, bn_state, xtr[:5000], ytr[:5000])
    test_acc = evaluate(cfg, params, masks, bn_state, xte, yte)

    # float reference (no quant/prune): same topology, quick train
    float_acc = _float_reference(cfg, xtr, ytr, xte, yte, seed)
    return JSCTrainResult(params, masks, bn_state, train_acc, test_acc,
                          float_acc)


def _float_reference(cfg, xtr, ytr, xte, yte, seed) -> float:
    key = jax.random.PRNGKey(seed + 7)
    sizes = (cfg.n_inputs,) + cfg.features
    ws = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        ws.append([jax.random.normal(k, (sizes[i + 1], sizes[i])) /
                   np.sqrt(sizes[i]), jnp.zeros(sizes[i + 1])])

    def fwd(ws, x):
        h = x
        for i, (w, b) in enumerate(ws):
            h = h @ w.T + b
            if i < len(ws) - 1:
                h = jax.nn.relu(h)
        return h

    opt = AdamW(lr=2e-3)
    st = opt.init(ws)

    @jax.jit
    def step(ws, st, x, y):
        def lf(ws):
            logits = fwd(ws, x)[:, : cfg.n_classes]
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
        g = jax.grad(lf)(ws)
        return opt.update(g, st, ws)

    it = jsc_data.batches(xtr, ytr, 256, seed)
    for _ in range(800):
        xb, yb = next(it)
        ws, st = step(ws, st, jnp.asarray(xb), jnp.asarray(yb))
    pred = jnp.argmax(fwd(ws, jnp.asarray(xte))[:, : cfg.n_classes], -1)
    return float(jnp.mean(pred == jnp.asarray(yte)))
