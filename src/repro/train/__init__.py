"""Training substrate: optimizer, schedules, checkpointing, loop."""
