"""Training loop: jitted step factory + fault-tolerant Trainer.

The step factory builds a pjit-able ``train_step(state, batch)`` for any
ArchConfig; the Trainer owns checkpoint/restore, the straggler watchdog,
emergency checkpoints and (optional) gradient compression on the
data-parallel reduction.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import compress as C
from repro.dist.fault import StepWatchdog, retry_step
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamW, AdamWState, global_norm

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamWState
    ef: Optional[C.EFState]  # gradient-compression error feedback


def make_train_step(cfg: ArchConfig, optimizer: AdamW,
                    compress: str = "none", compress_frac: float = 0.01,
                    grad_accum: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_accum`` > 1 splits the batch's leading axis into microbatches
    scanned sequentially (constant memory in the number of microbatches)
    — the standard way to push global batch beyond per-step activation
    memory at pod scale.
    """

    def loss_fn(params, batch):
        kw = {}
        if "enc_embeds" in batch:
            kw["enc_embeds"] = batch["enc_embeds"]
        if "frames" in batch:
            hidden, _, _ = lm.forward(cfg, params, frames=batch["frames"],
                                      **kw)
        else:
            hidden, _, _ = lm.forward(cfg, params, tokens=batch["tokens"],
                                      **kw)
        return lm.lm_loss(cfg, params, hidden, batch["labels"])

    def grads_of(params, batch):
        if grad_accum <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            acc_loss, acc_g = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (acc_loss + loss,
                    jax.tree_util.tree_map(jnp.add, acc_g, g)), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / grad_accum
        return loss_sum * inv, jax.tree_util.tree_map(
            lambda g: g * inv, g_sum)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, grads = grads_of(state.params, batch)
        ef = state.ef
        if compress == "topk":
            grads, ef = C.topk_compress(grads, ef, compress_frac)
        elif compress == "sign":
            grads, ef = C.sign_compress(grads, ef)
        gnorm = global_norm(grads)
        params, opt = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt.step}
        return TrainState(params, opt, ef), metrics

    return train_step


def init_state(cfg: ArchConfig, optimizer: AdamW, key,
               compress: str = "none") -> TrainState:
    params = lm.init_params(cfg, key)
    opt = optimizer.init(params)
    ef = C.init_ef(params) if compress != "none" else None
    return TrainState(params, opt, ef)


@dataclasses.dataclass
class Trainer:
    """Fault-tolerant orchestration around a jitted step.

    * resumes from the latest committed checkpoint on construction;
    * async-checkpoints every ``ckpt_every`` steps;
    * emergency (synchronous) checkpoint on any exception escape;
    * StepWatchdog flags stragglers; flagged steps are logged and, past
      ``max_straggler_events``, trigger a checkpoint so a scheduler could
      migrate the job (the 1000-node playbook).
    """

    train_step: Any
    state: TrainState
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    max_straggler_events: int = 10

    def __post_init__(self):
        self.watchdog = StepWatchdog()
        self.step = 0
        self._ckpt = (ckpt.AsyncCheckpointer(self.ckpt_dir, self.keep)
                      if self.ckpt_dir else None)
        if self.ckpt_dir:
            restored = ckpt.restore_latest(self.ckpt_dir, self.state)
            if restored is not None:
                self.state = jax.tree_util.tree_map(jnp.asarray, restored)
                self.step = int(self.state.opt.step)

    def run(self, batch_iter, n_steps: int, log_every: int = 10,
            log_fn=print) -> Dict[str, float]:
        last = {}
        safe_step = retry_step(self.train_step, max_retries=2)
        try:
            for _ in range(n_steps):
                batch = next(batch_iter)
                t0 = time.perf_counter()
                self.state, metrics = safe_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step += 1
                straggler = self.watchdog.record(dt)
                if straggler and (self.watchdog.straggler_events
                                  >= self.max_straggler_events):
                    self._save()
                if self.step % log_every == 0:
                    last = {k: float(v) for k, v in metrics.items()}
                    log_fn(f"step {self.step}: loss={last['loss']:.4f} "
                           f"gnorm={last['grad_norm']:.3f} {dt*1e3:.0f}ms")
                if self._ckpt and self.step % self.ckpt_every == 0:
                    self._save()
        except BaseException:
            if self._ckpt:  # emergency checkpoint, then re-raise
                self._ckpt.wait()
                ckpt.save(self.ckpt_dir, self.step, self.state,
                          extra={"emergency": True})
            raise
        if self._ckpt:
            self._save()
            self._ckpt.wait()
        return last

    def _save(self):
        if self._ckpt:
            self._ckpt.save(self.step, self.state,
                            extra={"mean_step_s": self.watchdog.mean_step})
