"""Optimizers from scratch (no optax offline): AdamW, SGD-momentum.

State pytrees mirror the param pytree, so under pjit the moments inherit
the 2-D fsdp+tensor param sharding — ZeRO-sharded optimizer state by
construction (see dist/shardings.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree
    master: Optional[PyTree] = None  # f32 master copy (mixed precision)


@dataclasses.dataclass(frozen=True)
class AdamW:
    """AdamW; with ``mixed_precision`` the live params are bf16 (all
    fwd/bwd collectives move 2-byte data) and the f32 master copy lives
    in the (ZeRO-sharded) optimizer state."""

    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    mixed_precision: bool = False

    def init(self, params: PyTree) -> AdamWState:
        def z(p):
            return jnp.zeros(p.shape, jnp.float32)
        master = None
        if self.mixed_precision:
            master = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree_util.tree_map(z, params),
                          jax.tree_util.tree_map(z, params),
                          master)

    def cast_params(self, params: PyTree) -> PyTree:
        if not self.mixed_precision:
            return params
        return jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16), params)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> Tuple[PyTree, AdamWState]:
        step = state.step + 1
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * (g * g), state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay > 0 and p.ndim >= 2:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return p.astype(jnp.float32) - lr * u

        src = state.master if state.master is not None else params
        new_master = jax.tree_util.tree_map(upd, src, mu, nu)
        if state.master is not None:
            new_params = self.cast_params(new_master)
            return new_params, AdamWState(step, mu, nu, new_master)
        return new_master, AdamWState(step, mu, nu, None)


class SGDState(NamedTuple):
    step: jax.Array
    momentum: PyTree


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-2
    momentum: float = 0.9
    grad_clip: float = 0.0

    def init(self, params: PyTree) -> SGDState:
        return SGDState(jnp.zeros((), jnp.int32),
                        jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(self, grads, state, params):
        step = state.step + 1
        if self.grad_clip > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        mom = jax.tree_util.tree_map(
            lambda m, g: self.momentum * m + g, state.momentum, grads)
        lr = self.lr(step) if callable(self.lr) else self.lr
        new_params = jax.tree_util.tree_map(
            lambda p, m: p - lr * m, params, mom)
        return new_params, SGDState(step, mom)


def global_norm(tree: PyTree) -> jax.Array:
    sq = jax.tree_util.tree_reduce(
        lambda acc, x: acc + jnp.sum(jnp.square(x.astype(jnp.float32))),
        tree, jnp.zeros((), jnp.float32))
    return jnp.sqrt(sq)
