"""Sharded, fault-tolerant checkpointing.

Design (1000-node posture):
  * every leaf saved as .npy inside a step directory, manifest.json maps
    flat keys -> files + shapes/dtypes; directory committed via atomic
    rename (crash mid-save never corrupts the latest checkpoint);
  * ``restore_latest`` scans for the newest committed step — the
    restart-after-node-failure path;
  * ``restore`` takes target shardings, so a checkpoint written on one
    mesh restores onto ANY other mesh (elastic rescale: 256 -> 512 chips
    or a degraded pod) via jax.make_array_from_callback per-shard reads;
  * async save: serialisation happens on a worker thread; the train loop
    only blocks on the previous save (double-buffering).

On a real multi-host pod each host writes only the shards it owns
(process-local addressable shards); in this single-process container that
degenerates to full arrays, same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: PyTree,
         extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "time": time.time(), "leaves": {},
                "extra": extra or {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


class AsyncCheckpointer:
    """Double-buffered async saves; ``wait()`` before exit/next save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: PyTree, extra=None):
        self.wait()
        # device_get on the caller thread (ordered wrt the train step),
        # file IO on the worker.
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(self.ckpt_dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(_committed_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def _committed_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(d[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _committed_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(path: str, target_tree: PyTree,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of target_tree.

    shardings: optional matching pytree of NamedShardings — leaves are
    materialised shard-by-shard (elastic re-mesh path).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    leaves_meta = manifest["leaves"]

    out_flat = {}
    for key in flat_target:
        if key not in leaves_meta:
            raise KeyError(f"checkpoint missing leaf {key}")
        meta = leaves_meta[key]
        arr = np.load(os.path.join(path, meta["file"]), mmap_mode="r")
        sh = flat_shard.get(key)
        if sh is not None:
            leaf = jax.make_array_from_callback(
                arr.shape, sh, lambda idx, a=arr: np.asarray(a[idx]))
        else:
            leaf = np.asarray(arr)
        out_flat[key] = leaf

    # rebuild pytree in target structure
    treedef = jax.tree_util.tree_structure(target_tree)
    paths = [  # same ordering as _flatten over target
        _SEP.join(str(getattr(p, "key", getattr(p, "idx",
                  getattr(p, "name", p)))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(target_tree)[0]]
    leaves = [out_flat[k] for k in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir: str, target_tree: PyTree,
                   shardings: Optional[PyTree] = None
                   ) -> Optional[PyTree]:
    s = latest_step(ckpt_dir)
    if s is None:
        return None
    return restore(os.path.join(ckpt_dir, f"step_{s:08d}"),
                   target_tree, shardings)
