"""Model zoo: unified LM (dense/MoE/SSM/hybrid/enc-dec) + quantized MLP."""
from . import layers, lm, mamba, mlp  # noqa: F401
