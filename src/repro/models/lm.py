"""Unified LM: dense / GQA / SWA / MoE / SSM / hybrid / encoder-decoder.

One parameter schema + three entry points:

  forward(cfg, params, ...)            -> hidden states (train/prefill)
  lm_loss(cfg, params, hidden, labels) -> chunked cross-entropy
  prefill(cfg, params, ...)            -> last-token logits + KV/SSM cache
  decode_step(cfg, params, cache, ...) -> next-token logits + cache

Layers are stacked on a leading L axis and executed with
``lax.scan`` + per-layer ``jax.checkpoint`` (remat): HLO stays O(1 layer)
— the policy that keeps both compile time and activation memory bounded
at 1000-node scale.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import shardings as sh
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import scan_utils as SU

Array = jax.Array
PyTree = Any

ATTN_CHUNK_THRESHOLD = 2048   # use chunked (online-softmax) attention above
ATTN_CHUNK = 1024
LOSS_CHUNK = 512              # sequence chunk for cross-entropy


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_dec_layer(key, cfg: ArchConfig) -> Dict[str, Array]:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Dict[str, Array] = {"ln1": jnp.ones((d,), jnp.float32)}
    if cfg.family == "ssm":
        p["mamba"] = M.init_mamba(ks[0], cfg)
        return p
    p["attn"] = L.init_attn(ks[0], cfg)
    if cfg.family == "hybrid":
        p["mamba"] = M.init_mamba(ks[1], cfg)
        p["ln_attn_out"] = jnp.ones((d,), jnp.float32)
        p["ln_mamba_out"] = jnp.ones((d,), jnp.float32)
    if cfg.is_encdec:
        p["ln_cross"] = jnp.ones((d,), jnp.float32)
        p["cross"] = L.init_attn(ks[2], cfg, cross=True)
    p["ln2"] = jnp.ones((d,), jnp.float32)
    if cfg.family == "moe":
        p["moe"] = L.init_moe(ks[3], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg)
    return p


def _init_enc_layer(key, cfg: ArchConfig) -> Dict[str, Array]:
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": L.init_attn(ks[0], cfg),
        "ln2": jnp.ones((d,), jnp.float32),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> PyTree:
    k_emb, k_layers, k_enc, k_head = jax.random.split(key, 4)
    vp, d = cfg.padded_vocab, cfg.d_model
    params: Dict[str, Any] = {
        "embed": jax.random.normal(k_emb, (vp, d), jnp.float32) * 0.02,
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    lkeys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _init_dec_layer(k, cfg))(lkeys)
    if cfg.is_encdec:
        ekeys = jax.random.split(k_enc, cfg.n_enc_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_enc_layer(k, cfg))(ekeys)
        params["enc_norm"] = jnp.ones((d,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (d, vp), jnp.float32)
            / math.sqrt(d))
    return params


# ---------------------------------------------------------------------------
# Blocks (sequence / training / prefill form)
# ---------------------------------------------------------------------------

def _attention_mixer(cfg: ArchConfig, p: Dict[str, Array], x: Array,
                     positions: Array, kv_src: Optional[Array] = None,
                     causal: bool = True, window: int = 0,
                     return_kv: bool = False):
    dt = x.dtype
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = kv_src if kv_src is not None else x
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, dh)
    k = (src @ p["wk"].astype(dt)).reshape(b, src.shape[1], kv, dh)
    v = (src @ p["wv"].astype(dt)).reshape(b, src.shape[1], kv, dh)
    if kv_src is None:  # self-attention: rope both
        q = L.apply_rope(q, positions, cfg)
        k = L.apply_rope(k, positions, cfg)
    q = sh.constrain_heads(q)
    k = sh.constrain_heads(k)
    sk = k.shape[1]
    if max(s, sk) > ATTN_CHUNK_THRESHOLD:
        out = L.chunked_attention(q, k, v, causal=causal, window=window,
                                  chunk=ATTN_CHUNK)
    else:
        out = L.full_attention(q, k, v, causal=causal, window=window)
    out = sh.constrain_heads(out)
    y = out.reshape(b, s, h * dh) @ p["wo"].astype(dt)
    if return_kv:
        return y, (k, v)
    return y


def _dec_block(cfg: ArchConfig, p: Dict[str, Array], x: Array,
               positions: Array, enc_out: Optional[Array],
               collect_kv: bool):
    """One decoder block. Returns (x, aux) where aux carries KV for
    prefill-cache construction (zeros-free pytree when not collecting)."""
    eps = cfg.norm_eps
    aux = {}
    hin = L.rms_norm(x, p["ln1"], eps)
    if cfg.family == "ssm":
        if collect_kv:
            y, states = M.mamba_forward(hin, p["mamba"], cfg,
                                        return_state=True)
            aux.update(states)
        else:
            y = M.mamba_forward(hin, p["mamba"], cfg)
        x = x + y
        x = sh.constrain_hidden(x)
        return x, aux
    if cfg.family == "hybrid":
        a_out, kvp = _attention_mixer(cfg, p["attn"], hin, positions,
                                      causal=True, window=cfg.window,
                                      return_kv=True)
        if collect_kv:
            m_out, states = M.mamba_forward(hin, p["mamba"], cfg,
                                            return_state=True)
            aux.update(states)
        else:
            m_out = M.mamba_forward(hin, p["mamba"], cfg)
        mixed = 0.5 * (L.rms_norm(a_out, p["ln_attn_out"], eps)
                       + L.rms_norm(m_out, p["ln_mamba_out"], eps))
        x = x + mixed
        if collect_kv:
            aux["k"], aux["v"] = kvp
    else:
        a_out, kvp = _attention_mixer(cfg, p["attn"], hin, positions,
                                      causal=True, window=cfg.window,
                                      return_kv=True)
        x = x + a_out
        if collect_kv:
            aux["k"], aux["v"] = kvp
    if cfg.is_encdec:
        hc = L.rms_norm(x, p["ln_cross"], eps)
        x = x + _attention_mixer(cfg, p["cross"], hc, positions,
                                 kv_src=enc_out, causal=False)
    h2 = L.rms_norm(x, p["ln2"], eps)
    if cfg.family == "moe":
        x = x + L.moe(h2, p["moe"], cfg)
    else:
        x = x + L.mlp(h2, p["mlp"], cfg)
    x = sh.constrain_hidden(x)
    return x, aux


def _enc_block(cfg: ArchConfig, p: Dict[str, Array], x: Array,
               positions: Array) -> Array:
    eps = cfg.norm_eps
    hin = L.rms_norm(x, p["ln1"], eps)
    x = x + _attention_mixer(cfg, p["attn"], hin, positions, causal=False)
    h2 = L.rms_norm(x, p["ln2"], eps)
    x = x + L.mlp(h2, p["mlp"], cfg)
    return sh.constrain_hidden(x)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def encode(cfg: ArchConfig, params: PyTree, enc_embeds: Array) -> Array:
    """Encoder stack over stubbed frame embeddings (B, F, D)."""
    x = enc_embeds.astype(L.cdtype(cfg))
    positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        y = jax.checkpoint(
            lambda c, q: _enc_block(cfg, q, c, positions))(carry, lp)
        return y, None

    x, _ = SU.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(cfg: ArchConfig, params: PyTree,
            tokens: Optional[Array] = None,
            frames: Optional[Array] = None,
            enc_embeds: Optional[Array] = None,
            collect_kv: bool = False):
    """Sequence forward. Returns (hidden, enc_out, kv_stack).

    hidden: (B, S, D) pre-head normalised states.
    kv_stack: (L, B, S, KV, dh) pair when collect_kv (prefill path).
    """
    dt = L.cdtype(cfg)
    if frames is not None:
        x = frames.astype(dt)
    else:
        x = params["embed"].astype(dt)[tokens]
    x = sh.constrain_hidden(x)
    positions = jnp.arange(x.shape[1])
    enc_out = None
    if cfg.is_encdec:
        assert enc_embeds is not None
        enc_out = encode(cfg, params, enc_embeds)

    def body(carry, lp):
        y, aux = jax.checkpoint(
            lambda c, q: _dec_block(cfg, q, c, positions, enc_out,
                                    collect_kv),
            static_argnums=())(carry, lp)
        return y, aux

    x, kv_stack = SU.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, enc_out, kv_stack


def logits_head(cfg: ArchConfig, params: PyTree, hidden: Array) -> Array:
    dt = hidden.dtype
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return hidden @ head.astype(dt)


def lm_loss(cfg: ArchConfig, params: PyTree, hidden: Array,
            labels: Array) -> Array:
    """Chunked cross-entropy: never materialises (B, S, V) logits.

    Scans sequence chunks; per-chunk logits are (B, LOSS_CHUNK, Vp) and
    padded-vocab columns are masked out.
    """
    b, s, d = hidden.shape
    vp, v = cfg.padded_vocab, cfg.vocab_size
    chunk = min(LOSS_CHUNK, s)
    n_chunks = s // chunk
    assert s % chunk == 0, (s, chunk)
    hc = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])

    def body(acc, xs):
        hk, lk = xs
        logits = (hk @ head.astype(hk.dtype)).astype(jnp.float32)
        logits = sh.constrain_logits(logits)
        if vp > v:
            neg = jnp.full((vp - v,), -1e30, jnp.float32)
            logits = logits + jnp.concatenate(
                [jnp.zeros((v,), jnp.float32), neg])
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lk[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = SU.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (b * s)


# ---------------------------------------------------------------------------
# KV / SSM caches
# ---------------------------------------------------------------------------

def cache_len(cfg: ArchConfig, max_seq: int) -> int:
    """Ring-buffer length: window-bounded for SWA archs."""
    if cfg.window > 0:
        return min(max_seq, cfg.window)
    return max_seq


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               enc_frames: int = 0) -> PyTree:
    dt = L.cdtype(cfg)
    ln = cfg.n_layers
    cache: Dict[str, Any] = {}
    w = cache_len(cfg, max_seq)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.family != "ssm":
        cache["k"] = jnp.zeros((ln, batch, w, kv, dh), dt)
        cache["v"] = jnp.zeros((ln, batch, w, kv, dh), dt)
        cache["positions"] = jnp.full((batch, w), -1, jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        di, n, cw = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        cache["ssm"] = jnp.zeros((ln, batch, di, n), jnp.float32)
        cache["conv"] = jnp.zeros((ln, batch, cw - 1, di), dt)
    if cfg.is_encdec:
        cache["enc_out"] = jnp.zeros((batch, enc_frames, cfg.d_model), dt)
    return cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _dec_block_step(cfg: ArchConfig, p, x: Array, layer_cache, positions,
                    cache_positions, enc_out):
    """Single-token decoder block. x: (B, D)."""
    eps = cfg.norm_eps
    dt = x.dtype
    b, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    new_cache = {}
    hin = L.rms_norm(x, p["ln1"], eps)

    def attn_step(pa, xin, kc, vc):
        q = (xin @ pa["wq"].astype(dt)).reshape(b, 1, h, dh)
        k1 = (xin @ pa["wk"].astype(dt)).reshape(b, 1, kv, dh)
        v1 = (xin @ pa["wv"].astype(dt)).reshape(b, 1, kv, dh)
        q = L.apply_rope(q, positions[:, None], cfg)
        k1 = L.apply_rope(k1, positions[:, None], cfg)
        w = kc.shape[1]
        slot = positions % w
        # One-hot blend instead of dynamic scatter: elementwise, so the
        # update stays LOCAL under a sequence-sharded cache (a scatter
        # on the sharded W axis makes GSPMD all-gather the whole cache
        # every layer — 21.5 GB/step on glm4 decode; EXPERIMENTS.md §Perf).
        hit = jnp.arange(w)[None, :] == slot[:, None]          # (B, W)
        kc2 = jnp.where(hit[..., None, None], k1, kc)
        vc2 = jnp.where(hit[..., None, None], v1, vc)
        cpos = jnp.where(hit, positions[:, None], cache_positions)
        out = L.decode_attention(q, kc2, vc2, cpos, positions,
                                 window=cfg.window)
        y = out.reshape(b, h * dh) @ pa["wo"].astype(dt)
        return y, kc2, vc2, cpos

    cpos_out = cache_positions
    if cfg.family == "ssm":
        y, ms = M.mamba_step(hin, {"ssm": layer_cache["ssm"],
                                   "conv": layer_cache["conv"]},
                             p["mamba"], cfg)
        new_cache.update(ms)
        return x + y, new_cache, cpos_out
    if cfg.family == "hybrid":
        a_out, k2, v2, cpos_out = attn_step(p["attn"], hin,
                                            layer_cache["k"],
                                            layer_cache["v"])
        m_out, ms = M.mamba_step(hin, {"ssm": layer_cache["ssm"],
                                       "conv": layer_cache["conv"]},
                                 p["mamba"], cfg)
        mixed = 0.5 * (L.rms_norm(a_out, p["ln_attn_out"], eps)
                       + L.rms_norm(m_out, p["ln_mamba_out"], eps))
        x = x + mixed
        new_cache.update({"k": k2, "v": v2, **ms})
    else:
        a_out, k2, v2, cpos_out = attn_step(p["attn"], hin,
                                            layer_cache["k"],
                                            layer_cache["v"])
        x = x + a_out
        new_cache.update({"k": k2, "v": v2})
    if cfg.is_encdec:
        hc = L.rms_norm(x, p["ln_cross"], eps)
        y = _attention_mixer(cfg, p["cross"], hc[:, None, :],
                             positions[:, None], kv_src=enc_out,
                             causal=False)
        x = x + y[:, 0]
    h2 = L.rms_norm(x, p["ln2"], eps)
    if cfg.family == "moe":
        x = x + L.moe(h2[:, None, :], p["moe"], cfg)[:, 0]
    else:
        x = x + L.mlp(h2, p["mlp"], cfg)
    return x, new_cache, cpos_out


def decode_step(cfg: ArchConfig, params: PyTree, cache: PyTree,
                tokens: Array, positions: Array
                ) -> Tuple[Array, PyTree]:
    """One decode step. tokens: (B, 1); positions: (B,). Returns
    (logits (B, Vp), new_cache)."""
    dt = L.cdtype(cfg)
    x = params["embed"].astype(dt)[tokens[:, 0]]               # (B, D)
    x = sh.constraint(x, sh.batch_axes(), None)
    enc_out = cache.get("enc_out")
    has_attn = cfg.family != "ssm"
    has_ssm = cfg.family in ("ssm", "hybrid")
    cpos = cache.get("positions")

    def body(carry, xs):
        xc, cp = carry
        lp, lc = xs
        y, nc, cp2 = _dec_block_step(cfg, lp, xc, lc, positions, cp,
                                     enc_out)
        return (y, cp2), nc

    layer_caches = {}
    if has_attn:
        layer_caches["k"] = cache["k"]
        layer_caches["v"] = cache["v"]
    if has_ssm:
        layer_caches["ssm"] = cache["ssm"]
        layer_caches["conv"] = cache["conv"]
    (x, cpos_new), new_layer_caches = SU.scan(
        body, (x, cpos if cpos is not None else jnp.zeros((0,), jnp.int32)),
        (params["layers"], layer_caches))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_head(cfg, params, x)
    new_cache = dict(cache)
    new_cache.update(new_layer_caches)
    if cpos is not None:
        new_cache["positions"] = cpos_new
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params: PyTree,
            tokens: Optional[Array] = None,
            frames: Optional[Array] = None,
            enc_embeds: Optional[Array] = None,
            max_seq: Optional[int] = None
            ) -> Tuple[Array, PyTree]:
    """Run the full prompt; return (last-token logits, decode cache).

    ``max_seq`` sizes the returned cache (>= prompt length + planned new
    tokens); defaults to the prompt length (the dry-run's decode-at-S
    semantics)."""
    hidden, enc_out, cache_stack = forward(
        cfg, params, tokens=tokens, frames=frames, enc_embeds=enc_embeds,
        collect_kv=True)
    b, s, _ = hidden.shape
    w = cache_len(cfg, max_seq or s)
    cache: Dict[str, Any] = {}
    if cfg.family != "ssm":
        k, v = cache_stack["k"], cache_stack["v"]  # (L, B, S, KV, dh)
        keep = min(s, w)
        # absolute position p lives in slot p % w (ring when w < s)
        pos = jnp.arange(s - keep, s)
        slots = pos % w
        kr = jnp.zeros(k.shape[:2] + (w,) + k.shape[3:], k.dtype)
        vr = jnp.zeros_like(kr)
        kr = kr.at[:, :, slots].set(k[:, :, s - keep:])
        vr = vr.at[:, :, slots].set(v[:, :, s - keep:])
        cpos = jnp.full((b, w), -1, jnp.int32
                        ).at[:, slots].set(pos[None, :])
        cache["k"], cache["v"], cache["positions"] = kr, vr, cpos
    if cfg.family in ("ssm", "hybrid"):
        cache["ssm"] = cache_stack["ssm"]          # (L, B, Di, N)
        cache["conv"] = cache_stack["conv"]        # (L, B, CW-1, Di)
    if cfg.is_encdec:
        cache["enc_out"] = enc_out
    logits = logits_head(cfg, params, hidden[:, -1])
    return logits, cache
