"""Scan-vs-unroll switch shared by all sequence/layer loops.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE
irrespective of trip count. The dry-run therefore measures per-iteration
costs on small UNROLLED configs and re-multiplies by trip counts
(launch/dryrun.py). Production path always uses lax.scan (bounded HLO,
bounded memory); ``unrolled()`` flips every loop in the model to a
Python loop for cost measurement only.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

UNROLL = False


@contextlib.contextmanager
def unrolled():
    global UNROLL
    prev = UNROLL
    UNROLL = True
    try:
        yield
    finally:
        UNROLL = prev


def scan(body, carry, xs, length=None):
    """lax.scan, or a Python loop under ``unrolled()``."""
    if not UNROLL:
        return jax.lax.scan(body, carry, xs, length=length)
    n = (jax.tree_util.tree_leaves(xs)[0].shape[0]
         if xs is not None else length)
    ys = []
    for i in range(n):
        sl = jax.tree_util.tree_map(lambda a: a[i], xs) \
            if xs is not None else None
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and jax.tree_util.tree_leaves(ys[0]):
        stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = ys[0] if ys else None
    return carry, stacked
