"""Quantized, fanin-prunable MLP — the paper's evaluation model family.

JSC-S/M/L (LogicNets architectures) are instances of this model:
linear -> batch-norm -> quantized activation per layer, trained with QAT
(per-layer activation selection) + FCP, then compiled to fixed-function
logic via ``repro.core``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.core.fcp import GradualFCP, topk_row_mask
from repro.core.quant import ActQuantSpec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    name: str
    n_inputs: int
    features: Tuple[int, ...]        # hidden + output widths
    fanins: Tuple[int, ...]          # per-layer fanin budget (post-FCP)
    act_bits: Tuple[int, ...]        # per-layer *output* activation bits
    in_bits: int = 1                 # input quantization bits
    n_classes: int = 5
    alpha: float = 2.0               # quantizer range
    bn: bool = True

    @property
    def n_layers(self) -> int:
        return len(self.features)

    def in_spec(self) -> ActQuantSpec:
        # JSC features are standardised (both signs) -> signed branch
        return Q.select_activation(False, self.in_bits)

    def layer_specs(self) -> List[ActQuantSpec]:
        """Per-layer output activation specs (paper's selection rule).

        Hidden layers follow BN, whose outputs take both signs -> signed;
        the final scoring layer uses a wider signed code so argmax has
        resolution.
        """
        return [Q.select_activation(False, b) for b in self.act_bits]


def init_mlp_params(cfg: MLPConfig, key: jax.Array) -> Dict:
    layers = []
    d_in = cfg.n_inputs
    keys = jax.random.split(key, cfg.n_layers)
    for i, d_out in enumerate(cfg.features):
        k1, k2 = jax.random.split(keys[i])
        lp = {
            "w": jax.random.normal(k1, (d_out, d_in), jnp.float32)
            * (1.0 / math.sqrt(d_in)),
            "b": jnp.zeros((d_out,), jnp.float32),
            # learnable quantizer range (PACT-style, also for the signed
            # branch): trained jointly, folded into the truth tables.
            "alpha": jnp.asarray(cfg.alpha, jnp.float32),
        }
        if cfg.bn:
            lp.update({
                "bn_gamma": jnp.ones((d_out,), jnp.float32),
                "bn_beta": jnp.zeros((d_out,), jnp.float32),
            })
        layers.append(lp)
        d_in = d_out
    return {"layers": layers}


def init_bn_state(cfg: MLPConfig) -> Dict:
    return {
        "mean": [jnp.zeros((f,), jnp.float32) for f in cfg.features],
        "var": [jnp.ones((f,), jnp.float32) for f in cfg.features],
    }


def init_masks(cfg: MLPConfig) -> List[Array]:
    masks = []
    d_in = cfg.n_inputs
    for d_out in cfg.features:
        masks.append(jnp.ones((d_out, d_in), bool))
        d_in = d_out
    return masks


def mlp_forward(cfg: MLPConfig, params: Dict, masks: Sequence[Array],
                bn_state: Dict, x: Array, train: bool = False,
                momentum: float = 0.1):
    """Quantized forward. Returns (scores, new_bn_state).

    scores: decoded real values of the last layer (pre-argmax)."""
    specs = cfg.layer_specs()
    in_spec = cfg.in_spec()
    h = Q.apply_act_quant(in_spec, x, jnp.asarray(cfg.alpha, jnp.float32))
    new_mean, new_var = [], []
    for i, lp in enumerate(params["layers"]):
        w = jnp.where(masks[i], lp["w"], 0.0)
        y = h @ w.T + lp["b"]
        if cfg.bn:
            if train:
                mu = jnp.mean(y, axis=0)
                var = jnp.var(y, axis=0)
                new_mean.append((1 - momentum) * bn_state["mean"][i]
                                + momentum * mu)
                new_var.append((1 - momentum) * bn_state["var"][i]
                               + momentum * var)
            else:
                mu, var = bn_state["mean"][i], bn_state["var"][i]
                new_mean.append(mu)
                new_var.append(var)
            y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
            y = y * lp["bn_gamma"] + lp["bn_beta"]
        a_i = layer_alpha(cfg, lp)
        h = Q.apply_act_quant(specs[i], y, a_i)
    return h, {"mean": new_mean, "var": new_var}


def layer_alpha(cfg: MLPConfig, lp: Dict) -> Array:
    """Learnable positive quantizer range (fixed cfg.alpha fallback)."""
    if "alpha" in lp:
        return jnp.abs(lp["alpha"]) + 1e-3
    return jnp.asarray(cfg.alpha, jnp.float32)


def mlp_loss(cfg: MLPConfig, params, masks, bn_state, x, labels,
             train: bool = True):
    scores, new_bn = mlp_forward(cfg, params, masks, bn_state, x, train)
    logits = scores[:, : cfg.n_classes]
    logp = jax.nn.log_softmax(logits / 0.25, axis=-1)  # temp sharpens quantized scores
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
    return loss, new_bn


def update_masks_gradual(cfg: MLPConfig, params, step: int,
                         schedule: GradualFCP) -> List[Array]:
    """Recompute FCP masks along the gradual schedule (host-side)."""
    masks = []
    for i, lp in enumerate(params["layers"]):
        fanin_target = cfg.fanins[i]
        sched = dataclasses.replace(schedule, target_fanin=fanin_target)
        masks.append(sched.update_mask(lp["w"], step))
    return masks


def final_masks(cfg: MLPConfig, params) -> List[Array]:
    return [topk_row_mask(lp["w"], cfg.fanins[i])
            for i, lp in enumerate(params["layers"])]


def to_logic(cfg: MLPConfig, params, masks, bn_state):
    """Compile the trained MLP to a LogicNetwork (core flow end-to-end)."""
    from repro.core.logic_infer import compile_mlp_to_logic

    layers = []
    for i, lp in enumerate(params["layers"]):
        d = {"w": lp["w"], "b": lp["b"]}
        if cfg.bn:
            d.update({
                "bn_gamma": lp["bn_gamma"], "bn_beta": lp["bn_beta"],
                "bn_mean": bn_state["mean"][i], "bn_var": bn_state["var"][i],
            })
        layers.append(d)
    return compile_mlp_to_logic(
        {"layers": layers},
        specs=cfg.layer_specs(),
        alphas=[float(layer_alpha(cfg, lp))
                for lp in params["layers"]],
        masks=[np.asarray(m) for m in masks],
        fanins=list(cfg.fanins),
        in_spec=cfg.in_spec(),
        in_alpha=cfg.alpha,
    )
