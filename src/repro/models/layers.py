"""Shared neural layers: norms, RoPE, attention (full / chunked / decode),
QAT+FCP-aware linears, dense MLP, and sort-based MoE.

Functional style: params are plain dict pytrees; every function takes
(cfg, params, inputs). Compute dtype policy: params live in
``cfg.param_dtype`` and are cast to ``cfg.compute_dtype`` at use.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import quant as Q
from repro.models import scan_utils as SU

Array = jax.Array


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ArchConfig) -> Array:
    dh = cfg.head_dim
    rot = int(dh * cfg.rotary_pct) // 2 * 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x: Array, positions: Array, cfg: ArchConfig) -> Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    inv = rope_freqs(cfg)
    rot = inv.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    if rot < dh:
        y = jnp.concatenate([y, x_pass], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _repeat_kv(k: Array, n_rep: int) -> Array:
    """(B, S, KV, dh) -> (B, S, KV*n_rep, dh)."""
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, dh)
                            ).reshape(b, s, kv * n_rep, dh)


def full_attention(q: Array, k: Array, v: Array, *, causal: bool,
                   window: int = 0, q_offset: int = 0) -> Array:
    """q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh). Materialises Sq x Sk."""
    h, kv = q.shape[2], k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int = 0, chunk: int = 1024) -> Array:
    """Online-softmax (flash-style) attention: lax.scan over KV chunks.

    Memory O(Sq * chunk) instead of O(Sq * Sk); used for 32k+ prefill and
    as the default sub-quadratic-memory attention at train time.
    """
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    k = _repeat_kv(k, h // kvh)
    v = _repeat_kv(v, h // kvh)
    if sk % chunk:
        pad = (-sk) % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    kc = k.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(dh)
    qpos = jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        kpos = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        mask = kpos[None, :] < sk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = SU.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Sq, H, dh)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_positions: Array, positions: Array,
                     *, window: int = 0) -> Array:
    """Single-token attention against a (possibly ring) KV cache.

    q: (B, 1, H, dh); caches: (B, W, KV, dh); cache_positions: (B, W)
    absolute position per slot (-1 = empty); positions: (B,) current pos.

    Sharding: when kv-heads divide the model axis, heads-TP decode;
    otherwise flash-decode — the cache stays SEQUENCE-sharded over
    'model', q is replicated (it is tiny), and GSPMD reduces the partial
    softmax stats. Without this, GSPMD re-shards the multi-GB cache onto
    the q-heads axis every layer (EXPERIMENTS.md §Perf, decode cell).
    """
    from repro.dist import shardings as sh
    b, _, h, dh = q.shape
    kvh = k_cache.shape[2]
    mesh = sh.active_mesh()
    if mesh is not None:
        msize = mesh.shape["model"]
        dp = sh._dp_for(mesh, b)
        if kvh % msize == 0:
            q = sh.constraint(q, dp, None, "model", None)
            k_cache = sh.constraint(k_cache, dp, None, "model", None)
            v_cache = sh.constraint(v_cache, dp, None, "model", None)
        elif k_cache.shape[1] % msize == 0:
            q = sh.constraint(q, dp, None, None, None)
            k_cache = sh.constraint(k_cache, dp, "model", None, None)
            v_cache = sh.constraint(v_cache, dp, "model", None, None)
    # grouped-GQA form: KV is NEVER repeated/materialised, so the cache's
    # sharding survives straight into the einsums.
    g = h // kvh
    q5 = q.reshape(b, 1, kvh, g, dh)
    scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bqkgd,bwkd->bkgqw", q5, k_cache
                        ).astype(jnp.float32) * scale       # (B,KV,G,1,W)
    valid = (cache_positions >= 0) & \
        (cache_positions <= positions[:, None])              # (B, W)
    if window > 0:
        valid &= cache_positions > (positions[:, None] - window)
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqw,bwkd->bqkgd", probs, v_cache)
    return out.reshape(b, 1, h, dh)


# ---------------------------------------------------------------------------
# QAT + FCP aware linear (the paper's technique inside LM blocks)
# ---------------------------------------------------------------------------

def quant_linear(x: Array, w: Array, cfg: ArchConfig,
                 mask: Optional[Array] = None,
                 alpha: Optional[Array] = None,
                 nonnegative: bool = False) -> Array:
    """x @ w with optional QAT (activations) + DoReFa (weights) + FCP mask.

    Implements the paper's per-layer activation selection: PACT when the
    input range is non-negative (e.g. after relu^2/silu-gated stacks),
    symmetric signed quantization otherwise.
    """
    if cfg.quant_bits > 0:
        a = alpha if alpha is not None else jnp.asarray(1.0, jnp.float32)
        spec = Q.select_activation(nonnegative, cfg.quant_bits)
        x = Q.apply_act_quant(spec, x, a.astype(x.dtype))
    if cfg.quant_weights > 0:
        w = Q.dorefa_weight(w.astype(jnp.float32), cfg.quant_weights)
    if mask is not None:
        w = w * mask.astype(w.dtype)
    return x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def mlp(x: Array, p: Dict[str, Array], cfg: ArchConfig) -> Array:
    dt = x.dtype
    mask1 = p.get("mask_w1")
    mask2 = p.get("mask_w2")
    alpha = p.get("pact_alpha")
    if cfg.act == "swiglu":
        g = quant_linear(x, p["w1"], cfg, mask1, alpha, nonnegative=False)
        u = x @ p["w3"].astype(dt)
        h = jax.nn.silu(g) * u
        nonneg = False  # silu-gated products take both signs
    elif cfg.act == "relu2":
        h = quant_linear(x, p["w1"], cfg, mask1, alpha, nonnegative=False)
        h = jnp.square(jax.nn.relu(h))
        nonneg = True   # squared ReLU is non-negative -> PACT branch
    else:  # gelu
        h = quant_linear(x, p["w1"], cfg, mask1, alpha, nonnegative=False)
        h = jax.nn.gelu(h)
        nonneg = False
    return quant_linear(h, p["w2"], cfg, mask2, alpha, nonnegative=nonneg)


# ---------------------------------------------------------------------------
# MoE (sort-based routing; no fake one-hot-einsum FLOPs)
# ---------------------------------------------------------------------------

def moe(x: Array, p: Dict[str, Array], cfg: ArchConfig) -> Array:
    """Top-k MoE with per-sequence capacity routing.

    Routing (argsort -> position-in-expert -> capacity clip) is computed
    per batch row (device-local under batch-sharded pjit); the token
    buffers and expert einsums run at full batch shape so sharding
    constraints can pin the EP layout: under OPTS['moe_ep'] the expert
    axis of both weights and token buffers shards over 'model' — tokens
    move (all-to-all), expert weights stay put. Expert FLOPs match the
    active-parameter model (tokens * top_k * capacity_factor).
    x: (B, S, D).
    """
    from repro.dist import shardings as sh

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = max(k, int(math.ceil(s * k * cfg.capacity_factor / e)))
    dt = x.dtype

    router_logits = (x.astype(jnp.float32)
                     @ p["router"].astype(jnp.float32))      # (B, S, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (B, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    mesh = sh.active_mesh()
    if sh.OPTS["moe_ep"] and mesh is not None \
            and b % (np_prod := _dp_size(mesh)) == 0 and np_prod > 1:
        return _moe_shard_map(x, p, cfg, top_e, top_p, cap, mesh)

    w1 = p["w1"].astype(dt)
    w2 = p["w2"].astype(dt)
    w3 = p["w3"].astype(dt) if "w3" in p else None

    def route_one(xrow, erow, prow):
        # xrow: (S, D); erow/prow: (S, k)
        a = s * k
        eflat = erow.reshape(a)
        pflat = prow.reshape(a)
        tok = jnp.repeat(jnp.arange(s), k)
        order = jnp.argsort(eflat, stable=True)
        es = eflat[order]
        counts = jnp.sum(jax.nn.one_hot(eflat, e, dtype=jnp.int32), axis=0)
        starts = jnp.cumsum(counts) - counts                  # (E,)
        pos = jnp.arange(a) - starts[es]                      # pos in expert
        keep = pos < cap
        slot = jnp.where(keep, es * cap + pos, e * cap)       # overflow slot
        # gather tokens into (E*cap, D) expert buffers
        xe = jnp.zeros((e * cap + 1, d), dt)
        xe = xe.at[slot].set(jnp.where(keep[:, None], xrow[tok[order]], 0))
        xe = xe[:-1].reshape(e, cap, d)
        # expert MLPs
        if cfg.act == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", xe, w1)
            u = jnp.einsum("ecd,edf->ecf", xe, w3)
            h = jax.nn.silu(g) * u
        elif cfg.act == "relu2":
            h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xe, w1)))
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, w1))
        ye = jnp.einsum("ecf,efd->ecd", h, w2)
        # scatter back with combine weights
        yflat = ye.reshape(e * cap, d)
        ya = jnp.where(keep[:, None], yflat[jnp.clip(slot, 0, e * cap - 1)], 0)
        ya = ya * pflat[order][:, None].astype(dt)
        out = jnp.zeros((s, d), dt).at[tok[order]].add(ya)
        return out

    return jax.vmap(route_one)(x, top_e, top_p)


def _dp_size(mesh) -> int:
    n = mesh.shape.get("data", 1)
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def _routing_indices(top_e: Array, s: int, k: int, e: int, cap: int):
    """Per-row capacity routing (vmapped integer math). -> order/slot/keep
    plus token + combine-weight gathers, all (B, S*k)."""
    a = s * k

    def route_row(erow):
        eflat = erow.reshape(a)
        order = jnp.argsort(eflat, stable=True)
        es = eflat[order]
        counts = jnp.sum(jax.nn.one_hot(eflat, e, dtype=jnp.int32), axis=0)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(a) - starts[es]
        keep = pos < cap
        slot = jnp.where(keep, es * cap + pos, e * cap)
        return order, slot, keep

    return jax.vmap(route_row)(top_e)


def _moe_shard_map(x: Array, p: Dict[str, Array], cfg: ArchConfig,
                   top_e: Array, top_p: Array, cap: int, mesh) -> Array:
    """MoE block under shard_map: explicit collectives where GSPMD's
    propagation around data-dependent dispatch goes pathological
    (EXPERIMENTS.md §Perf dbrx: every pjit variant either all-reduced
    (B,E,C,F) activations over 'data' or replicated expert compute).

    Layout: batch rows local to each dp shard; expert weights stored 2-D
    sharded (ZeRO) and all-gathered over 'data' in bf16 (cheap: the
    gathered copy is still d_ff-sharded over 'model'); each device
    computes its d_ff slice of every expert; ONE psum over 'model'
    returns token outputs. Routing/dispatch never leaves the device.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist import shardings as sh

    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    dp = sh._dp_for(mesh, b)
    act = cfg.act

    def block(xl, tel, tpl, w1s, w3s, w2s):
        # xl: (B_l, S, D); w1s/w3s: (E, D/dp, F/mp); w2s: (E, F/mp, D/dp)
        w1g = jax.lax.all_gather(w1s, "data", axis=1, tiled=True).astype(dt)
        w2g = jax.lax.all_gather(w2s, "data", axis=2, tiled=True).astype(dt)
        w3g = None
        if act == "swiglu":
            w3g = jax.lax.all_gather(w3s, "data", axis=1,
                                     tiled=True).astype(dt)

        bl = xl.shape[0]
        order, slot, keep = _routing_indices(tel, s, k, e, cap)
        bidx = jnp.arange(bl)[:, None]
        tok = jnp.broadcast_to(
            jnp.repeat(jnp.arange(s), k)[None], (bl, s * k))
        tok_o = jnp.take_along_axis(tok, order, axis=1)
        p_o = jnp.take_along_axis(tpl.reshape(bl, s * k), order, axis=1)

        vals = jnp.where(keep[..., None], xl[bidx, tok_o], 0)
        xe = jnp.zeros((bl, e * cap + 1, d), dt).at[bidx, slot].set(vals)
        xe = xe[:, :-1].reshape(bl, e, cap, d)

        g = jnp.einsum("becd,edf->becf", xe, w1g)
        if act == "swiglu":
            u = jnp.einsum("becd,edf->becf", xe, w3g)
            h = jax.nn.silu(g) * u
        elif act == "relu2":
            h = jnp.square(jax.nn.relu(g))
        else:
            h = jax.nn.gelu(g)
        ye = jnp.einsum("becf,efd->becd", h, w2g)   # partial over F slice

        # combine is LINEAR in ye, so combine the partials locally and
        # psum the (B,S,D) result — 5x fewer bytes on the wire than
        # psumming the (B,E,C,D) slot buffers (slots/token = top_k * cf).
        yflat = ye.reshape(bl, e * cap, d)
        ya = yflat[bidx, jnp.clip(slot, 0, e * cap - 1)]
        ya = jnp.where(keep[..., None], ya, 0) * p_o[..., None].astype(dt)
        out = jnp.zeros((bl, s, d), dt).at[bidx, tok_o].add(ya)
        return jax.lax.psum(out, "model")

    w3 = p.get("w3")
    fn = shard_map(
        block, mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, None, None), P(dp, None, None),
                  P(None, "data", "model"),
                  P(None, "data", "model") if w3 is not None else P(),
                  P(None, "model", "data")),
        out_specs=P(dp, None, None),
        check_rep=False)
    return fn(x, top_e, top_p, p["w1"],
              w3 if w3 is not None else jnp.zeros((), jnp.float32),
              p["w2"])


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def ninit(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale


def init_attn(key, cfg: ArchConfig, cross: bool = False) -> Dict[str, Array]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": ninit(ks[0], (d, h * dh)),
        "wk": ninit(ks[1], (d, kv * dh)),
        "wv": ninit(ks[2], (d, kv * dh)),
        "wo": ninit(ks[3], (h * dh, d)),
    }


def init_mlp(key, cfg: ArchConfig, with_fcp: bool = True) -> Dict[str, Array]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w1": ninit(ks[0], (d, f)), "w2": ninit(ks[1], (f, d))}
    if cfg.act == "swiglu":
        p["w3"] = ninit(ks[2], (d, f))
    if cfg.quant_bits > 0:
        p["pact_alpha"] = jnp.asarray(6.0, jnp.float32)
    if cfg.fcp_fanin > 0 and with_fcp:
        p["mask_w1"] = jnp.ones((d, f), jnp.float32)
        p["mask_w2"] = jnp.ones((f, d), jnp.float32)
    return p


def init_moe(key, cfg: ArchConfig) -> Dict[str, Array]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": ninit(ks[0], (d, e)),
        "w1": ninit(ks[1], (e, d, f), scale=1.0 / math.sqrt(d)),
        "w2": ninit(ks[2], (e, f, d), scale=1.0 / math.sqrt(f)),
    }
    if cfg.act == "swiglu":
        p["w3"] = ninit(ks[3], (e, d, f), scale=1.0 / math.sqrt(d))
    return p
