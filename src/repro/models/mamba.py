"""Mamba-1 selective SSM mixer (falcon-mamba-7b family; Hymba SSM heads).

Forward over a sequence uses jax.lax.associative_scan (parallel prefix)
on the diagonal linear recurrence  h_t = abar_t * h_{t-1} + bbar_t x_t;
decode is the O(1)-per-token state update, which is what makes the
long_500k shape feasible for SSM/hybrid architectures.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Array = jax.Array


def init_mamba(key, cfg: ArchConfig, d_model: int = 0) -> Dict[str, Array]:
    d = d_model or cfg.d_model
    di, n, r, cw = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.ssm_conv
    ks = jax.random.split(key, 6)

    def ninit(k, shape, scale):
        return jax.random.normal(k, shape, jnp.float32) * scale

    # S4D-real initialisation of A
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "in_proj": ninit(ks[0], (d, 2 * di), 1 / math.sqrt(d)),
        "conv_w": ninit(ks[1], (cw, di), 1 / math.sqrt(cw)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": ninit(ks[2], (di, r + 2 * n), 1 / math.sqrt(di)),
        "dt_proj_w": ninit(ks[3], (r, di), 1 / math.sqrt(r)),
        "dt_proj_b": jnp.log(jnp.expm1(  # softplus^-1 of dt ~ U(1e-3, 1e-1)
            jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": ninit(ks[4], (di, d), 1 / math.sqrt(di)),
    }


SCAN_CHUNK = 256


def _chunked_linear_scan(abar: Array, bx: Array,
                         chunk: int = SCAN_CHUNK) -> Array:
    """Cumulative h_t = abar_t * h_{t-1} + bx_t along axis 1.

    A flat associative_scan over S costs ~log2(S) elementwise passes over
    the (B,S,Di,N) tensors; chunking to ``chunk`` costs log2(chunk)
    passes + one sequential carry per chunk — e.g. 8 vs 15 passes at
    S=32k, a ~1.9x cut of the dominant SSM FLOPs (EXPERIMENTS.md §Perf,
    hymba/falcon compute term).
    """
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    b, s = abar.shape[0], abar.shape[1]
    if s <= chunk or s % chunk != 0:
        _, h = jax.lax.associative_scan(combine, (abar, bx), axis=1)
        return h

    n = s // chunk
    ac = abar.reshape((b, n, chunk) + abar.shape[2:]).transpose(
        1, 0, 2, 3, 4)
    bc = bx.reshape((b, n, chunk) + bx.shape[2:]).transpose(1, 0, 2, 3, 4)

    def per_chunk(carry, xs):
        a_i, b_i = xs                       # (B, chunk, Di, N)
        a_cum, b_cum = jax.lax.associative_scan(combine, (a_i, b_i), axis=1)
        h = a_cum * carry[:, None] + b_cum  # inject carry h0
        return h[:, -1], h

    h0 = jnp.zeros_like(abar[:, 0])
    _, hc = jax.lax.scan(per_chunk, h0, (ac, bc))
    return hc.transpose(1, 0, 2, 3, 4).reshape(abar.shape)


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over S. x: (B, S, Di); w: (CW, Di)."""
    cw = w.shape[0]
    acc = x * w[cw - 1]
    for t in range(1, cw):
        shifted = jnp.pad(x, ((0, 0), (t, 0), (0, 0)))[:, : x.shape[1]]
        acc = acc + shifted * w[cw - 1 - t]
    return acc + b


def _ssm_params(p: Dict[str, Array], xz: Array, cfg: ArchConfig):
    """Common projections. xz: (..., Di) post-conv activations."""
    n, r = cfg.ssm_state, cfg.dt_rank_
    dt = xz.dtype
    proj = xz @ p["x_proj"].astype(dt)                       # (..., r+2n)
    dt_r, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        dt_r @ p["dt_proj_w"].astype(dt)
        + p["dt_proj_b"].astype(dt))                          # (..., Di)
    return delta, b_ssm, c_ssm


def mamba_forward(x: Array, p: Dict[str, Array], cfg: ArchConfig,
                  return_state: bool = False):
    """x: (B, S, D) -> (B, S, D) [, final (ssm, conv) states]."""
    dt = x.dtype
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"].astype(dt)                          # (B, S, 2Di)
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs = _causal_conv(xs_raw, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    xs = jax.nn.silu(xs)

    delta, b_ssm, c_ssm = _ssm_params(p, xs, cfg)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))              # (Di, N)
    abar = jnp.exp(delta.astype(jnp.float32)[..., None] * a)  # (B,S,Di,N)
    bx = (delta[..., None] * b_ssm[..., None, :]
          * xs[..., None]).astype(jnp.float32)                # (B,S,Di,N)
    h = _chunked_linear_scan(abar, bx)
    y = jnp.einsum("bsdn,bsn->bsd", h.astype(dt), c_ssm)
    y = y + xs * p["D"].astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    if return_state:
        cw = cfg.ssm_conv
        conv_tail = xs_raw[:, -(cw - 1):]           # (B, CW-1, Di)
        return out, {"ssm": h[:, -1], "conv": conv_tail}
    return out


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> Dict[str, Array]:
    di, n, cw = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "ssm": jnp.zeros((batch, di, n), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, di), dtype),
    }


def mamba_step(x: Array, cache: Dict[str, Array], p: Dict[str, Array],
               cfg: ArchConfig) -> Tuple[Array, Dict[str, Array]]:
    """Single-token decode. x: (B, D) -> (B, D), updated cache."""
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    xs, z = jnp.split(xz, 2, axis=-1)                          # (B, Di)
    # conv ring: history (B, CW-1, Di)
    hist = cache["conv"]
    w = p["conv_w"].astype(dt)                                 # (CW, Di)
    acc = xs * w[-1]
    cw = w.shape[0]
    for t in range(1, cw):
        acc = acc + hist[:, cw - 1 - t] * w[cw - 1 - t]
    xs_c = jax.nn.silu(acc + p["conv_b"].astype(dt))
    new_hist = jnp.concatenate([hist[:, 1:], xs[:, None]], axis=1)

    delta, b_ssm, c_ssm = _ssm_params(p, xs_c, cfg)            # (B, Di), (B,N)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    abar = jnp.exp(delta.astype(jnp.float32)[..., None] * a)   # (B, Di, N)
    bx = (delta[..., None] * b_ssm[:, None, :] * xs_c[..., None]
          ).astype(jnp.float32)
    h = abar * cache["ssm"] + bx                                # (B, Di, N)
    y = jnp.einsum("bdn,bn->bd", h.astype(dt), c_ssm)
    y = y + xs_c * p["D"].astype(dt)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt)
    return out, {"ssm": h, "conv": new_hist}
