"""Multi-level LUT mapping + retiming cost model (NullaNet Tiny step 5).

The paper hands minimized SOPs to Xilinx Vivado for multi-level logic
minimization, technology mapping to 6-input LUTs, and retiming, then
reports LUTs / FFs / fmax on a VU9P. Vivado is not available offline, so
this module provides an *analytic mapping model* with the same outputs:

  * LUT count  — structural covering of the SOP network into 6-LUTs with
    support-aware collapsing (a function whose total support <= 6 is one
    LUT regardless of SOP size — that is what Vivado's mapper achieves).
  * logic depth — LUT levels on the critical path.
  * fmax      — 1 / (t_ff + depth * t_level); calibrated on VU9P-class
    numbers so that a depth-1 network hits ~2.08 GHz (the paper's JSC-S
    reports 2,079 MHz, i.e. single-level logic between FFs).
  * FFs       — retiming model: one pipeline register per layer output
    code bit (+ input register stage).

Absolute numbers are a model; the reproduction target is the *ratios*
between NullaNet Tiny and the LogicNets baseline (see DESIGN.md §7).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from .espresso import Cover, FREE
# the cost model (LUT width, timing, MapReport, tree/RAM LUT counts) is
# shared with synth.lutmap via core.lutcost — single definition site
from .lutcost import (LUT_K, T_FF_NS, T_LEVEL_NS,  # noqa: F401 (re-export)
                      MapReport, logicnets_lut_cost,
                      tree_lut_cost as _tree)


def map_cover(cover: Cover) -> MapReport:
    """Map one single-output SOP cover to 6-LUTs.

    Strategy mirroring a technology mapper:
      1. If the function's true support fits in one LUT -> 1 LUT, depth 1.
      2. Else: each cube is an AND tree over its literals; cubes that fit
         together (combined support <= 6) get packed into shared LUTs via
         first-fit-decreasing on support; the OR tree combines cube
         outputs, absorbing cube ANDs into OR LUTs when slack allows.
    """
    if cover.n_cubes == 0:
        return MapReport(0, 0, 0)  # constant
    support = cover.support()
    s = int(support.sum())
    if s == 0:
        return MapReport(0, 0, 0)  # constant
    if s <= LUT_K:
        return MapReport(1, 1, 0)
    # A real mapper never does worse than the RAM-style decomposition of
    # the raw s-input function (LUT6 + mux tree); take min(SOP tree, RAM).
    ram = logicnets_lut_cost(s, 1)

    # per-cube AND trees
    total_luts = 0
    and_depths = []
    cube_sizes = sorted(
        (int(np.sum(c != FREE)) for c in cover.cubes), reverse=True)

    # First-fit-decreasing packing: cubes with combined literal count <= 6
    # can share a LUT producing the OR of those small products.
    bins: List[int] = []   # remaining capacity of each shared (AND+OR) LUT
    or_inputs = 0
    for sz in cube_sizes:
        if sz >= LUT_K:
            luts, depth = _tree(sz)
            total_luts += luts
            and_depths.append(depth)
            or_inputs += 1
            continue
        placed = False
        for i, cap in enumerate(bins):
            if sz <= cap:
                bins[i] = cap - sz
                placed = True
                break
        if not placed:
            bins.append(LUT_K - sz)
            or_inputs += 1
    total_luts += len(bins)
    if bins:
        and_depths.append(1)

    or_luts, or_depth = _tree(or_inputs)
    total_luts += or_luts
    depth = (max(and_depths) if and_depths else 0) + or_depth
    sop = MapReport(total_luts, max(depth, 1), 0)
    if ram.luts < sop.luts:
        return ram
    return sop


def map_neuron(covers: Sequence[Cover]) -> MapReport:
    """A neuron with a b-bit output is b independent Boolean functions."""
    rep = MapReport(0, 0, 0)
    for c in covers:
        rep = rep + map_cover(c)
    return rep


def map_layer(neuron_reports: Sequence[MapReport], out_bits_total: int,
              pipeline: bool = True) -> MapReport:
    """Aggregate neurons of one layer; retiming inserts one FF stage per
    layer output bit (the paper's 'retiming' knob)."""
    rep = MapReport(0, 0, 0)
    for r in neuron_reports:
        rep = rep + r
    ffs = out_bits_total if pipeline else 0
    return MapReport(rep.luts, rep.depth, rep.ffs + ffs)


def map_network(layer_reports: Sequence[MapReport]) -> MapReport:
    """Whole-network totals. Depth model: with per-layer pipelining
    (retiming), fmax is set by the *deepest single layer*, and latency is
    n_layers cycles; report depth = max layer depth."""
    luts = sum(r.luts for r in layer_reports)
    ffs = sum(r.ffs for r in layer_reports)
    depth = max((r.depth for r in layer_reports), default=0)
    return MapReport(luts, depth, ffs)


def latency_ns(network: MapReport, n_stages: int) -> float:
    """Pipelined latency = stages / fmax."""
    return n_stages * 1e3 / network.fmax_mhz


# ---------------------------------------------------------------------------
# Structural (measured) mapping via repro.synth — analytic model fallback
# ---------------------------------------------------------------------------

def structural_report(net, effort: int = 1, pipeline: bool = True):
    """Measured per-layer 6-LUT mapping of a compiled ``LogicNetwork``.

    Runs the real synthesis pipeline (SOP -> AIG -> balance/rewrite ->
    FlowMap-style 6-LUT mapping, ``repro.synth``) on every layer and
    aggregates with the same retiming/FF model as the analytic path, so
    the two reports are directly comparable. Returns
    ``(MapReport, per_layer, "synth")``; on any synthesis failure falls
    back to the analytic estimate and tags it ``"analytic"``.
    """
    try:
        from repro.synth import layer_to_aig, synthesize

        per_layer = []
        for lt in net.layers:
            mapped = synthesize(layer_to_aig(lt), effort=effort, k=LUT_K)
            out_bits_total = lt.out_spec.code_bits * lt.n_neurons
            ffs = out_bits_total if pipeline else 0
            per_layer.append(mapped.report(ffs))
        return map_network(per_layer), per_layer, "synth"
    except Exception as e:
        # loudly: downstream reports tag the backend, but a silent switch
        # from measured to modeled numbers must not pass unnoticed
        import warnings
        warnings.warn(f"repro.synth structural mapping failed ({e!r}); "
                      "falling back to the analytic cost model")
        from .logic_infer import hardware_report
        rep, per_layer = hardware_report(net, minimize_logic=True)
        return rep, per_layer, "analytic"
