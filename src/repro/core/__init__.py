"""NullaNet Tiny core: QAT + FCP + truth tables + logic minimization.

Public API:
    quant      — STE quantizers (sign/binary/PACT/signed/DoReFa), per-layer
                 activation selection, BN folding.
    fcp        — fanin-constrained pruning (gradual + ADMM).
    truthtable — neuron -> truth-table enumeration.
    espresso   — two-level minimization (espresso-lite).
    lutmap     — 6-LUT mapping + fmax/FF cost model.
    netlist    — Verilog emission.
    logic_infer— JAX execution of compiled logic networks.
"""
from . import espresso, fcp, lutmap, quant, truthtable  # noqa: F401
from .logic_infer import (LogicNetwork, classify, compile_mlp_to_logic,  # noqa: F401
                          hardware_report, logic_layer_apply)
from .quant import ActQuantSpec, select_activation  # noqa: F401
