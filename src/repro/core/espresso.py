"""espresso-lite: two-level logic minimization (NullaNet Tiny step 4).

The paper uses ESPRESSO-II. We implement the same EXPAND / IRREDUNDANT
loop specialised to *completely-specified* single-output functions given
as dense on-set bitmaps over K <= ~16 variables — exactly what
truth-table extraction produces. (An optional don't-care set is honoured;
NullaNet-2018-style partial enumeration produces DCs, NullaNet Tiny's
full enumeration does not.)

Cube representation: int8 vector of length K with entries
  0 = negative literal, 1 = positive literal, 2 = free (don't-care).

For K <= 16 the dense bitmap (2^K bools) makes the two critical
predicates — "cube inside on+dc" and "rows covered by cube" — cheap,
vectorised numpy operations, so the minimizer is fast enough to run over
every neuron of the JSC networks inside the test suite.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

FREE = 2


@dataclasses.dataclass
class Cover:
    """A sum-of-products cover: cubes (C, K) int8 plus bookkeeping."""

    cubes: np.ndarray  # (C, K) int8 in {0, 1, FREE}
    n_vars: int

    @property
    def n_cubes(self) -> int:
        return int(self.cubes.shape[0])

    @property
    def n_literals(self) -> int:
        return int(np.sum(self.cubes != FREE))

    def support(self) -> np.ndarray:
        """Variables actually used by the cover."""
        if self.n_cubes == 0:
            return np.zeros(self.n_vars, bool)
        return np.any(self.cubes != FREE, axis=0)


def _rows_of_cube(cube: np.ndarray) -> np.ndarray:
    """Row indices (little-endian var 0 = bit 0) covered by a cube."""
    k = cube.shape[0]
    fixed = 0
    free_bits: List[int] = []
    for v in range(k):
        if cube[v] == 1:
            fixed |= 1 << v
        elif cube[v] == FREE:
            free_bits.append(v)
    rows = np.zeros(1 << len(free_bits), dtype=np.int64)
    for i, v in enumerate(free_bits):
        half = 1 << i
        rows[half: 2 * half] = rows[:half] + (1 << v)
    return rows + fixed


def cube_covers(cube: np.ndarray) -> np.ndarray:
    return _rows_of_cube(cube)


def _cube_inside(cube: np.ndarray, allowed: np.ndarray) -> bool:
    """True iff every row of the cube lies in the allowed (on+dc) set."""
    return bool(np.all(allowed[_rows_of_cube(cube)]))


def _expand_cube(cube: np.ndarray, allowed: np.ndarray,
                 order: Sequence[int]) -> np.ndarray:
    """EXPAND: greedily free literals while the cube stays inside on+dc."""
    cube = cube.copy()
    for v in order:
        if cube[v] == FREE:
            continue
        saved = cube[v]
        cube[v] = FREE
        if not _cube_inside(cube, allowed):
            cube[v] = saved
    return cube


def minimize(onset: np.ndarray, dc: Optional[np.ndarray] = None,
             n_vars: Optional[int] = None) -> Cover:
    """Two-level minimization of a dense on-set bitmap.

    onset: (2^K,) bool. dc: optional (2^K,) bool don't-care set.
    Returns an irredundant prime cover (greedy; espresso-quality, not
    guaranteed minimum — same contract as ESPRESSO-II).
    """
    onset = np.asarray(onset, bool)
    n_rows = onset.shape[0]
    if n_vars is None:
        n_vars = int(n_rows).bit_length() - 1
    assert 1 << n_vars == n_rows, "onset length must be 2^K"
    if dc is None:
        dc = np.zeros(n_rows, bool)
    allowed = onset | dc

    on_rows = np.nonzero(onset)[0]
    if len(on_rows) == 0:
        return Cover(np.zeros((0, n_vars), np.int8), n_vars)
    if np.all(allowed):
        return Cover(np.full((1, n_vars), FREE, np.int8), n_vars)

    # --- EXPAND: one prime per on-set minterm (dedup as we go) ---------
    # Variable order heuristic: free the variable whose column is most
    # "balanced" in the on-set last (it is most likely to be essential).
    col_ones = np.array([
        int(np.sum((on_rows >> v) & 1)) for v in range(n_vars)])
    balance = np.minimum(col_ones, len(on_rows) - col_ones)
    order = list(np.argsort(balance))  # least balanced freed first

    covered = np.zeros(n_rows, bool)
    primes: List[np.ndarray] = []
    seen = set()
    for r in on_rows:
        if covered[r]:
            continue
        cube = np.array([(r >> v) & 1 for v in range(n_vars)], np.int8)
        cube = _expand_cube(cube, allowed, order)
        key = cube.tobytes()
        if key not in seen:
            seen.add(key)
            primes.append(cube)
            covered[_rows_of_cube(cube)] = True

    # --- IRREDUNDANT: greedy minimum-ish cover of the on-set ------------
    prime_rows = [
        np.intersect1d(_rows_of_cube(c), on_rows, assume_unique=False)
        for c in primes]
    need = np.zeros(n_rows, bool)
    need[on_rows] = True
    chosen: List[int] = []
    remaining = int(need.sum())
    gains = [len(pr) for pr in prime_rows]
    alive = [True] * len(primes)
    while remaining > 0:
        best, best_gain = -1, 0
        for i, pr in enumerate(prime_rows):
            if not alive[i]:
                continue
            g = int(np.sum(need[pr]))
            gains[i] = g
            if g > best_gain:
                best, best_gain = i, g
        if best < 0:
            break  # should not happen for complete covers
        chosen.append(best)
        alive[best] = False
        need[prime_rows[best]] = False
        remaining = int(need.sum())

    cubes = np.stack([primes[i] for i in chosen]) if chosen else \
        np.zeros((0, n_vars), np.int8)
    return Cover(cubes, n_vars)


def evaluate(cover: Cover, n_rows: Optional[int] = None) -> np.ndarray:
    """Dense bitmap realised by a cover (for verification)."""
    n_rows = n_rows or (1 << cover.n_vars)
    out = np.zeros(n_rows, bool)
    for c in cover.cubes:
        out[_rows_of_cube(c)] = True
    return out


def verify(cover: Cover, onset: np.ndarray,
           dc: Optional[np.ndarray] = None) -> bool:
    """Cover must equal the on-set outside the DC set."""
    got = evaluate(cover, onset.shape[0])
    care = ~dc if dc is not None else np.ones_like(onset)
    return bool(np.all(got[care] == np.asarray(onset, bool)[care]))


def cover_to_sop_str(cover: Cover, var_names: Optional[Sequence[str]] = None
                     ) -> str:
    """Human/Verilog-readable SOP string, e.g. "(a&~b) | (c)"."""
    if cover.n_cubes == 0:
        return "1'b0"
    names = var_names or [f"x{v}" for v in range(cover.n_vars)]
    terms = []
    for c in cover.cubes:
        lits = []
        for v in range(cover.n_vars):
            if c[v] == 1:
                lits.append(names[v])
            elif c[v] == 0:
                lits.append("~" + names[v])
        terms.append("(" + " & ".join(lits) + ")" if lits else "1'b1")
    return " | ".join(terms)
