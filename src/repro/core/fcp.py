"""Fanin-constrained pruning (FCP) — NullaNet Tiny §FCP.

Caps the number of *distinct inputs* feeding each neuron at ``fanin`` so
that truth-table enumeration over 2^(fanin·bits) combinations is feasible.

Two schedules, per the paper:
  * gradual pruning (Zhu & Gupta 2018): fanin shrinks along a cubic
    schedule during training; at each update the per-row top-k |w| survive.
  * ADMM (Boyd et al.; Zhang et al. 2018): auxiliary variable Z projected
    onto the fanin-K set, dual U, quadratic penalty rho/2 ||W - Z + U||^2
    added to the loss; W converges to a fanin-K matrix.

Masks are row-structured: mask[j] selects <= K columns of weight row j.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def topk_row_mask(w: Array, fanin: int) -> Array:
    """Boolean mask keeping the ``fanin`` largest-|w| entries of each row.

    w: (out, in). Deterministic tie-break by column index (lower wins),
    which keeps the mask stable under recompilation.
    """
    out_dim, in_dim = w.shape
    k = min(fanin, in_dim)
    mag = jnp.abs(w)
    # stable tie-break: subtract a tiny index-based epsilon
    tie = jnp.arange(in_dim, dtype=w.dtype) * jnp.asarray(1e-12, w.dtype)
    score = mag - tie
    thresh = jax.lax.top_k(score, k)[0][:, -1:]
    mask = score >= thresh
    return mask


def project_fanin(w: Array, fanin: int) -> Array:
    """Euclidean projection of w onto {matrices with row fanin <= K}."""
    return jnp.where(topk_row_mask(w, fanin), w, 0.0)


# ---------------------------------------------------------------------------
# Gradual (Zhu–Gupta) schedule, adapted from sparsity to fanin
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradualFCP:
    """Cubic fanin schedule: fanin_t goes in_dim -> target over steps
    [begin, end], updated every ``freq`` steps."""

    target_fanin: int
    begin_step: int = 0
    end_step: int = 1000
    freq: int = 50

    def fanin_at(self, step: int, in_dim: int) -> Array:
        """Current fanin budget (traced-friendly: works on jnp scalars)."""
        step = jnp.asarray(step, jnp.float32)
        b, e = float(self.begin_step), float(self.end_step)
        frac = jnp.clip((step - b) / max(e - b, 1.0), 0.0, 1.0)
        # cubic decay of the *excess* fanin (Zhu–Gupta form)
        excess = (in_dim - self.target_fanin) * (1.0 - frac) ** 3
        return jnp.round(self.target_fanin + excess).astype(jnp.int32)

    def update_mask(self, w: Array, step: int) -> Array:
        """Recompute the mask for the current schedule point.

        Called outside jit every ``freq`` steps (mask is part of the train
        state); uses concrete python ints for top_k k.
        """
        in_dim = w.shape[1]
        fanin = int(self.fanin_at(step, in_dim))
        return topk_row_mask(w, fanin)


# ---------------------------------------------------------------------------
# ADMM schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdmmFCP:
    """ADMM fanin pruning.

    State per weight: (Z, U). Every ``dual_freq`` steps:
        Z <- project_fanin(W + U, K);  U <- U + W - Z
    Training loss gains  rho/2 * ||W - Z + U||^2  (see ``penalty``).
    After convergence call ``finalize`` to hard-project W.
    """

    target_fanin: int
    rho: float = 1e-3
    dual_freq: int = 100

    def init_state(self, w: Array) -> Tuple[Array, Array]:
        return project_fanin(w, self.target_fanin), jnp.zeros_like(w)

    def dual_update(self, w: Array, z: Array, u: Array) -> Tuple[Array, Array]:
        z_new = project_fanin(w + u, self.target_fanin)
        u_new = u + w - z_new
        return z_new, u_new

    def penalty(self, w: Array, z: Array, u: Array) -> Array:
        d = w - z + u
        return 0.5 * self.rho * jnp.sum(d * d)

    def finalize(self, w: Array) -> Tuple[Array, Array]:
        mask = topk_row_mask(w, self.target_fanin)
        return jnp.where(mask, w, 0.0), mask


# ---------------------------------------------------------------------------
# Introspection helpers
# ---------------------------------------------------------------------------

def row_fanins(mask_or_w: Array) -> Array:
    """Number of non-zero inputs per output neuron."""
    return jnp.sum(jnp.asarray(mask_or_w) != 0, axis=1).astype(jnp.int32)


def fanin_indices(mask: Array, fanin: int):
    """Dense (out, fanin) column-index matrix from a row mask.

    Rows with fewer than ``fanin`` survivors are padded by repeating the
    first surviving index (weight 0 there keeps semantics exact). Returns
    (idx, valid) as numpy-compatible jnp arrays; evaluated eagerly at
    conversion time (not inside jit).
    """
    import numpy as np

    m = np.asarray(mask)
    out_dim = m.shape[0]
    idx = np.zeros((out_dim, fanin), dtype=np.int32)
    valid = np.zeros((out_dim, fanin), dtype=bool)
    for j in range(out_dim):
        cols = np.nonzero(m[j])[0]
        if len(cols) == 0:
            cols = np.array([0])
        take = cols[:fanin]
        idx[j, : len(take)] = take
        valid[j, : len(take)] = True
        if len(take) < fanin:
            idx[j, len(take):] = take[0] if len(take) else 0
    return jnp.asarray(idx), jnp.asarray(valid)
