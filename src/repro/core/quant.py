"""Quantization-aware training primitives (NullaNet Tiny §QAT).

The paper's key QAT idea: *per-layer activation function selection* —
use a signed quantizer (``sign`` / bipolar / symmetric multi-bit) when a
layer's inputs take both signs, and PACT (parameterized clipping
activation, Choi et al. 2018) when inputs are non-negative.

All quantizers use straight-through estimators (STE) implemented with
``jax.custom_vjp`` or the stop-gradient trick so they are differentiable
under ``jax.grad`` and safe inside ``pjit``/``shard_map``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Straight-through rounding / sign
# ---------------------------------------------------------------------------

def ste_round(x: Array) -> Array:
    """round(x) in the forward pass, identity gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def ste_floor(x: Array) -> Array:
    return x + jax.lax.stop_gradient(jnp.floor(x) - x)


@jax.custom_vjp
def sign_ste(x: Array) -> Array:
    """Bipolar sign: {-1, +1}; clipped-identity STE (|x| <= 1 passes grad).

    sign(0) is mapped to +1 so every input has a defined binary code.
    """
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _sign_fwd(x):
    return sign_ste(x), x


def _sign_bwd(x, g):
    # Hard-tanh STE (Hubara et al., Binarized Neural Networks).
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


sign_ste.defvjp(_sign_fwd, _sign_bwd)


@jax.custom_vjp
def binary_ste(x: Array) -> Array:
    """Binary {0, 1} step with clipped-identity STE on [0, 1]."""
    return (x >= 0.5).astype(x.dtype)


def _bin_fwd(x):
    return binary_ste(x), x


def _bin_bwd(x, g):
    return (g * ((x >= 0.0) & (x <= 1.0)).astype(g.dtype),)


binary_ste.defvjp(_bin_fwd, _bin_bwd)


# ---------------------------------------------------------------------------
# PACT — parameterized clipping activation (for non-negative layers)
# ---------------------------------------------------------------------------

def pact(x: Array, alpha: Array, bits: int) -> Array:
    """PACT quantizer: y = clip(x, 0, alpha) quantized to ``bits`` levels.

    ``alpha`` is a learnable scalar (or per-channel vector). Gradient w.r.t.
    alpha flows through the clip boundary exactly as in the PACT paper:
    d y / d alpha = 1 where x >= alpha, else 0 (via the clip), plus the STE
    treats rounding as identity.
    """
    alpha = jnp.asarray(alpha, x.dtype)
    levels = (1 << bits) - 1
    y = jnp.clip(x, 0.0, alpha)  # grads: x in (0, alpha) -> x; x >= alpha -> alpha
    scale = levels / jnp.maximum(alpha, 1e-8)
    q = ste_round(y * scale) / scale
    return q


def pact_levels(alpha: float, bits: int) -> jnp.ndarray:
    """The discrete value set PACT can emit (used by truth-table enumeration)."""
    levels = (1 << bits) - 1
    return jnp.arange(levels + 1, dtype=jnp.float32) * (alpha / levels)


# ---------------------------------------------------------------------------
# Symmetric signed multi-bit quantizer (bipolar generalisation)
# ---------------------------------------------------------------------------

def signed_uniform(x: Array, alpha: Array, bits: int) -> Array:
    """Symmetric signed quantizer on [-alpha, alpha] with 2^bits - 1 levels.

    bits=1 degenerates to bipolar sign * alpha. Used for layers whose
    inputs take both signs (the paper's ``sign`` branch, generalised).
    """
    alpha = jnp.asarray(alpha, x.dtype)
    if bits == 1:
        return sign_ste(x) * alpha
    half = (1 << (bits - 1)) - 1  # e.g. bits=2 -> {-1,0,1}
    y = jnp.clip(x, -alpha, alpha)
    scale = half / jnp.maximum(alpha, 1e-8)
    return ste_round(y * scale) / scale


def signed_levels(alpha: float, bits: int) -> jnp.ndarray:
    if bits == 1:  # bipolar
        return jnp.array([-alpha, alpha], dtype=jnp.float32)
    half = (1 << (bits - 1)) - 1
    return jnp.arange(-half, half + 1, dtype=jnp.float32) * (alpha / half)


# ---------------------------------------------------------------------------
# DoReFa weight quantizer
# ---------------------------------------------------------------------------

def dorefa_weight(w: Array, bits: int) -> Array:
    """DoReFa-Net weight quantization (Zhou et al. 2016).

    bits=1: sign(w) * E[|w|] (XNOR-Net-style scaling).
    bits>1: tanh-normalised uniform quantization to [-1, 1].
    """
    if bits >= 32:
        return w
    if bits == 1:
        scale = jnp.mean(jnp.abs(w))
        return sign_ste(w) * jax.lax.stop_gradient(scale)
    t = jnp.tanh(w)
    t = t / jnp.maximum(jnp.max(jnp.abs(t)), 1e-8)  # [-1, 1]
    u = (t + 1.0) * 0.5
    levels = (1 << bits) - 1
    q = ste_round(u * levels) / levels
    return 2.0 * q - 1.0


# ---------------------------------------------------------------------------
# Activation-function selection (the paper's per-layer rule)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ActQuantSpec:
    """Per-layer activation quantizer choice.

    kind: 'sign' (bipolar), 'binary' ({0,1}), 'pact' (non-negative
          multi-bit), 'signed' (symmetric multi-bit), 'none'.
    bits: output bit-width (1 for sign/binary).
    """

    kind: str = "sign"
    bits: int = 1

    @property
    def n_levels(self) -> int:
        if self.kind == "none":
            raise ValueError("unquantized activation has no level set")
        if self.kind in ("sign", "binary"):
            return 2
        if self.kind == "pact":
            return 1 << self.bits
        if self.kind == "signed":
            if self.bits == 1:  # degenerates to bipolar sign
                return 2
            return 2 * ((1 << (self.bits - 1)) - 1) + 1
        raise ValueError(self.kind)

    @property
    def code_bits(self) -> int:
        """Bits needed to index the level set (for truth-table packing)."""
        n = self.n_levels
        return max(1, (n - 1).bit_length())

    def levels(self, alpha: float) -> jnp.ndarray:
        if self.kind == "sign":
            return jnp.array([-alpha, alpha], dtype=jnp.float32)
        if self.kind == "binary":
            return jnp.array([0.0, alpha], dtype=jnp.float32)
        if self.kind == "pact":
            return pact_levels(alpha, self.bits)
        if self.kind == "signed":
            return signed_levels(alpha, self.bits)
        raise ValueError(self.kind)


def select_activation(inputs_nonnegative: bool, bits: int = 1) -> ActQuantSpec:
    """The paper's per-layer selection rule: PACT for non-negative ranges,
    sign/signed for ranges spanning both signs."""
    if inputs_nonnegative:
        return ActQuantSpec("pact", max(bits, 1)) if bits > 1 else ActQuantSpec("binary", 1)
    return ActQuantSpec("signed", bits) if bits > 1 else ActQuantSpec("sign", 1)


def apply_act_quant(spec: ActQuantSpec, x: Array, alpha: Array) -> Array:
    if spec.kind == "none":
        return x
    if spec.kind == "sign":
        return sign_ste(x) * jnp.asarray(alpha, x.dtype)
    if spec.kind == "binary":
        return binary_ste(x) * jnp.asarray(alpha, x.dtype)
    if spec.kind == "pact":
        return pact(x, alpha, spec.bits)
    if spec.kind == "signed":
        return signed_uniform(x, alpha, spec.bits)
    raise ValueError(spec.kind)


def encode_levels(spec: ActQuantSpec, x: Array, alpha: Array) -> Array:
    """Map quantized activation values -> integer level codes [0, n_levels).

    Used when feeding a logic (truth-table) layer: logic layers consume
    codes, not real values.
    """
    alpha = jnp.asarray(alpha, jnp.float32)
    xf = x.astype(jnp.float32)
    if spec.kind == "sign":
        return (xf > 0).astype(jnp.int32)
    if spec.kind == "binary":
        return (xf > alpha * 0.5).astype(jnp.int32)
    if spec.kind == "pact":
        levels = (1 << spec.bits) - 1
        return jnp.clip(jnp.round(xf * levels / alpha), 0, levels).astype(jnp.int32)
    if spec.kind == "signed":
        if spec.bits == 1:  # bipolar
            return (xf > 0).astype(jnp.int32)
        half = (1 << (spec.bits - 1)) - 1
        return jnp.clip(jnp.round(xf * half / alpha) + half, 0, 2 * half).astype(jnp.int32)
    raise ValueError(spec.kind)


def decode_levels(spec: ActQuantSpec, codes: Array, alpha: float) -> Array:
    """Integer level codes -> real activation values."""
    lv = spec.levels(alpha)
    return lv[codes]


# ---------------------------------------------------------------------------
# Folded batch-norm (inference view used during truth-table extraction)
# ---------------------------------------------------------------------------

def fold_bn(w: Array, b: Array, gamma: Array, beta: Array, mean: Array,
            var: Array, eps: float = 1e-5):
    """Fold BN(gamma,beta,mean,var) following y = xW^T + b into (w', b').

    Returns weights/bias such that BN(xW^T + b) == x w'^T + b'.
    w: (out, in). This is the 'BN disappears into the Boolean function'
    step of the paper.
    """
    inv = gamma / jnp.sqrt(var + eps)
    w2 = w * inv[:, None]
    b2 = (b - mean) * inv + beta
    return w2, b2
