"""Shared 6-LUT cost model — the single source of truth for LUT width,
timing constants, and the cost/report datatypes used by *both* mappers.

``core.lutmap`` (the analytic Vivado-style estimate) and
``synth.lutmap`` (the measured FlowMap-style cover) must report through
the same ``MapReport`` with the same ``LUT_K``/timing constants, or
their depth/area/fmax numbers silently drift apart. Everything they
share lives here; ``repro.check``'s duplicate-definition lint keeps a
second copy from ever reappearing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

LUT_K = 6                # Xilinx UltraScale+ native LUT width
T_LEVEL_NS = 0.25        # per-LUT-level logic+routing delay (VU9P-class)
T_FF_NS = 0.231          # clk->q + setup;  depth1 -> 1/(0.481ns) = 2.079 GHz


@dataclasses.dataclass
class MapReport:
    luts: int
    depth: int           # LUT levels
    ffs: int

    @property
    def fmax_mhz(self) -> float:
        if self.depth <= 0:
            return 1e3 / T_FF_NS
        return 1e3 / (T_FF_NS + self.depth * T_LEVEL_NS)

    def __add__(self, other: "MapReport") -> "MapReport":
        return MapReport(self.luts + other.luts,
                         max(self.depth, other.depth),
                         self.ffs + other.ffs)


def tree_lut_cost(n: int, k: int = LUT_K) -> Tuple[int, int]:
    """(luts, depth) of a balanced k-ary tree combining n signals with an
    associative gate. n <= 1 is free."""
    if n <= 1:
        return 0, 0
    luts, depth = 0, 0
    while n > 1:
        groups = math.ceil(n / k)
        luts += groups
        depth += 1
        n = groups
    return luts, depth


def logicnets_lut_cost(fanin_bits: int, out_bits: int) -> MapReport:
    """LogicNets maps each neuron's *entire* (fanin_bits -> out_bits) truth
    table to a LUT cascade without two-level minimization. Standard RAM-
    style decomposition: a b-output, n-input table costs
    b * 2^(n-6) (wait... ) — we use the Xilinx LUT6 count for an n-input
    1-output function: L(n) = 1 for n<=6 else 2*L(n-1)... that explodes;
    real mappers use L(n) = ceil((2^(n-4)-1)/3)-ish MUX trees. We model
    the published LogicNets heuristic: L(n) ~ (2^(n-4) - 1) / 3 * 2 + 1
    for n > 6, i.e. a F7/F8-mux LUT tree, clamped at >= 1.
    """
    if fanin_bits <= LUT_K:
        per_bit, depth = 1, 1
    else:
        # LUT6 + carry/mux tree: each extra input doubles the LUT count.
        per_bit = 2 ** (fanin_bits - LUT_K)
        # depth grows ~ (n-6) mux levels on top of the base LUT (muxes are
        # fast; count them as half a level).
        depth = 1 + math.ceil((fanin_bits - LUT_K) / 2)
    return MapReport(per_bit * out_bits, depth, 0)
