"""Truth-table extraction — NullaNet Tiny's core conversion step.

For each neuron j with fanin set S_j (|S_j| = K) and b-bit quantized
inputs, enumerate all (2^b)^K input combinations, push them through
(folded-BN) MAC + output activation quantizer, and record the output
*level codes*. MAC + BN + activation collapse into one lookup table —
the "fixed-function combinational logic" of the paper title.

Tables are stored code-indexed: index = sum_k code_k * n_levels^k
(little-endian in fanin position k). For binary activations this is the
classic bit-packed truth-table index.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .quant import ActQuantSpec, apply_act_quant, encode_levels

Array = jax.Array


@dataclasses.dataclass
class NeuronTable:
    """Truth table of one neuron: fanin indices + output code per row."""

    fanin_idx: np.ndarray      # (K,) int32 — columns of the input vector
    table: np.ndarray          # (n_levels_in ** K,) int8/int16 output codes
    n_levels_in: int
    n_levels_out: int

    @property
    def fanin(self) -> int:
        return int(self.fanin_idx.shape[0])


@dataclasses.dataclass
class LayerTables:
    """All neuron tables of one layer (homogeneous fanin K)."""

    fanin_idx: np.ndarray      # (N, K)
    tables: np.ndarray         # (N, n_levels_in ** K)
    in_spec: ActQuantSpec
    out_spec: ActQuantSpec
    in_alpha: float
    out_alpha: float

    @property
    def n_neurons(self) -> int:
        return int(self.tables.shape[0])

    @property
    def fanin(self) -> int:
        return int(self.fanin_idx.shape[1])

    def neuron(self, j: int) -> NeuronTable:
        return NeuronTable(
            fanin_idx=self.fanin_idx[j],
            table=self.tables[j],
            n_levels_in=self.in_spec.n_levels,
            n_levels_out=self.out_spec.n_levels,
        )


def enumerate_codes(n_levels: int, fanin: int) -> np.ndarray:
    """All (n_levels^K, K) input code combinations, little-endian."""
    n_rows = n_levels ** fanin
    if n_rows > (1 << 24):
        raise ValueError(
            f"enumeration of {n_levels}^{fanin} = {n_rows} rows is infeasible; "
            "tighten the fanin constraint (this is exactly why the paper "
            "applies FCP before conversion)")
    rows = np.arange(n_rows, dtype=np.int64)
    combos = np.empty((n_rows, fanin), dtype=np.int32)
    for k in range(fanin):
        combos[:, k] = (rows // (n_levels ** k)) % n_levels
    return combos


def extract_layer_tables(
    w: Array,
    b: Array,
    mask: Array,
    in_spec: ActQuantSpec,
    out_spec: ActQuantSpec,
    in_alpha: float,
    out_alpha: float,
    fanin: int,
    gamma: Optional[Array] = None,
    beta: Optional[Array] = None,
    bn_mean: Optional[Array] = None,
    bn_var: Optional[Array] = None,
) -> LayerTables:
    """Convert one fanin-pruned quantized linear(+BN)+act layer to tables.

    w: (out, in) weights (already trained & masked), b: (out,) bias.
    The enumeration is fully vectorised: one (2^bK, K) combo matrix is
    shared by all neurons; per-neuron weights are gathered via fanin_idx.
    """
    from .fcp import fanin_indices
    from .quant import fold_bn

    w = jnp.where(jnp.asarray(mask, bool), w, 0.0)
    if gamma is not None:
        w, b = fold_bn(w, b, gamma, beta, bn_mean, bn_var)

    idx, _valid = fanin_indices(np.asarray(mask), fanin)  # (N, K)
    n_levels_in = in_spec.n_levels
    combos = enumerate_codes(n_levels_in, fanin)           # (R, K) codes
    in_levels = np.asarray(in_spec.levels(in_alpha))       # (n_levels_in,)
    combo_vals = in_levels[combos]                          # (R, K) real values

    w_np = np.asarray(w, np.float64)
    b_np = np.asarray(b, np.float64)
    idx_np = np.asarray(idx)
    n = w_np.shape[0]

    # gather per-neuron fanin weights: (N, K)
    wk = np.take_along_axis(w_np, idx_np, axis=1)
    # Padded duplicate indices would double-count a weight; zero all but the
    # first occurrence of each column within a row.
    for j in range(n):
        seen = {}
        for k in range(idx_np.shape[1]):
            c = int(idx_np[j, k])
            if c in seen:
                wk[j, k] = 0.0
            else:
                seen[c] = k

    # pre-activations for every neuron and combo: (N, R)
    pre = wk @ combo_vals.T + b_np[:, None]

    # output activation quantizer -> codes
    pre_j = jnp.asarray(pre, jnp.float32)
    q = apply_act_quant(out_spec, pre_j, jnp.asarray(out_alpha, jnp.float32))
    codes = encode_levels(out_spec, q, out_alpha)
    tables = np.asarray(codes, np.int32)
    dt = np.int8 if out_spec.n_levels <= 127 else np.int16
    return LayerTables(
        fanin_idx=idx_np.astype(np.int32),
        tables=tables.astype(dt),
        in_spec=in_spec,
        out_spec=out_spec,
        in_alpha=float(in_alpha),
        out_alpha=float(out_alpha),
    )


def table_index(codes: Array, n_levels: int) -> Array:
    """Pack per-fanin codes (…, K) into table row indices (…,)."""
    k = codes.shape[-1]
    weights = jnp.asarray([n_levels ** i for i in range(k)], jnp.int32)
    return jnp.sum(codes * weights, axis=-1)


def onset_of(table: np.ndarray, out_bit: int) -> np.ndarray:
    """Boolean on-set bitmap of one output bit of a (possibly multi-bit)
    truth table. Multi-bit outputs become ``code_bits`` separate Boolean
    functions (the paper minimizes each independently)."""
    return ((table.astype(np.int64) >> out_bit) & 1).astype(bool)
