"""Execution of a compiled logic network in JAX (TPU-native analogue of
the FPGA LUT fabric).

A ``LogicNetwork`` is a sequence of ``LayerTables``; inference is a chain
of bit-pack + table-gather operations — the TPU's VMEM-resident gather
plays the role of the LUT. Both a pure-jnp path (the oracle) and a Pallas
path (``repro.kernels.lut_layer``) are provided.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .quant import ActQuantSpec, encode_levels
from .truthtable import LayerTables, table_index

Array = jax.Array


@dataclasses.dataclass
class LogicNetwork:
    """Fixed-function network: input quantizer + per-layer truth tables."""

    layers: List[LayerTables]
    in_spec: ActQuantSpec
    in_alpha: float
    n_inputs: int
    n_outputs: int

    def quantize_inputs(self, x: Array) -> Array:
        """Real inputs -> integer level codes."""
        from .quant import apply_act_quant
        q = apply_act_quant(self.in_spec, x, jnp.asarray(self.in_alpha, x.dtype))
        return encode_levels(self.in_spec, q, self.in_alpha)

    def apply_codes(self, codes: Array, use_pallas: bool = False) -> Array:
        """codes: (batch, n_inputs) int32 -> output codes (batch, n_out)."""
        for lt in self.layers:
            codes = logic_layer_apply(lt, codes, use_pallas=use_pallas)
        return codes

    def __call__(self, x: Array, use_pallas: bool = False) -> Array:
        """Real inputs -> decoded real outputs of the last layer."""
        codes = self.quantize_inputs(x)
        out_codes = self.apply_codes(codes, use_pallas=use_pallas)
        last = self.layers[-1]
        levels = jnp.asarray(last.out_spec.levels(last.out_alpha))
        return levels[out_codes]


def logic_layer_apply(lt: LayerTables, codes: Array,
                      use_pallas: bool = False) -> Array:
    """Apply one truth-table layer: (batch, n_in) codes -> (batch, N)."""
    tables = jnp.asarray(lt.tables)
    idx = jnp.asarray(lt.fanin_idx)
    if use_pallas:
        from repro.kernels.lut_layer.ops import lut_layer
        return lut_layer(codes, idx, tables, lt.in_spec.n_levels)
    # pure-jnp oracle
    gathered = codes[:, idx]                       # (batch, N, K)
    rows = table_index(gathered, lt.in_spec.n_levels)  # (batch, N)
    return _gather_tables(tables, rows)


def _gather_tables(tables: Array, rows: Array) -> Array:
    """tables: (N, R) codes; rows: (batch, N) row index per neuron."""
    tables = tables.astype(jnp.int32)
    # vmap over neurons: out[b, j] = tables[j, rows[b, j]]
    return jax.vmap(lambda t, r: t[r], in_axes=(0, 1), out_axes=1)(tables, rows)


def classify(net: LogicNetwork, x: Array, classes: int,
             use_pallas: bool = False) -> Array:
    """Argmax classification over decoded last-layer values.

    The last layer has ``classes`` neurons whose multi-bit output codes act
    as per-class scores (the paper keeps the output layer's quantized
    scores and takes argmax — fixed-function comparators on chip)."""
    vals = net(x, use_pallas=use_pallas)
    return jnp.argmax(vals[..., :classes], axis=-1)


# ---------------------------------------------------------------------------
# Conversion driver: trained QAT+FCP MLP -> LogicNetwork
# ---------------------------------------------------------------------------

def compile_mlp_to_logic(params: dict, specs: Sequence[ActQuantSpec],
                         alphas: Sequence[float], masks: Sequence[np.ndarray],
                         fanins: Sequence[int], in_spec: ActQuantSpec,
                         in_alpha: float) -> LogicNetwork:
    """Compile a trained quantized MLP (see models/mlp.py) to logic.

    params: {'layers': [{'w','b', optional bn stats}...]}.
    specs/alphas: *output* activation spec per layer.
    """
    from .truthtable import extract_layer_tables

    layer_tables: List[LayerTables] = []
    prev_spec, prev_alpha = in_spec, in_alpha
    for i, lp in enumerate(params["layers"]):
        lt = extract_layer_tables(
            w=lp["w"], b=lp["b"], mask=masks[i],
            in_spec=prev_spec, out_spec=specs[i],
            in_alpha=prev_alpha, out_alpha=float(alphas[i]),
            fanin=fanins[i],
            gamma=lp.get("bn_gamma"), beta=lp.get("bn_beta"),
            bn_mean=lp.get("bn_mean"), bn_var=lp.get("bn_var"),
        )
        layer_tables.append(lt)
        prev_spec, prev_alpha = specs[i], float(alphas[i])
    n_in = params["layers"][0]["w"].shape[1]
    n_out = params["layers"][-1]["w"].shape[0]
    return LogicNetwork(layer_tables, in_spec, float(in_alpha), n_in, n_out)


# ---------------------------------------------------------------------------
# Hardware report for a LogicNetwork (espresso + lutmap pipeline)
# ---------------------------------------------------------------------------

def hardware_report(net: LogicNetwork, minimize_logic: bool = True):
    """Run two-level minimization + LUT mapping over every neuron.

    Returns (MapReport, per-layer list). ``minimize_logic=False`` gives the
    LogicNets-style baseline cost (raw table mapping, no espresso).
    """
    from .espresso import minimize, verify
    from .lutmap import (MapReport, logicnets_lut_cost, map_cover,
                         map_layer, map_network)
    from .truthtable import onset_of

    per_layer = []
    for lt in net.layers:
        out_bits = lt.out_spec.code_bits
        in_bits = lt.in_spec.code_bits
        fanin_bits = lt.fanin * in_bits
        neuron_reports = []
        for j in range(lt.n_neurons):
            table = np.asarray(lt.tables[j])
            if minimize_logic:
                # codes -> bit-level onsets; one Boolean fn per output bit.
                # Input row index == packed code index only when levels are
                # powers of two; our specs guarantee that (code_bits).
                rep = MapReport(0, 0, 0)
                for ob in range(out_bits):
                    onset, dc = _bitexpand(onset_of(table, ob), lt, in_bits)
                    cov = minimize(onset, dc)
                    rep = rep + map_cover(cov)
                neuron_reports.append(rep)
            else:
                neuron_reports.append(logicnets_lut_cost(fanin_bits, out_bits))
        per_layer.append(
            map_layer(neuron_reports, out_bits * lt.n_neurons))
    return map_network(per_layer), per_layer


def _bitexpand(onset_codes: np.ndarray, lt: LayerTables, in_bits: int):
    """Re-index an onset from code-radix rows to bit-packed rows.

    Table rows are indexed in radix n_levels per fanin; Boolean
    minimization wants radix-2 per *bit*. When n_levels is a power of two
    the mappings coincide (empty DC set); otherwise bit rows containing
    an unused code become DON'T CARES — they can never occur at runtime,
    and handing them to ESPRESSO is precisely how the paper shrinks the
    two-level covers. Returns (onset, dc)."""
    n_levels = lt.in_spec.n_levels
    k = lt.fanin
    n_bit_rows = 1 << (k * in_bits)
    if n_levels == (1 << in_bits):
        return onset_codes, None  # already aligned, fully specified
    out = np.zeros(n_bit_rows, bool)
    reachable = np.zeros(n_bit_rows, bool)
    codes = np.arange(len(onset_codes))
    digits = np.empty((len(codes), k), np.int64)
    for i in range(k):
        digits[:, i] = (codes // (n_levels ** i)) % n_levels
    bit_rows = np.zeros(len(codes), np.int64)
    for i in range(k):
        bit_rows |= digits[:, i] << (i * in_bits)
    out[bit_rows] = onset_codes
    reachable[bit_rows] = True
    return out, ~reachable
