"""hymba-1.5b — hybrid-head LM: parallel attention + Mamba heads in every
block, GQA kv=5, SWA [arXiv:2411.13676; hf]. Attention uses a 1024
sliding window (the paper mixes SWA + a few global layers; we model all-
SWA and note the simplification in DESIGN.md). Sub-quadratic: runs
long_500k. 25 heads is not 16-divisible; GSPMD pads."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, act="swiglu",
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    window=1024, rope_theta=10000.0, source="arXiv:2411.13676",
)

SMOKE = ArchConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, act="swiglu",
    ssm_state=8, ssm_conv=4, ssm_expand=2, window=64,
)
