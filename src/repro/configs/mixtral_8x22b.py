"""mixtral-8x22b — sparse MoE decoder: 8 experts top-2, GQA kv=8, SWA
[arXiv:2401.04088; hf]. Sliding window -> sub-quadratic decode: runs
long_500k with a ring KV cache."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768, act="swiglu",
    n_experts=8, moe_top_k=2, capacity_factor=1.25,
    window=4096, rope_theta=1000000.0, source="arXiv:2401.04088",
)

SMOKE = ArchConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, act="swiglu",
    n_experts=4, moe_top_k=2, window=64,
)
