"""phi4-mini-3.8b — dense decoder, RoPE (partial), SwiGLU, GQA kv=8
[arXiv:2412.08905; hf]. 24 heads is not divisible by the 16-way model
axis; GSPMD pads (cost discussed in EXPERIMENTS.md §Roofline)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=200064, act="swiglu",
    rope_theta=10000.0, rotary_pct=0.75, source="arXiv:2412.08905",
)

SMOKE = ArchConfig(
    name="phi4-mini-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=512, act="swiglu", rotary_pct=0.75,
)
