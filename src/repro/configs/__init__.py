"""Config registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, input_specs  # noqa: F401

from repro.configs import (chameleon_34b, dbrx_132b, deepseek_67b,  # noqa: E501
                           falcon_mamba_7b, glm4_9b, hymba_1_5b,
                           mixtral_8x22b, nemotron_4_340b, phi4_mini_3_8b,
                           seamless_m4t_large_v2)

_MODULES = {
    "chameleon-34b": chameleon_34b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "falcon-mamba-7b": falcon_mamba_7b,
    "glm4-9b": glm4_9b,
    "deepseek-67b": deepseek_67b,
    "nemotron-4-340b": nemotron_4_340b,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "mixtral-8x22b": mixtral_8x22b,
    "dbrx-132b": dbrx_132b,
    "hymba-1.5b": hymba_1_5b,
}

ARCHS: Dict[str, ArchConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKES: Dict[str, ArchConfig] = {k: m.SMOKE for k, m in _MODULES.items()}


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch '{name}'; have {sorted(table)}")
    return table[name]


def all_cells() -> Tuple[Tuple[ArchConfig, ShapeConfig], ...]:
    """Every (arch x shape) dry-run cell, skips filtered per spec."""
    cells = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            if a.supports_shape(s):
                cells.append((a, s))
    return tuple(cells)
