"""deepseek-67b — llama-architecture dense decoder, GQA kv=8
[arXiv:2401.02954; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=102400, act="swiglu",
    rope_theta=10000.0, source="arXiv:2401.02954",
)

SMOKE = ArchConfig(
    name="deepseek-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=512, act="swiglu",
)
