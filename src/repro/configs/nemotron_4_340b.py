"""nemotron-4-340b — dense decoder, GQA kv=8, squared-ReLU MLP
[arXiv:2402.16819; unverified]. The squared-ReLU activation is
non-negative, so the paper's per-layer activation-selection rule picks
the PACT branch for QAT here (see DESIGN.md §4)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab_size=256000, act="relu2",
    rope_theta=10000.0, rotary_pct=0.5, source="arXiv:2402.16819",
)

SMOKE = ArchConfig(
    name="nemotron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512, act="relu2", rotary_pct=0.5,
)
