"""JSC-S/M/L — jet substructure classification MLPs (LogicNets
architectures [34], as evaluated by NullaNet Tiny Table I).

Topologies follow LogicNets: 16 inputs, 5 classes.
  JSC-S: 64-32-32-32   fanin 2-3, low bitwidth  -> tiny (paper: 39 LUTs)
  JSC-M: 64-32-32-32   wider fanin/bits          (paper: 1,553 LUTs)
  JSC-L: 32-64-192-192-16 fanin 4, higher bits   (paper: 11,752 LUTs)

The exact LogicNets (fanin, bits) pairs are approximated where the papers
leave them implicit; the reproduction target is the relative claim
structure (accuracy >= LogicNets at multiple-x fewer LUTs) on identical
synthetic data — see DESIGN.md §7.
"""
from repro.models.mlp import MLPConfig

JSC_S = MLPConfig(
    name="jsc-s", n_inputs=16,
    features=(64, 32, 5), fanins=(3, 3, 3),
    act_bits=(2, 2, 3), in_bits=2, n_classes=5, alpha=1.0,
)

JSC_M = MLPConfig(
    name="jsc-m", n_inputs=16,
    features=(64, 32, 32, 5), fanins=(4, 4, 4, 4),
    act_bits=(2, 2, 2, 4), in_bits=2, n_classes=5, alpha=1.0,
)

JSC_L = MLPConfig(
    name="jsc-l", n_inputs=16,
    features=(32, 64, 192, 16, 5), fanins=(4, 4, 4, 4, 4),
    act_bits=(2, 2, 2, 2, 4), in_bits=3, n_classes=5, alpha=1.0,
)

# reduced config for examples / fast tests
JSC_DEMO = MLPConfig(
    name="jsc-demo", n_inputs=16,
    features=(16, 8, 5), fanins=(3, 3, 3),
    act_bits=(2, 2, 3), in_bits=2, n_classes=5, alpha=1.0,
)

JSC = {"jsc-s": JSC_S, "jsc-m": JSC_M, "jsc-l": JSC_L}
