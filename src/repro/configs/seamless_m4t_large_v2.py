"""seamless-m4t-large-v2 — multimodal encoder-decoder
[arXiv:2308.11596; hf]. Transformer backbone only: the speech frontend is
a stub; ``input_specs`` supplies precomputed frame embeddings
(B, seq/8, d_model) to the encoder. MHA (kv == heads)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, act="gelu", cross_attention=True,
    frontend="frames", frontend_frames_div=8,
    source="arXiv:2308.11596",
)

SMOKE = ArchConfig(
    name="seamless-m4t-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, act="gelu", cross_attention=True,
    frontend="frames", frontend_frames_div=8,
)
