"""glm4-9b — dense decoder, RoPE (partial rotary), extreme GQA (kv=2)
[hf:THUDM/glm-4-9b; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=151552, act="swiglu",
    rope_theta=10000.0, rotary_pct=0.5, source="hf:THUDM/glm-4-9b",
)

SMOKE = ArchConfig(
    name="glm4-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=512, act="swiglu", rotary_pct=0.5,
)
