"""Architecture config schema + shape registry.

Every assigned architecture is an ``ArchConfig``; the four LM shape
points (train_4k / prefill_32k / decode_32k / long_500k) are
``ShapeConfig``s. ``input_specs`` builds ShapeDtypeStruct stand-ins for
the dry-run (no allocation).

The paper's technique surfaces as first-class knobs:
  quant_bits / quant_weights — QAT (PACT or signed per the paper's
      per-layer activation-selection rule, see core/quant.py)
  fcp_fanin                  — fanin-constrained pruning of MLP weights
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attn-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 -> d_model // n_heads
    act: str = "swiglu"           # swiglu | relu2 | gelu
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    # attention flavour
    window: int = 0               # sliding-window size; 0 = full attention
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    # enc-dec
    n_enc_layers: int = 0         # >0 => encoder-decoder
    cross_attention: bool = False
    # modality frontend stub: 'tokens' | 'frames' (precomputed embeddings)
    frontend: str = "tokens"
    frontend_frames_div: int = 8  # frames = seq_len // div for 'frames'
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # ---- paper technique knobs (QAT + FCP) ----
    quant_bits: int = 0           # 0 = off; activation bits for MLP QAT
    quant_weights: int = 0        # DoReFa weight bits; 0 = off
    fcp_fanin: int = 0            # 0 = off; per-neuron fanin cap on MLP
    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # citation tag
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, 256)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def n_dec_layers(self) -> int:
        return self.n_layers

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM / SWA / hybrid)."""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k":
            return self.subquadratic
        return True

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        h, kv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        n = 0
        n += v * d                                  # embed
        if not self.tie_embeddings:
            n += d * v                              # head

        def attn_params():
            return d * h * dh + 2 * d * kv * dh + h * dh * d

        def mlp_params():
            mats = 3 if self.act == "swiglu" else 2
            return mats * d * f

        def moe_params():
            mats = 3 if self.act == "swiglu" else 2
            return d * self.n_experts + self.n_experts * mats * d * f

        def mamba_params():
            di, s, r = self.d_inner, self.ssm_state, self.dt_rank_
            return (d * 2 * di + di * self.ssm_conv + di * (r + 2 * s)
                    + r * di + di * s + di + di * d)

        per_layer = 2 * d  # norms
        if self.family == "ssm":
            per_layer += mamba_params()
        elif self.family == "hybrid":
            per_layer += attn_params() + mamba_params() + mlp_params() + 2 * d
        elif self.family == "moe":
            per_layer += attn_params() + moe_params()
        else:
            per_layer += attn_params() + mlp_params()
        n += self.n_layers * per_layer
        if self.is_encdec:
            enc_layer = 2 * d + attn_params() + mlp_params()
            dec_cross = attn_params() + d  # cross-attn + its norm
            n += self.n_enc_layers * enc_layer + self.n_layers * dec_cross
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mats = 3 if self.act == "swiglu" else 2
        inactive = (self.n_experts - self.moe_top_k) * mats * d * f
        return self.param_count() - self.n_layers * inactive


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; zero allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for every model input of the given shape point."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        if cfg.is_encdec:
            frames = S // cfg.frontend_frames_div
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, frames, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = tok(B, S)
            specs["labels"] = tok(B, S)
        elif cfg.frontend == "frames":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.bfloat16)
            specs["labels"] = tok(B, S)
        else:
            specs["tokens"] = tok(B, S)
            specs["labels"] = tok(B, S)
    elif shape.kind == "prefill":
        if cfg.is_encdec:
            frames = S // cfg.frontend_frames_div
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, frames, cfg.d_model), jnp.bfloat16)
            specs["tokens"] = tok(B, S)
        else:
            specs["tokens"] = tok(B, S)
    else:  # decode: one new token against a seq_len cache
        specs["tokens"] = tok(B, 1)
        specs["positions"] = jax.ShapeDtypeStruct((B,), i32)
    return specs
