"""chameleon-34b — early-fusion VLM, dense decoder over text+VQ image
tokens [arXiv:2405.09818; unverified]. Backbone only; the VQ tokenizer is
a stub (image content arrives as token ids in the shared 65536 vocab)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536, act="swiglu",
    rope_theta=10000.0, source="arXiv:2405.09818",
)

SMOKE = ArchConfig(
    name="chameleon-34b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=512, act="swiglu",
)
