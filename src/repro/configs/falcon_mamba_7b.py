"""falcon-mamba-7b — attention-free Mamba-1 SSM LM
[arXiv:2410.05355; unverified]. 64 blocks, d_model 4096, d_inner 8192,
ssm_state 16, conv 4. Sub-quadratic: runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024, ssm_state=16, ssm_conv=4, ssm_expand=2,
    source="arXiv:2410.05355",
)

SMOKE = ArchConfig(
    name="falcon-mamba-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=512, ssm_state=8, ssm_conv=4, ssm_expand=2,
)
