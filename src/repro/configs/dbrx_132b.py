"""dbrx-132b — fine-grained sparse MoE decoder: 16 experts top-4,
GQA kv=8 [hf:databricks/dbrx-base; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352, act="swiglu",
    n_experts=16, moe_top_k=4, capacity_factor=1.25,
    rope_theta=500000.0, source="hf:databricks/dbrx-base",
)

SMOKE = ArchConfig(
    name="dbrx-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=512, act="swiglu",
    n_experts=4, moe_top_k=2,
)
