"""SOP -> AIG construction: espresso `Cover`s become AND/OR trees.

Bridges the paper's two-level minimization (repro.core.espresso) into
the multi-level flow: each cube is an AND tree over its literals, cubes
join in an OR tree, and both trees are built level-aware so the initial
AIG is already depth-balanced. ``network_to_aig`` flattens a whole
compiled ``LogicNetwork`` (truth tables per neuron output bit, with
unreachable input codes as don't-cares) into one combinational AIG whose
PIs/POs are the bit-level wires of the input/output code planes.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.espresso import FREE, Cover, minimize

from .aig import AIG, lit_not


def cover_to_aig(aig: AIG, cover: Cover, in_lits: Sequence[int]) -> int:
    """Build the SOP realised by ``cover`` over existing literals; returns
    the output literal. ``in_lits[v]`` is the literal of SOP variable v."""
    assert len(in_lits) == cover.n_vars
    cube_lits: List[int] = []
    for cube in cover.cubes:
        lits = []
        for v in range(cover.n_vars):
            if cube[v] == FREE:
                continue
            lits.append(in_lits[v] if cube[v] == 1 else lit_not(in_lits[v]))
        cube_lits.append(aig.and_many(lits))
    return aig.or_many(cube_lits)


# beyond this many SOP literals a flat two-level form is likely worse
# than Shannon decomposition (the multi-level analogue of the LUT-RAM
# mux-tree a real mapper falls back to for unstructured functions)
_SOP_LIMIT = 48


def minimize_both_phases(onset: np.ndarray, dc: Optional[np.ndarray] = None
                         ):
    """Minimize a function and its complement; return ``(cover,
    inverted)`` for whichever phase is cheaper (fewer literals, then
    fewer cubes). Inversion is free on an AIG edge, so builders always
    want the cheap phase."""
    onset = np.asarray(onset, bool)
    dc_arr = None if dc is None else np.asarray(dc, bool)
    pos = minimize(onset, dc_arr)
    neg_on = ~onset if dc_arr is None else (~onset & ~dc_arr)
    neg = minimize(neg_on, dc_arr)
    if (neg.n_literals, neg.n_cubes) < (pos.n_literals, pos.n_cubes):
        return neg, True
    return pos, False


def table_to_aig(aig: AIG, onset: np.ndarray, dc: Optional[np.ndarray],
                 in_lits: Sequence[int]) -> int:
    """Minimize a dense on-set (+ optional DC set) and build multi-level
    logic for it.

    Small covers become flat SOPs in whichever phase (function or
    complement) is cheaper — inversion is free on the AIG edge. Covers
    past ``_SOP_LIMIT`` literals are split by Shannon cofactoring on the
    most balanced variable and rebuilt as a mux of two recursive halves,
    which keeps unstructured (near-random) functions mappable."""
    onset = np.asarray(onset, bool)
    n_vars = len(in_lits)
    dc_arr = None if dc is None else np.asarray(dc, bool)
    cov, inv = minimize_both_phases(onset, dc_arr)
    if cov.n_literals > _SOP_LIMIT and n_vars > 6:
        care = np.ones_like(onset) if dc_arr is None else ~dc_arr
        idx = np.nonzero(care & onset)[0]
        # split on the variable whose cofactors are most balanced
        ones = np.array([int(np.sum((idx >> v) & 1)) for v in range(n_vars)])
        v = int(np.argmin(np.abs(ones - len(idx) / 2)))
        rows = np.arange(onset.shape[0])
        lo, hi = ((rows >> v) & 1) == 0, ((rows >> v) & 1) == 1
        rest = list(in_lits[:v]) + list(in_lits[v + 1:])
        f0 = table_to_aig(aig, onset[lo],
                          None if dc_arr is None else dc_arr[lo], rest)
        f1 = table_to_aig(aig, onset[hi],
                          None if dc_arr is None else dc_arr[hi], rest)
        return aig.mux(in_lits[v], f1, f0)
    res = cover_to_aig(aig, cov, in_lits)
    return lit_not(res) if inv else res


def _layer_wires_to_aig(aig: AIG, lt, wires: Sequence[int]) -> List[int]:
    """Synthesize one ``LayerTables`` layer: ``wires`` are the literals of
    the input code bit-plane; returns the output bit-plane literals."""
    from repro.core.logic_infer import _bitexpand
    from repro.core.truthtable import onset_of

    in_bits = lt.in_spec.code_bits
    out_bits = lt.out_spec.code_bits
    out_wires: List[int] = []
    for j in range(lt.n_neurons):
        in_lits = []
        for k in range(lt.fanin):
            src = int(lt.fanin_idx[j, k])
            for b in range(in_bits):
                in_lits.append(wires[src * in_bits + b])
        table = np.asarray(lt.tables[j])
        for ob in range(out_bits):
            onset, dc = _bitexpand(onset_of(table, ob), lt, in_bits)
            out_wires.append(table_to_aig(aig, onset, dc, in_lits))
    return out_wires


def layer_to_aig(lt, n_in: Optional[int] = None) -> AIG:
    """One logic layer as a standalone AIG (PIs = input code bits)."""
    if n_in is None:
        n_in = int(np.max(lt.fanin_idx)) + 1
    in_bits = lt.in_spec.code_bits
    aig = AIG(n_in * in_bits)
    wires = [2 * (p + 1) for p in range(n_in * in_bits)]
    aig.outputs = _layer_wires_to_aig(aig, lt, wires)
    return aig


def network_to_aig(net) -> AIG:
    """Flatten a compiled ``LogicNetwork`` into one combinational AIG.

    PI i*in_bits+b is bit b of input code i; PO j*out_bits+ob is bit ob of
    the last layer's neuron j output code. Layer boundaries disappear —
    this is the representation the mapper covers and the bitplane
    executor runs."""
    in_bits0 = net.in_spec.code_bits
    aig = AIG(net.n_inputs * in_bits0)
    wires: List[int] = [2 * (p + 1) for p in range(net.n_inputs * in_bits0)]
    for lt in net.layers:
        wires = _layer_wires_to_aig(aig, lt, wires)
    aig.outputs = list(wires)
    return aig
