"""Priority k-feasible-cut enumeration over an AIG.

The shared engine under both the rewriter (k=4 resynthesis windows) and
the LUT mapper (k=6 FlowMap-style covering). For each AND node the
bottom-up merge of its fanins' cut sets is filtered to <= k leaves,
deduplicated, pruned for dominance (a cut that is a superset of another
cut of the same node is never useful), and truncated to the ``n_cuts``
best by (depth, area-flow) — the standard priority-cuts scheme that
keeps the exact-FlowMap depth optimum in practice while staying linear
in network size.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .aig import AIG, lit_var


@dataclasses.dataclass(frozen=True)
class Cut:
    leaves: Tuple[int, ...]     # sorted node ids
    depth: int                  # 1 + max leaf arrival (0 for the PI cut)
    aflow: float                # area flow of the cone rooted here


def enumerate_cuts(aig: AIG, k: int = 6, n_cuts: int = 8
                   ) -> Tuple[List[List[Cut]], List[int], List[float]]:
    """Returns (cuts-per-node, arrival-per-node, area-flow-per-node).

    ``arrival[n]`` is the depth-optimal k-LUT arrival time of node n;
    cut lists are sorted best-first by (depth, aflow, size).
    """
    n = aig.n_nodes
    fanout = aig.fanout_counts()
    cuts: List[List[Cut]] = [[] for _ in range(n)]
    arrival = [0] * n
    aflow = [0.0] * n
    cuts[0] = [Cut((), 0, 0.0)]
    for p in range(1, aig.n_pis + 1):
        cuts[p] = [Cut((p,), 0, 0.0)]

    for node in range(aig.n_pis + 1, n):
        f0, f1 = aig.fanins(node)
        c0s, c1s = cuts[lit_var(f0)], cuts[lit_var(f1)]
        merged = {}
        for c0 in c0s:
            s0 = set(c0.leaves)
            for c1 in c1s:
                leaves = s0 | set(c1.leaves)
                if len(leaves) > k:
                    continue
                key = tuple(sorted(leaves))
                if key in merged:
                    continue
                d = 1 + max((arrival[x] for x in key), default=0)
                af = 1.0 + sum(aflow[x] for x in key)
                merged[key] = Cut(key, d, af)
        cands = sorted(merged.values(),
                       key=lambda c: (c.depth, c.aflow, len(c.leaves)))
        # dominance pruning: drop cuts containing an earlier (better) cut
        kept: List[Cut] = []
        for c in cands:
            cs = set(c.leaves)
            if any(set(b.leaves) <= cs for b in kept):
                continue
            kept.append(c)
            if len(kept) >= n_cuts:
                break
        best = kept[0]
        arrival[node] = best.depth
        aflow[node] = best.aflow / max(1, int(fanout[node]))
        # the trivial cut lets parents treat this node as a leaf
        kept.append(Cut((node,), arrival[node], aflow[node]))
        cuts[node] = kept
    return cuts, arrival, aflow
