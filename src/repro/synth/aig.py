"""And-Inverter Graph IR — the multi-level logic representation.

NullaNet Tiny hands espresso'd SOPs to Vivado for multi-level
minimization and technology mapping; ``repro.synth`` replaces that step
offline. The AIG is the standard structural IR of that tool family
(ABC's ``aig``): every node is a 2-input AND, inversion is a literal
attribute on edges, and three invariants are maintained on construction:

  * structural hashing — an ``(a, b)`` AND is created at most once;
  * constant propagation — ANDs with 0/1/x/~x operands fold away;
  * operand canonicalisation — fanins sorted so hash keys are unique.

Encoding: node ids are dense ints, node 0 is constant-FALSE, nodes
``1..n_pis`` are primary inputs, the rest are ANDs. A *literal* is
``2 * node + complement`` (so literal 0 = const0, literal 1 = const1),
matching the AIGER convention.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

NONE = -1


def lit(node: int, compl: int = 0) -> int:
    return (node << 1) | compl


def lit_not(l: int) -> int:
    return l ^ 1

def lit_var(l: int) -> int:
    return l >> 1


def lit_compl(l: int) -> int:
    return l & 1


CONST0 = lit(0, 0)
CONST1 = lit(0, 1)


class AIG:
    """Mutable AIG builder with structural hashing."""

    def __init__(self, n_pis: int = 0):
        self._f0: List[int] = [NONE]      # fanin-0 literal per node
        self._f1: List[int] = [NONE]      # fanin-1 literal per node
        self._level: List[int] = [0]      # logic depth per node
        self._strash: Dict[Tuple[int, int], int] = {}
        self.n_pis = 0
        self.outputs: List[int] = []      # output literals
        for _ in range(n_pis):
            self.add_pi()

    # -- structure ----------------------------------------------------------

    @property
    def n_nodes(self) -> int:
        return len(self._f0)

    @property
    def n_ands(self) -> int:
        return self.n_nodes - 1 - self.n_pis

    def is_pi(self, node: int) -> bool:
        return 1 <= node <= self.n_pis

    def is_and(self, node: int) -> bool:
        return node > self.n_pis

    def fanins(self, node: int) -> Tuple[int, int]:
        return self._f0[node], self._f1[node]

    def level(self, node: int) -> int:
        return self._level[node]

    def depth(self) -> int:
        return max((self._level[lit_var(o)] for o in self.outputs), default=0)

    def add_pi(self) -> int:
        """Append a primary input; returns its (positive) literal."""
        assert self.n_ands == 0, "PIs must be added before any AND node"
        self._f0.append(NONE)
        self._f1.append(NONE)
        self._level.append(0)
        self.n_pis += 1
        return lit(self.n_nodes - 1)

    # -- construction (hashing + constant propagation) ----------------------

    def and2(self, a: int, b: int) -> int:
        if a > b:
            a, b = b, a
        if a == CONST0:
            return CONST0
        if a == CONST1:
            return b
        if a == b:
            return a
        if a == lit_not(b):
            return CONST0
        node = self._strash.get((a, b))
        if node is None:
            node = self.n_nodes
            self._f0.append(a)
            self._f1.append(b)
            self._level.append(
                1 + max(self._level[lit_var(a)], self._level[lit_var(b)]))
            self._strash[(a, b)] = node
        return lit(node)

    def or2(self, a: int, b: int) -> int:
        return lit_not(self.and2(lit_not(a), lit_not(b)))

    def xor2(self, a: int, b: int) -> int:
        return self.or2(self.and2(a, lit_not(b)), self.and2(lit_not(a), b))

    def mux(self, sel: int, t: int, e: int) -> int:
        return self.or2(self.and2(sel, t), self.and2(lit_not(sel), e))

    def _reduce(self, lits: Sequence[int], op, identity: int) -> int:
        """Level-aware (Huffman) reduction: combine the two shallowest
        operands first, which yields a depth-minimal tree even for skewed
        operand levels."""
        if not lits:
            return identity
        import heapq
        heap = [(self._level[lit_var(l)], i, l) for i, l in enumerate(lits)]
        heapq.heapify(heap)
        tie = len(lits)
        while len(heap) > 1:
            _, _, x = heapq.heappop(heap)
            _, _, y = heapq.heappop(heap)
            z = op(x, y)
            heapq.heappush(heap, (self._level[lit_var(z)], tie, z))
            tie += 1
        return heap[0][2]

    def and_many(self, lits: Sequence[int]) -> int:
        return self._reduce(lits, self.and2, CONST1)

    def or_many(self, lits: Sequence[int]) -> int:
        return self._reduce(lits, self.or2, CONST0)

    # -- traversal ----------------------------------------------------------

    def topo_from(self, roots: Iterable[int]) -> List[int]:
        """AND node ids reachable from root literals, in topological order
        (fanins first). Iterative DFS — logic depth can exceed Python's
        recursion limit on wide networks."""
        seen = set()
        order: List[int] = []
        for r in roots:
            n = lit_var(r)
            if n in seen or not self.is_and(n):
                continue
            stack = [(n, False)]
            while stack:
                node, expanded = stack.pop()
                if expanded:
                    order.append(node)
                    continue
                if node in seen or not self.is_and(node):
                    continue
                seen.add(node)
                stack.append((node, True))
                f0, f1 = self._f0[node], self._f1[node]
                stack.append((lit_var(f1), False))
                stack.append((lit_var(f0), False))
        return order

    def fanin_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """(f0, f1) int32 fanin-literal arrays over the AND nodes, in node
        order — the linear program consumed by the simulators."""
        first = self.n_pis + 1
        return (np.asarray(self._f0[first:], np.int32),
                np.asarray(self._f1[first:], np.int32))

    def fanout_counts(self) -> np.ndarray:
        """Structural fanout per node (outputs count as one fanout each)."""
        cnt = np.zeros(self.n_nodes, np.int64)
        for n in range(self.n_pis + 1, self.n_nodes):
            cnt[lit_var(self._f0[n])] += 1
            cnt[lit_var(self._f1[n])] += 1
        for o in self.outputs:
            cnt[lit_var(o)] += 1
        return cnt

    def compact(self) -> "AIG":
        """Rebuild keeping only logic reachable from the outputs. PIs keep
        their count and order; dead ANDs (e.g. rewriting garbage) vanish."""
        new = AIG(self.n_pis)
        old2new = {0: CONST0}
        for p in range(1, self.n_pis + 1):
            old2new[p] = lit(p)

        def map_lit(l: int) -> int:
            return old2new[lit_var(l)] ^ lit_compl(l)

        for n in self.topo_from(self.outputs):
            old2new[n] = new.and2(map_lit(self._f0[n]), map_lit(self._f1[n]))
        new.outputs = [map_lit(o) for o in self.outputs]
        return new

    # -- local function extraction ------------------------------------------

    def cut_tt(self, root: int, leaves: Sequence[int]) -> int:
        """Truth table (python int, bit r = value on minterm r) of the cone
        between ``leaves`` (node ids, var order = list order) and the
        ``root`` node id. Every path from root must hit a leaf or a
        constant; asserts otherwise."""
        m = len(leaves)
        assert m <= 16
        mask = (1 << (1 << m)) - 1
        tts: Dict[int, int] = {0: 0}
        for i, leaf in enumerate(leaves):
            tts[leaf] = _var_tt(i, m)
        if root in tts:
            return tts[root]
        stack = [(root, False)]
        while stack:
            node, expanded = stack.pop()
            if node in tts:
                continue
            assert self.is_and(node), \
                f"node {node} not in the cut cone of {leaves}"
            f0, f1 = self._f0[node], self._f1[node]
            if expanded:
                t0 = tts[lit_var(f0)] ^ (mask if lit_compl(f0) else 0)
                t1 = tts[lit_var(f1)] ^ (mask if lit_compl(f1) else 0)
                tts[node] = t0 & t1
                continue
            stack.append((node, True))
            if lit_var(f0) not in tts:
                stack.append((lit_var(f0), False))
            if lit_var(f1) not in tts:
                stack.append((lit_var(f1), False))
        return tts[root]


_VAR_TT_CACHE: Dict[Tuple[int, int], int] = {}


def _var_tt(i: int, m: int) -> int:
    """Truth table of variable i among m variables."""
    key = (i, m)
    tt = _VAR_TT_CACHE.get(key)
    if tt is None:
        tt = 0
        for r in range(1 << m):
            if (r >> i) & 1:
                tt |= 1 << r
        _VAR_TT_CACHE[key] = tt
    return tt


def tt_expand(tt: int, m: int, k: int) -> int:
    """Pad an m-variable truth table to k variables (new vars ignored)."""
    for _ in range(k - m):
        tt |= tt << (1 << m)
        m += 1
    return tt
