"""Mapped-netlist execution (packed bitplanes) and Verilog emission.

The mapped 6-LUT network is the serving representation: instead of one
table gather per neuron (``repro.core.logic_infer``), inference packs 32
samples per uint32 lane and evaluates each LUT *level* as vectorized
bitwise ops — a Shannon-cofactor fold of every LUT's 64-bit INIT vector
over its six input planes (6 select steps, each one AND/ANDN/OR over the
whole level). Per 32 samples, a LUT costs ~18 word ops regardless of
batch size — the TPU/CPU analogue of the FPGA's spatial LUT fabric.

Execution engines are pluggable: ``BitplaneNetwork(engine=...)`` looks
the name up in the ``repro.synth.executors`` registry (unknown names
raise ``UnknownEngineError`` listing what is registered; third-party
engines join via ``executors.register``). Built-ins:

  * ``engine="numpy"``          — the host fold below
    (``execute_packed``), level-by-level vectorized bitwise ops;
  * ``engine="pallas"``         — ``compile_device_plan`` stacks the
    levelized netlist into device-resident plan tensors and the
    monolithic ``repro.kernels.lut_eval`` kernel evaluates every level
    with the whole wire plane resident in VMEM;
  * ``engine="pallas-streamed"`` — ``compile_tile_plan`` renumbers the
    wire plane level-major and tiles the slot walk; the streamed kernel
    keeps the plane in HBM, double-buffers the per-tile plan tensors
    HBM→VMEM, and folds a whole tile of LUTs per step — faster than
    both of the above and the only engine whose netlists may exceed
    VMEM.

All engines are bit-identical on every reachable input; the device
engines fuse bitplane pack, all levels, the output complement and the
per-request argmax into one jit, so nothing touches the host between
enqueue and verdict.

``emit_verilog`` prints the same netlist structurally (one INIT-indexed
assign per LUT), i.e. the post-mapping artifact the paper gets out of
Vivado, where ``repro.core.netlist`` only emitted pre-mapping SOPs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .aig import lit_compl, lit_var, tt_expand
from .lutmap import MappedNetwork
from .simulate import WORD_BITS, pack_bits, unpack_bits

# Back-compat alias: the authoritative list is the executors registry
# (``repro.synth.executors.names()``), which third parties can extend.
ENGINES = ("numpy", "pallas", "pallas-streamed")

# wire numbering for execution/emission:
#   wire 0            = constant 0
#   wires 1..n_pis    = primary inputs
#   wires n_pis+1+i   = output of LUT i
_CONST_WIRE = 0

_DEFAULT_TILE_ROWS = 32     # mirrors repro.kernels.spec without importing it


@dataclasses.dataclass
class _LevelArrays:
    leaf_idx: np.ndarray     # (L, k) int32 wire indices (const-padded)
    tt_bits: np.ndarray      # (L, 2^k) uint32 0 / 0xFFFFFFFF masks
    out_wires: np.ndarray    # (L,) int32 wire index written


@dataclasses.dataclass
class _Plan:
    """Precompiled execution plan — everything per-call execution needs
    that does not depend on the batch (built once, reused per batch)."""
    levels: List[_LevelArrays]
    out_idx: np.ndarray      # (n_outputs,) int32 wire index per output
    out_neg: np.ndarray      # (n_outputs,) bool complement flags


def _wire_of(mapped: MappedNetwork, node: int, lut_pos: dict) -> int:
    if node == 0:
        return _CONST_WIRE
    if node <= mapped.n_pis:
        return node
    return mapped.n_pis + 1 + lut_pos[node]


def _compile_plan(mapped: MappedNetwork) -> _Plan:
    k = mapped.k
    lut_pos = {l.root: i for i, l in enumerate(mapped.luts)}
    lvl = mapped.levels()
    by_level: dict = {}
    for i, l in enumerate(mapped.luts):
        by_level.setdefault(lvl[l.root], []).append(i)
    levels: List[_LevelArrays] = []
    for level in sorted(by_level):
        idxs = by_level[level]
        leaf_idx = np.zeros((len(idxs), k), np.int32)
        tt_bits = np.zeros((len(idxs), 1 << k), np.uint32)
        out_wires = np.zeros((len(idxs),), np.int32)
        for row, i in enumerate(idxs):
            l = mapped.luts[i]
            m = len(l.leaves)
            for j, x in enumerate(l.leaves):
                leaf_idx[row, j] = _wire_of(mapped, x, lut_pos)
            tt = tt_expand(l.tt, m, k)     # pad slots read the const wire
            for r in range(1 << k):
                if (tt >> r) & 1:
                    tt_bits[row, r] = 0xFFFFFFFF
            out_wires[row] = mapped.n_pis + 1 + i
        levels.append(_LevelArrays(leaf_idx, tt_bits, out_wires))
    out_idx = np.array([_wire_of(mapped, lit_var(o), lut_pos)
                        for o in mapped.outputs], np.int32)
    out_neg = np.array([bool(lit_compl(o)) for o in mapped.outputs], bool)
    return _Plan(levels, out_idx, out_neg)


def execute_packed(mapped: MappedNetwork, pi_words: np.ndarray,
                   plan: Optional[_Plan] = None) -> np.ndarray:
    """pi_words: (n_pis, W) uint32 -> output words (n_outputs, W)."""
    pi_words = np.asarray(pi_words, np.uint32)
    assert pi_words.shape[0] == mapped.n_pis
    w = pi_words.shape[1]
    if plan is None:
        plan = _compile_plan(mapped)
    wires = np.zeros((mapped.n_pis + 1 + mapped.n_luts, w), np.uint32)
    wires[1: mapped.n_pis + 1] = pi_words
    for la in plan.levels:
        ins = wires[la.leaf_idx]                       # (L, k, W)
        state = np.broadcast_to(
            la.tt_bits[:, :, None], la.tt_bits.shape + (w,)).copy()
        half = state.shape[1] // 2
        for j in range(la.leaf_idx.shape[1] - 1, -1, -1):
            sel = ins[:, j:j + 1, :]                   # (L, 1, W)
            state = (state[:, :half] & ~sel) | (state[:, half:] & sel)
            half //= 2
        wires[la.out_wires] = state[:, 0, :]
    out = wires[plan.out_idx]
    out[plan.out_neg] = ~out[plan.out_neg]
    return out


# ---------------------------------------------------------------------------
# Tile plan: level-major renumbering + slot tiling for the streamed kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TilePlan:
    """The mapped netlist as a streamed tile schedule.

    Wires are renumbered *level-major*: row 0 stays the constant-0
    plane, rows 1..n_pis the primary inputs, then each LUT level
    occupies one contiguous band of rows, padded up to a multiple of
    ``tile_rows`` so every tile writes exactly one contiguous band of
    ``tile_rows`` rows (``out_base[t]`` is its first row). Pad slots
    read the constant row with all-zero INIT masks and therefore write
    0 to their own (never-read) pad row — no per-slot validity branch
    and no dump row.

    ``leaf_tiles`` holds plane-row leaf indices for the interpreter's
    vector-gather path; ``gather_rows``/``leaf_loc`` are the staged-DMA
    remap for the TPU path: ``gather_rows[t]`` lists the tile's unique
    leaf rows (padded by re-reading row 0) and
    ``leaf_loc[t, s, j]`` is slot ``s``'s position of leaf ``j`` inside
    that staged buffer. ``row_of_wire`` maps the original executor wire
    numbering (const/PIs/LUT outputs) to renumbered plane rows, so
    callers can pull any original wire out of the streamed plane.
    """

    tt_tiles: np.ndarray     # (n_tiles, T, 2^k) uint32 INIT masks
    leaf_tiles: np.ndarray   # (n_tiles, T, k) int32 plane-row leaves
    leaf_loc: np.ndarray     # (n_tiles, T, k) int32 staged-buffer index
    gather_rows: np.ndarray  # (n_tiles, G) int32 unique rows staged/tile
    out_base: np.ndarray     # (n_tiles,) int32 first row of tile's band
    level_of_tile: np.ndarray  # (n_tiles,) int32 source netlist level
    out_idx: np.ndarray      # (n_outputs,) int32 renumbered output rows
    out_neg: np.ndarray      # (n_outputs,) bool complement flags
    row_of_wire: np.ndarray  # (n_wires,) int32 original wire -> plane row
    n_pis: int
    n_rows: int              # renumbered plane height (incl. pad rows)
    tile_rows: int           # T — LUT slots folded per kernel step
    gather_cap: int          # G — staged leaf rows per tile (DMA mode)
    k: int

    @property
    def n_tiles(self) -> int:
        return self.tt_tiles.shape[0]

    @property
    def n_levels(self) -> int:
        return int(self.level_of_tile.max()) + 1 if self.n_tiles else 0

    def tiles_of_level(self, level: int) -> np.ndarray:
        """Tile indices belonging to one netlist level, in walk order."""
        return np.nonzero(self.level_of_tile == level)[0]


def compile_tile_plan(plan: _Plan, n_pis: int, k: int,
                      tile_rows: int = _DEFAULT_TILE_ROWS) -> TilePlan:
    """Tile the levelized plan for ``lut_eval_streamed_pallas``.

    Each level's slots are cut into tiles of ``tile_rows``; the level's
    output band is padded to a whole number of tiles so band stores
    stay contiguous. Levelization makes tile order a topological order,
    which is what lets the kernel stream tiles back-to-back with only
    plan-tensor DMAs in flight.
    """
    T = max(1, int(tile_rows))
    n_luts = sum(la.out_wires.shape[0] for la in plan.levels)
    n_wires = 1 + n_pis + n_luts
    row_of_wire = np.zeros((n_wires,), np.int32)
    row_of_wire[: n_pis + 1] = np.arange(n_pis + 1, dtype=np.int32)
    base = 1 + n_pis
    bands = []                       # (first_row, n_real_slots, n_tiles)
    for la in plan.levels:
        n_real = la.out_wires.shape[0]
        nt = -(-n_real // T)
        row_of_wire[la.out_wires] = base + np.arange(n_real,
                                                     dtype=np.int32)
        bands.append((base, n_real, nt))
        base += nt * T
    n_rows = base
    n_tiles = sum(b[2] for b in bands)
    tt_tiles = np.zeros((n_tiles, T, 1 << k), np.uint32)
    leaf_tiles = np.zeros((n_tiles, T, k), np.int32)
    leaf_loc = np.zeros((n_tiles, T, k), np.int32)
    out_base = np.zeros((n_tiles,), np.int32)
    level_of_tile = np.zeros((n_tiles,), np.int32)
    uniq: List[np.ndarray] = []
    ti = 0
    for lvl, ((b, n_real, nt), la) in enumerate(zip(bands, plan.levels)):
        for t in range(nt):
            lo, hi = t * T, min((t + 1) * T, n_real)
            n = hi - lo
            tt_tiles[ti, :n] = la.tt_bits[lo:hi]
            leaf_tiles[ti, :n] = row_of_wire[la.leaf_idx[lo:hi]]
            # pad slots keep row-0 leaves + zero INIT (write 0)
            rows, inv = np.unique(leaf_tiles[ti].reshape(-1),
                                  return_inverse=True)
            leaf_loc[ti] = inv.reshape(T, k).astype(np.int32)
            uniq.append(rows.astype(np.int32))
            out_base[ti] = b + lo
            level_of_tile[ti] = lvl
            ti += 1
    gather_cap = max((r.shape[0] for r in uniq), default=1)
    gather_rows = np.zeros((n_tiles, gather_cap), np.int32)
    for ti, rows in enumerate(uniq):
        gather_rows[ti, :rows.shape[0]] = rows   # pad: re-stage row 0
    out_idx = row_of_wire[plan.out_idx].astype(np.int32)
    return TilePlan(tt_tiles, leaf_tiles, leaf_loc, gather_rows, out_base,
                    level_of_tile, out_idx, plan.out_neg.copy(),
                    row_of_wire, n_pis, n_rows, T, gather_cap, k)


# ---------------------------------------------------------------------------
# Device plan: level-stacked, width-padded tensors for the lut_eval kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DevicePlan:
    """The mapped netlist as dense plan tensors for on-device execution.

    Levels are padded with no-op slots to the widest level so the
    tensors stack rectangularly: a padded slot reads the constant-0
    wire (all leaves 0, INIT masks 0) and writes the dump row
    ``n_wires`` — one past the last real wire — so the kernel's slot
    walk needs no per-slot validity branch.

    ``tiles`` (attached by ``compile_device_plan(..., tile_rows=...)``)
    is the same netlist as a streamed tile schedule (``TilePlan``) for
    the tiled kernel; it is derived data and deliberately excluded from
    ``repro.check.plan_check.plan_fingerprint``.
    """

    leaf_idx: np.ndarray     # (n_levels, Lw, k) int32 wire indices
    tt_bits: np.ndarray      # (n_levels, Lw, 2^k) uint32 INIT masks
    out_wires: np.ndarray    # (n_levels, Lw) int32 wire written
    out_idx: np.ndarray      # (n_outputs,) int32 wire index per output
    out_neg: np.ndarray      # (n_outputs,) bool complement flags
    n_pis: int
    n_wires: int             # 1 + n_pis + n_luts (dump row index)
    k: int
    tiles: Optional[TilePlan] = None

    @property
    def n_levels(self) -> int:
        return self.leaf_idx.shape[0]

    @property
    def level_width(self) -> int:
        return self.leaf_idx.shape[1]


def compile_device_plan(mapped: MappedNetwork,
                        plan: Optional[_Plan] = None,
                        verify: bool = False,
                        tile_rows: Optional[int] = None) -> DevicePlan:
    """Stack the per-level arrays of ``_compile_plan`` into uniform-width
    tensors ready to ship to the device.

    ``tile_rows`` additionally attaches the streamed tile schedule
    (``DevicePlan.tiles``) with that slot-tile size. ``verify=True``
    runs ``repro.check``'s plan validator plus a mapped<->plan miter on
    the result and raises ``CheckFailure`` with the first counterexample
    on any disagreement."""
    if plan is None:
        plan = _compile_plan(mapped)
    k = mapped.k
    n_wires = 1 + mapped.n_pis + mapped.n_luts
    n_levels = len(plan.levels)
    lw = max((la.out_wires.shape[0] for la in plan.levels), default=0)
    leaf_idx = np.full((n_levels, lw, k), _CONST_WIRE, np.int32)
    tt_bits = np.zeros((n_levels, lw, 1 << k), np.uint32)
    out_wires = np.full((n_levels, lw), n_wires, np.int32)   # dump row
    for i, la in enumerate(plan.levels):
        n = la.out_wires.shape[0]
        leaf_idx[i, :n] = la.leaf_idx
        tt_bits[i, :n] = la.tt_bits
        out_wires[i, :n] = la.out_wires
    dplan = DevicePlan(leaf_idx, tt_bits, out_wires,
                       plan.out_idx.copy(), plan.out_neg.copy(),
                       mapped.n_pis, n_wires, k)
    if tile_rows is not None:
        dplan.tiles = compile_tile_plan(plan, mapped.n_pis, k, tile_rows)
    if verify:
        from repro.check.pipeline import verify_plan
        verify_plan(mapped, dplan, formal=(verify == "formal"))
    return dplan


def execute_packed_pallas(mapped: MappedNetwork, pi_words: np.ndarray,
                          dplan: Optional[DevicePlan] = None,
                          interpret: Optional[bool] = None) -> np.ndarray:
    """``execute_packed`` through the lut_eval kernel: pi_words
    (n_pis, W) uint32 -> output words (n_outputs, W) uint32."""
    from repro.kernels.lut_eval import lut_eval

    pi_words = np.asarray(pi_words, np.uint32)
    assert pi_words.shape[0] == mapped.n_pis
    if dplan is None:
        dplan = compile_device_plan(mapped)
    plane = lut_eval(pi_words, dplan.leaf_idx, dplan.tt_bits,
                     dplan.out_wires, n_pis=dplan.n_pis,
                     n_wires=dplan.n_wires, interpret=interpret)
    out = plane[dplan.out_idx]
    out[dplan.out_neg] = ~out[dplan.out_neg]
    return out


def execute_packed_streamed(mapped: MappedNetwork, pi_words: np.ndarray,
                            tplan: Optional[TilePlan] = None,
                            tile_rows: int = _DEFAULT_TILE_ROWS,
                            gather: Optional[str] = None,
                            interpret: Optional[bool] = None) -> np.ndarray:
    """``execute_packed`` through the streamed/tiled kernel: pi_words
    (n_pis, W) uint32 -> output words (n_outputs, W) uint32."""
    from repro.kernels.lut_eval import lut_eval_streamed

    pi_words = np.asarray(pi_words, np.uint32)
    assert pi_words.shape[0] == mapped.n_pis
    if tplan is None:
        tplan = compile_tile_plan(_compile_plan(mapped), mapped.n_pis,
                                  mapped.k, tile_rows)
    plane = lut_eval_streamed(pi_words, tplan, gather=gather,
                              interpret=interpret)
    out = plane[tplan.out_idx]
    out[tplan.out_neg] = ~out[tplan.out_neg]
    return out


# ---------------------------------------------------------------------------
# Executors (the engine implementations behind repro.synth.executors)
# ---------------------------------------------------------------------------

class _NumpyExecutor:
    """Host-fold engine: ``execute_packed`` level by level, then the
    bitplane decode — no jax anywhere on the path."""

    name = "numpy"

    def __init__(self, bitnet: "BitplaneNetwork",
                 interpret: Optional[bool] = None, spec=None):
        self._b = bitnet

    def apply_codes(self, codes: np.ndarray) -> np.ndarray:
        b = self._b
        codes = np.asarray(codes, np.int64)
        batch = codes.shape[0]
        # codes -> input bitplanes (wire i*in_bits+j = bit j of code i)
        planes = np.empty((codes.shape[1] * b.in_bits, batch), np.uint8)
        for j in range(b.in_bits):
            planes[j::b.in_bits] = ((codes >> j) & 1).T
        out_words = execute_packed(b.mapped, pack_bits(planes),
                                   plan=b._plan)
        return self._decode(out_words, batch)

    def _decode(self, out_words: np.ndarray, batch: int) -> np.ndarray:
        b = self._b
        out_bits = unpack_bits(out_words, batch)       # (n_out_wires, B)
        n_out = out_bits.shape[0] // b.out_bits
        out_codes = np.zeros((batch, n_out), np.int64)
        for j in range(b.out_bits):
            out_codes |= out_bits[j::b.out_bits].T.astype(np.int64) << j
        return out_codes

    def classify_codes(self, codes: np.ndarray,
                       n_classes: int) -> np.ndarray:
        vals = self._b.out_levels[self.apply_codes(codes)]
        return np.argmax(vals[..., :n_classes], axis=-1).astype(np.int32)

    def classify_packed(self, pi_words: np.ndarray, n_rows: int,
                        n_classes: int) -> np.ndarray:
        b = self._b
        out_words = execute_packed(b.mapped, pi_words, plan=b._plan)
        vals = b.out_levels[self._decode(out_words, n_rows)]
        return np.argmax(vals[..., :n_classes], axis=-1).astype(np.int32)


class _DeviceExecutor:
    """Shared machinery of the fused on-device engines.

    Every public entry point is one jit: bitplane pack (32 samples per
    int32 lane), the netlist kernel (subclass ``_eval_words``), the
    output complement, code decode, and — for the classify paths — the
    ``out_levels`` gather and per-request argmax. Distinct batch shapes
    retrace; serving callers pin the shape (``pad_rows``) so the hot
    path compiles once.
    """

    name = "device"

    def __init__(self, bitnet: "BitplaneNetwork",
                 interpret: Optional[bool] = None, spec=None):
        import jax
        import jax.numpy as jnp
        from repro.kernels.spec import DEFAULT_SPEC

        self._jnp = jnp
        self.spec = DEFAULT_SPEC if spec is None else spec
        self.interpret = self.spec.resolve_interpret(interpret)
        self.in_bits = bitnet.in_bits
        self.out_bits = bitnet.out_bits
        self._levels = jnp.asarray(bitnet.out_levels)
        self._apply = jax.jit(self._apply_codes)
        self._argmax_codes = jax.jit(self._argmax_from_codes,
                                     static_argnames=("n_classes",))
        self._argmax_words = jax.jit(self._argmax_from_words,
                                     static_argnames=("n_classes",))

    # ---- jit-traced building blocks -------------------------------------

    def _eval_words(self, words):
        """(n_pis, W) int32 -> complemented output words (n_outputs, W)."""
        raise NotImplementedError

    def _pack(self, codes):
        """(B, n_inputs) int32 codes -> (n_pi_wires, ceil(B/32)) int32
        packed bitplanes (wire i*in_bits+b = bit b of code i)."""
        jnp = self._jnp
        b, n_in = codes.shape
        shifts = jnp.arange(self.in_bits, dtype=jnp.int32)
        bits = (codes[:, :, None].astype(jnp.int32) >> shifts) & 1
        planes = bits.reshape(b, n_in * self.in_bits).T
        pad = (-b) % WORD_BITS
        if pad:
            planes = jnp.pad(planes, ((0, 0), (0, pad)))
        lanes = planes.reshape(planes.shape[0], -1, WORD_BITS)
        # disjoint bit positions: int32 wraparound sum == bitwise OR
        return (lanes << jnp.arange(WORD_BITS, dtype=jnp.int32)).sum(
            axis=2, dtype=self._jnp.int32)

    def _decode(self, out_words, b):
        """(n_out_wires, W) int32 words -> (b, n_out) int32 codes."""
        jnp = self._jnp
        shifts = jnp.arange(WORD_BITS, dtype=jnp.int32)
        bits = ((out_words[:, :, None] >> shifts) & 1)
        bits = bits.reshape(out_words.shape[0], -1)[:, :b]
        n_out = out_words.shape[0] // self.out_bits
        grouped = bits.reshape(n_out, self.out_bits, b)
        weights = jnp.arange(self.out_bits, dtype=jnp.int32)[None, :, None]
        return (grouped << weights).sum(axis=1, dtype=jnp.int32).T

    def _apply_codes(self, codes):
        words = self._pack(codes)
        return self._decode(self._eval_words(words), codes.shape[0])

    def _argmax_from_codes(self, codes, n_classes: int):
        jnp = self._jnp
        vals = self._levels[self._apply_codes(codes)]
        return jnp.argmax(vals[..., :n_classes], axis=-1).astype(jnp.int32)

    def _argmax_from_words(self, words, n_classes: int):
        jnp = self._jnp
        out = self._eval_words(words)
        codes = self._decode(out, words.shape[1] * WORD_BITS)
        vals = self._levels[codes]
        return jnp.argmax(vals[..., :n_classes], axis=-1).astype(jnp.int32)

    # ---- host-facing API -------------------------------------------------

    def apply_codes(self, codes: np.ndarray) -> np.ndarray:
        jnp = self._jnp
        out = self._apply(jnp.asarray(np.asarray(codes), jnp.int32))
        return np.asarray(out).astype(np.int64)

    def classify_codes(self, codes, n_classes: int) -> np.ndarray:
        jnp = self._jnp
        return np.asarray(self._argmax_codes(
            jnp.asarray(codes, jnp.int32), n_classes=n_classes))

    def classify_words(self, pi_words: np.ndarray, n_rows: int,
                       n_classes: int) -> np.ndarray:
        """Packed PI words straight to the device; only the per-request
        argmax labels come back (the serve aggregation hot path)."""
        jnp = self._jnp
        words = jnp.asarray(
            np.ascontiguousarray(pi_words, np.uint32).view(np.int32))
        labels = self._argmax_words(words, n_classes=n_classes)
        return np.asarray(labels)[:n_rows]

    def classify_packed(self, pi_words: np.ndarray, n_rows: int,
                        n_classes: int) -> np.ndarray:
        return self.classify_words(pi_words, n_rows, n_classes)


class _PallasExecutor(_DeviceExecutor):
    """The monolithic on-device pipeline over a ``DevicePlan`` (whole
    wire plane resident in VMEM, one LUT slot per kernel step)."""

    name = "pallas"

    def __init__(self, bitnet: "BitplaneNetwork",
                 interpret: Optional[bool] = None, spec=None):
        super().__init__(bitnet, interpret=interpret, spec=spec)
        jnp = self._jnp
        dp = compile_device_plan(bitnet.mapped, bitnet._plan)
        self.dp = dp
        self.n_slots = dp.n_levels * dp.level_width
        self._leaf = jnp.asarray(dp.leaf_idx.reshape(-1, dp.k), jnp.int32)
        self._tt = jnp.asarray(np.ascontiguousarray(
            dp.tt_bits.reshape(-1, 1 << dp.k)).view(np.int32))
        self._ow = jnp.asarray(dp.out_wires.reshape(-1), jnp.int32)
        self._out_idx = jnp.asarray(dp.out_idx, jnp.int32)
        self._neg = jnp.asarray(np.where(dp.out_neg, -1, 0), jnp.int32)

    def _eval_words(self, words):
        from repro.kernels.lut_eval.lut_eval import lut_eval_pallas
        jnp = self._jnp
        dp = self.dp
        w = words.shape[1]
        bw = self.spec.tile.clamp_block_w(w)
        pad = (-w) % bw
        if pad:
            words = jnp.pad(words, ((0, 0), (0, pad)))
        if self.n_slots == 0:        # constant network: PIs + const only
            plane = jnp.zeros((dp.n_wires + 1, words.shape[1]), jnp.int32)
            plane = plane.at[1: dp.n_pis + 1].set(words)
        else:
            plane = lut_eval_pallas(
                words, self._leaf, self._tt, self._ow, n_pis=dp.n_pis,
                n_slots=self.n_slots, n_wires=dp.n_wires, k=dp.k,
                block_w=bw, interpret=self.interpret)
        return (plane[self._out_idx] ^ self._neg[:, None])[:, :w]


class _StreamedExecutor(_DeviceExecutor):
    """The streamed/tiled on-device pipeline over a ``TilePlan``: HBM
    wire plane, double-buffered plan-tensor DMA, whole-tile folds.

    Tile geometry comes from, in priority order: an explicit ``spec``,
    the persisted autotune cache (keyed by the plan's sha1 fingerprint,
    see ``repro.kernels.lut_eval.autotune``), or the spec defaults.
    """

    name = "pallas-streamed"

    def __init__(self, bitnet: "BitplaneNetwork",
                 interpret: Optional[bool] = None, spec=None,
                 gather: Optional[str] = None, use_cache: bool = True):
        super().__init__(bitnet, interpret=interpret, spec=spec)
        jnp = self._jnp
        from repro.kernels.lut_eval.lut_eval import default_gather
        dp = compile_device_plan(bitnet.mapped, bitnet._plan)
        if use_cache and spec is None:
            from repro.kernels.lut_eval import autotune
            tuned = autotune.cached_tile(dp, interpret=self.interpret)
            if tuned is not None:
                self.spec = self.spec.with_tile(tile_rows=tuned[0],
                                                block_w=tuned[1])
        tp = compile_tile_plan(bitnet._plan, dp.n_pis, dp.k,
                               self.spec.tile.tile_rows)
        dp.tiles = tp
        self.dp = dp
        self.tp = tp
        self.gather = default_gather() if gather is None else gather
        self._tt_tiles = jnp.asarray(np.ascontiguousarray(
            tp.tt_tiles).view(np.int32))
        self._leaf_tiles = jnp.asarray(tp.leaf_tiles)
        self._leaf_loc = jnp.asarray(tp.leaf_loc)
        self._gather_rows = jnp.asarray(tp.gather_rows)
        self._out_base = jnp.asarray(tp.out_base)
        self._out_idx = jnp.asarray(tp.out_idx, jnp.int32)
        self._neg = jnp.asarray(np.where(tp.out_neg, -1, 0), jnp.int32)

    def _eval_words(self, words):
        from repro.kernels.lut_eval.lut_eval import lut_eval_streamed_pallas
        jnp = self._jnp
        tp = self.tp
        w = words.shape[1]
        bw = self.spec.tile.clamp_block_w(w)
        pad = (-w) % bw
        if pad:
            words = jnp.pad(words, ((0, 0), (0, pad)))
        if tp.n_tiles == 0 or tp.n_pis == 0:     # constant network
            plane = jnp.zeros((tp.n_rows, words.shape[1]), jnp.int32)
            plane = plane.at[1: tp.n_pis + 1].set(words)
        else:
            plane = lut_eval_streamed_pallas(
                words, self._tt_tiles, self._leaf_tiles, self._leaf_loc,
                self._gather_rows, self._out_base, n_pis=tp.n_pis,
                n_tiles=tp.n_tiles, tile_rows=tp.tile_rows,
                gather_cap=tp.gather_cap, n_rows=tp.n_rows, k=tp.k,
                block_w=bw, gather=self.gather, interpret=self.interpret)
        return (plane[self._out_idx] ^ self._neg[:, None])[:, :w]


# ---------------------------------------------------------------------------
# Whole-network bitplane inference (LogicNetwork-compatible front end)
# ---------------------------------------------------------------------------

class BitplaneNetwork:
    """A compiled ``LogicNetwork`` executed through the mapped netlist.

    ``from_logic_network`` runs the full synthesis pipeline
    (SOP -> AIG -> balance/rewrite -> k-LUT map); ``__call__`` matches
    ``LogicNetwork.__call__`` bit-exactly on every reachable input.

    ``engine`` names an executor in the ``repro.synth.executors``
    registry (built-ins: ``"numpy"``, ``"pallas"``,
    ``"pallas-streamed"`` — see the module docstring; register your own
    with ``executors.register``). Unknown names raise
    ``UnknownEngineError`` listing the registered engines. All engines
    are bit-identical on every reachable input.
    """

    def __init__(self, net, mapped: MappedNetwork, engine: str = "numpy",
                 interpret: Optional[bool] = None, spec=None):
        from .executors import get as _get_engine
        self._factory = _get_engine(engine)    # typed error on bad name
        self.net = net
        self.mapped = mapped
        self.engine = engine
        self.interpret = interpret
        self.spec = spec
        # lazy import: this module loads during repro.serve/__init__
        # (via aggregate), while repro.obs pulls repro.serve.metrics —
        # a module-level import here would close an import cycle
        from repro.obs.trace import NULL_TRACER
        self.tracer = NULL_TRACER
        self._plan = _compile_plan(mapped)
        self._exec = None
        self._device_compat: Optional[_PallasExecutor] = None
        self.in_bits = net.in_spec.code_bits
        last = net.layers[-1]
        self.out_bits = last.out_spec.code_bits
        self.out_levels = np.asarray(last.out_spec.levels(last.out_alpha))

    @classmethod
    def from_logic_network(cls, net, effort: int = 1, k: int = 6,
                           engine: str = "numpy",
                           interpret: Optional[bool] = None,
                           verify: bool = False) -> "BitplaneNetwork":
        from . import synthesize        # lazy: package init imports us
        from .from_sop import network_to_aig
        bn = cls(net, synthesize(network_to_aig(net), effort=effort, k=k,
                                 verify=verify),
                 engine=engine, interpret=interpret)
        if verify:
            from repro.check.pipeline import preflight
            from repro.check.report import require_ok
            require_ok(preflight(bn))
        return bn

    @property
    def executor(self):
        """This network's engine instance (built lazily on first use)."""
        if self._exec is None:
            self._exec = self._factory(self, interpret=self.interpret,
                                       spec=self.spec)
        return self._exec

    @property
    def device(self) -> _DeviceExecutor:
        """The fused on-device executor (built lazily on first use).

        For device engines this is ``executor`` itself; under the numpy
        engine it builds the monolithic pallas executor on the side, so
        callers that want a device path regardless of the configured
        engine (profiling, checks) keep working."""
        ex = self.executor
        if isinstance(ex, _DeviceExecutor):
            return ex
        if self._device_compat is None:
            self._device_compat = _PallasExecutor(
                self, interpret=self.interpret, spec=self.spec)
        return self._device_compat

    def apply_codes(self, codes: np.ndarray) -> np.ndarray:
        """(B, n_inputs) input codes -> (B, n_out_neurons) output codes."""
        return self.executor.apply_codes(np.asarray(codes, np.int64))

    def __call__(self, x) -> np.ndarray:
        """Real inputs -> decoded real outputs (LogicNetwork contract)."""
        codes = np.asarray(self.net.quantize_inputs(x))
        return self.out_levels[self.apply_codes(codes)]

    def classify(self, x, n_classes: int) -> np.ndarray:
        codes = np.asarray(self.net.quantize_inputs(x))
        return self.executor.classify_codes(codes, n_classes)

    def classify_packed(self, pi_words: np.ndarray, n_rows: int,
                        n_classes: int) -> np.ndarray:
        """Packed PI bitplanes -> per-lane argmax labels, (n_rows,) int32.

        The serve-aggregation entry point: on device engines the words
        go straight to the kernel and only the scattered argmax
        returns; on numpy it is the host fold + decode."""
        with self.tracer.span("lut_eval", cat="kernel", args={
                "rows": n_rows, "engine": self.engine,
                "n_levels": len(self._plan.levels)}):
            return self.executor.classify_packed(pi_words, n_rows,
                                                 n_classes)


# ---------------------------------------------------------------------------
# Verilog emission of the mapped netlist
# ---------------------------------------------------------------------------

def emit_verilog(mapped: MappedNetwork, name: str = "mapped_logic") -> str:
    """Structural Verilog: one INIT-vector-indexed assign per LUT (the
    textual form of a LUT6 instance, synthesizable and simulable)."""
    k = mapped.k
    lut_pos = {l.root: i for i, l in enumerate(mapped.luts)}

    def wname(node: int) -> str:
        w = _wire_of(mapped, node, lut_pos)
        if w == _CONST_WIRE:
            return "1'b0"
        if w <= mapped.n_pis:
            return f"x[{w - 1}]"
        return f"n{w}"

    lines = [
        f"// {name}: {mapped.n_luts} LUT{k}s, depth {mapped.depth}",
        f"// generated by repro.synth (AIG -> rewrite -> {k}-LUT map)",
        f"module {name} (",
        f"  input  wire [{mapped.n_pis - 1}:0] x,",
        f"  output wire [{len(mapped.outputs) - 1}:0] y",
        ");",
    ]
    for i, l in enumerate(mapped.luts):
        m = len(l.leaves)
        tt = tt_expand(l.tt, m, k)
        init = f"{1 << k}'h{tt:0{(1 << k) // 4}x}"
        ins = [wname(x) for x in l.leaves]
        ins += ["1'b0"] * (k - m)            # pad unused select inputs
        sel = ", ".join(reversed(ins))       # MSB first in concatenation
        w = mapped.n_pis + 1 + i
        lines.append(f"  wire n{w};")
        lines.append(f"  wire [{(1 << k) - 1}:0] n{w}_init = {init};  // LUT{k}")
        lines.append(f"  assign n{w} = n{w}_init[{{{sel}}}];")
    for i, o in enumerate(mapped.outputs):
        inv = "~" if lit_compl(o) else ""
        src = wname(lit_var(o))
        if src == "1'b0" and inv:
            lines.append(f"  assign y[{i}] = 1'b1;")
        else:
            lines.append(f"  assign y[{i}] = {inv}{src};")
    lines.append("endmodule")
    return "\n".join(lines)
