"""Mapped-netlist execution (packed bitplanes) and Verilog emission.

The mapped 6-LUT network is the serving representation: instead of one
table gather per neuron (``repro.core.logic_infer``), inference packs 32
samples per uint32 lane and evaluates each LUT *level* as vectorized
bitwise ops — a Shannon-cofactor fold of every LUT's 64-bit INIT vector
over its six input planes (6 select steps, each one AND/ANDN/OR over the
whole level). Per 32 samples, a LUT costs ~18 word ops regardless of
batch size — the TPU/CPU analogue of the FPGA's spatial LUT fabric.

``emit_verilog`` prints the same netlist structurally (one INIT-indexed
assign per LUT), i.e. the post-mapping artifact the paper gets out of
Vivado, where ``repro.core.netlist`` only emitted pre-mapping SOPs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .aig import lit_compl, lit_var, tt_expand
from .lutmap import MappedNetwork
from .simulate import pack_bits, unpack_bits

# wire numbering for execution/emission:
#   wire 0            = constant 0
#   wires 1..n_pis    = primary inputs
#   wires n_pis+1+i   = output of LUT i
_CONST_WIRE = 0


@dataclasses.dataclass
class _LevelArrays:
    leaf_idx: np.ndarray     # (L, k) int32 wire indices (const-padded)
    tt_bits: np.ndarray      # (L, 2^k) uint32 0 / 0xFFFFFFFF masks
    out_wires: np.ndarray    # (L,) int32 wire index written


@dataclasses.dataclass
class _Plan:
    """Precompiled execution plan — everything per-call execution needs
    that does not depend on the batch (built once, reused per batch)."""
    levels: List[_LevelArrays]
    out_idx: np.ndarray      # (n_outputs,) int32 wire index per output
    out_neg: np.ndarray      # (n_outputs,) bool complement flags


def _wire_of(mapped: MappedNetwork, node: int, lut_pos: dict) -> int:
    if node == 0:
        return _CONST_WIRE
    if node <= mapped.n_pis:
        return node
    return mapped.n_pis + 1 + lut_pos[node]


def _compile_plan(mapped: MappedNetwork) -> _Plan:
    k = mapped.k
    lut_pos = {l.root: i for i, l in enumerate(mapped.luts)}
    lvl = mapped.levels()
    by_level: dict = {}
    for i, l in enumerate(mapped.luts):
        by_level.setdefault(lvl[l.root], []).append(i)
    levels: List[_LevelArrays] = []
    for level in sorted(by_level):
        idxs = by_level[level]
        leaf_idx = np.zeros((len(idxs), k), np.int32)
        tt_bits = np.zeros((len(idxs), 1 << k), np.uint32)
        out_wires = np.zeros((len(idxs),), np.int32)
        for row, i in enumerate(idxs):
            l = mapped.luts[i]
            m = len(l.leaves)
            for j, x in enumerate(l.leaves):
                leaf_idx[row, j] = _wire_of(mapped, x, lut_pos)
            tt = tt_expand(l.tt, m, k)     # pad slots read the const wire
            for r in range(1 << k):
                if (tt >> r) & 1:
                    tt_bits[row, r] = 0xFFFFFFFF
            out_wires[row] = mapped.n_pis + 1 + i
        levels.append(_LevelArrays(leaf_idx, tt_bits, out_wires))
    out_idx = np.array([_wire_of(mapped, lit_var(o), lut_pos)
                        for o in mapped.outputs], np.int32)
    out_neg = np.array([bool(lit_compl(o)) for o in mapped.outputs], bool)
    return _Plan(levels, out_idx, out_neg)


def execute_packed(mapped: MappedNetwork, pi_words: np.ndarray,
                   plan: Optional[_Plan] = None) -> np.ndarray:
    """pi_words: (n_pis, W) uint32 -> output words (n_outputs, W)."""
    pi_words = np.asarray(pi_words, np.uint32)
    assert pi_words.shape[0] == mapped.n_pis
    w = pi_words.shape[1]
    if plan is None:
        plan = _compile_plan(mapped)
    wires = np.zeros((mapped.n_pis + 1 + mapped.n_luts, w), np.uint32)
    wires[1: mapped.n_pis + 1] = pi_words
    for la in plan.levels:
        ins = wires[la.leaf_idx]                       # (L, k, W)
        state = np.broadcast_to(
            la.tt_bits[:, :, None], la.tt_bits.shape + (w,)).copy()
        half = state.shape[1] // 2
        for j in range(la.leaf_idx.shape[1] - 1, -1, -1):
            sel = ins[:, j:j + 1, :]                   # (L, 1, W)
            state = (state[:, :half] & ~sel) | (state[:, half:] & sel)
            half //= 2
        wires[la.out_wires] = state[:, 0, :]
    out = wires[plan.out_idx]
    out[plan.out_neg] = ~out[plan.out_neg]
    return out


# ---------------------------------------------------------------------------
# Whole-network bitplane inference (LogicNetwork-compatible front end)
# ---------------------------------------------------------------------------

class BitplaneNetwork:
    """A compiled ``LogicNetwork`` executed through the mapped netlist.

    ``from_logic_network`` runs the full synthesis pipeline
    (SOP -> AIG -> balance/rewrite -> k-LUT map); ``__call__`` matches
    ``LogicNetwork.__call__`` bit-exactly on every reachable input.
    """

    def __init__(self, net, mapped: MappedNetwork):
        self.net = net
        self.mapped = mapped
        self._plan = _compile_plan(mapped)
        self.in_bits = net.in_spec.code_bits
        last = net.layers[-1]
        self.out_bits = last.out_spec.code_bits
        self.out_levels = np.asarray(last.out_spec.levels(last.out_alpha))

    @classmethod
    def from_logic_network(cls, net, effort: int = 1,
                           k: int = 6) -> "BitplaneNetwork":
        from . import synthesize        # lazy: package init imports us
        from .from_sop import network_to_aig
        return cls(net, synthesize(network_to_aig(net), effort=effort, k=k))

    def apply_codes(self, codes: np.ndarray) -> np.ndarray:
        """(B, n_inputs) input codes -> (B, n_out_neurons) output codes."""
        codes = np.asarray(codes, np.int64)
        batch = codes.shape[0]
        # codes -> input bitplanes (wire i*in_bits+b = bit b of code i)
        planes = np.empty((codes.shape[1] * self.in_bits, batch), np.uint8)
        for b in range(self.in_bits):
            planes[b::self.in_bits] = ((codes >> b) & 1).T
        out_words = execute_packed(self.mapped, pack_bits(planes),
                                   plan=self._plan)
        out_bits = unpack_bits(out_words, batch)       # (n_out_wires, B)
        n_out = out_bits.shape[0] // self.out_bits
        out_codes = np.zeros((batch, n_out), np.int64)
        for b in range(self.out_bits):
            out_codes |= out_bits[b::self.out_bits].T.astype(np.int64) << b
        return out_codes

    def __call__(self, x) -> np.ndarray:
        """Real inputs -> decoded real outputs (LogicNetwork contract)."""
        codes = np.asarray(self.net.quantize_inputs(x))
        return self.out_levels[self.apply_codes(codes)]

    def classify(self, x, n_classes: int) -> np.ndarray:
        vals = self(x)
        return np.argmax(vals[..., :n_classes], axis=-1).astype(np.int32)


# ---------------------------------------------------------------------------
# Verilog emission of the mapped netlist
# ---------------------------------------------------------------------------

def emit_verilog(mapped: MappedNetwork, name: str = "mapped_logic") -> str:
    """Structural Verilog: one INIT-vector-indexed assign per LUT (the
    textual form of a LUT6 instance, synthesizable and simulable)."""
    k = mapped.k
    lut_pos = {l.root: i for i, l in enumerate(mapped.luts)}

    def wname(node: int) -> str:
        w = _wire_of(mapped, node, lut_pos)
        if w == _CONST_WIRE:
            return "1'b0"
        if w <= mapped.n_pis:
            return f"x[{w - 1}]"
        return f"n{w}"

    lines = [
        f"// {name}: {mapped.n_luts} LUT{k}s, depth {mapped.depth}",
        f"// generated by repro.synth (AIG -> rewrite -> {k}-LUT map)",
        f"module {name} (",
        f"  input  wire [{mapped.n_pis - 1}:0] x,",
        f"  output wire [{len(mapped.outputs) - 1}:0] y",
        ");",
    ]
    for i, l in enumerate(mapped.luts):
        m = len(l.leaves)
        tt = tt_expand(l.tt, m, k)
        init = f"{1 << k}'h{tt:0{(1 << k) // 4}x}"
        ins = [wname(x) for x in l.leaves]
        ins += ["1'b0"] * (k - m)            # pad unused select inputs
        sel = ", ".join(reversed(ins))       # MSB first in concatenation
        w = mapped.n_pis + 1 + i
        lines.append(f"  wire n{w};")
        lines.append(f"  wire [{(1 << k) - 1}:0] n{w}_init = {init};  // LUT{k}")
        lines.append(f"  assign n{w} = n{w}_init[{{{sel}}}];")
    for i, o in enumerate(mapped.outputs):
        inv = "~" if lit_compl(o) else ""
        src = wname(lit_var(o))
        if src == "1'b0" and inv:
            lines.append(f"  assign y[{i}] = 1'b1;")
        else:
            lines.append(f"  assign y[{i}] = {inv}{src};")
    lines.append("endmodule")
    return "\n".join(lines)
