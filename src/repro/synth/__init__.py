"""repro.synth — multi-level logic synthesis and k-LUT technology mapping.

The offline replacement for the Vivado step of NullaNet Tiny's flow:

    SOP covers (core.espresso)
      -> AIG with structural hashing          (synth.aig / synth.from_sop)
      -> balance + DAG-aware rewriting        (synth.rewrite)
      -> depth-optimal 6-LUT mapping + area   (synth.lutmap)
      -> measured LUTs/depth, Verilog,        (synth.executor)
         and bit-parallel TPU/CPU execution   (synth.simulate,
                                               kernels.aig_sim)

``compile_logic_network(net)`` is the one-call pipeline from a compiled
``LogicNetwork`` to its executable mapped netlist.
"""
from .aig import AIG, CONST0, CONST1, lit, lit_compl, lit_not, lit_var
from .cuts import Cut, enumerate_cuts
from . import executors
from .executor import (BitplaneNetwork, DevicePlan, TilePlan,
                       compile_device_plan, compile_tile_plan,
                       emit_verilog, execute_packed, execute_packed_pallas,
                       execute_packed_streamed)
from .from_sop import cover_to_aig, layer_to_aig, network_to_aig, table_to_aig
from .lutmap import MappedLUT, MappedNetwork, map_aig
from .rewrite import balance, optimize, rewrite
from .simulate import (exhaustive_equiv, input_patterns, pack_bits,
                       random_equiv, random_words, simulate, unpack_bits)


def synthesize(aig: AIG, effort: int = 1, k: int = 6,
               verify=False) -> MappedNetwork:
    """balance/rewrite rounds (``effort``; 0 = map the raw AIG) followed
    by k-LUT mapping with area recovery.

    ``verify=True`` miters every transform against its input (rewrite
    must preserve the function everywhere, the LUT cover must match the
    optimized AIG everywhere) and raises ``repro.check.CheckFailure``
    with a counterexample on any disagreement. Cones wider than the
    20-PI exhaustive limit are only *sampled*; ``verify="formal"``
    escalates them to the ``repro.check.sat`` engine, which proves the
    miter UNSAT at any width (or fails with a replayed SAT
    counterexample / an explicit UNPROVEN warning)."""
    raw = aig
    if effort > 0:
        aig = optimize(aig, rounds=effort)
    mapped = map_aig(aig, k=k)
    if verify:
        from repro.check.pipeline import verify_synthesis
        verify_synthesis(raw, aig, mapped, formal=(verify == "formal"))
    return mapped


def compile_logic_network(net, effort: int = 1, k: int = 6,
                          engine: str = "numpy",
                          interpret=None,
                          verify: bool = False) -> BitplaneNetwork:
    """LogicNetwork -> optimized mapped netlist, ready to execute.

    ``engine`` names an executor in the ``repro.synth.executors``
    registry: ``"pallas"`` runs the netlist through the fused
    ``kernels.lut_eval`` device pipeline instead of the host fold, and
    ``"pallas-streamed"`` through the streamed/tiled kernel (fastest,
    and the only engine whose wire plane may exceed VMEM).
    ``verify=True`` additionally runs the ``repro.check`` lint +
    equivalence passes over every synthesis stage (CheckFailure on the
    first counterexample)."""
    return BitplaneNetwork.from_logic_network(net, effort=effort, k=k,
                                              engine=engine,
                                              interpret=interpret,
                                              verify=verify)
