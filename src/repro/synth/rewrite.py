"""DAG-aware AIG optimization passes: balancing and cut rewriting.

``balance`` re-associates AND trees for minimum depth (ABC's ``balance``):
each maximal single-fanout conjunction cone is collapsed and rebuilt as
a level-aware Huffman tree, sharing preserved at multi-fanout frontiers.

``rewrite`` is cut-based resynthesis (ABC's ``rewrite`` in spirit): the
network is reconstructed node by node into a fresh structurally-hashed
AIG; for each node every enumerated k-cut's local function is
re-synthesized from its minimized SOP (both phases) *against the new
AIG's hash table*, so logic already built elsewhere in the DAG costs
zero — that sharing is what makes the pass DAG-aware rather than
tree-local. The cheapest implementation (fewest freshly created nodes,
ties broken on depth) wins; rejected candidates become dead nodes that
the final ``compact`` sweeps out. Every replacement is functionally
exact by construction (the cut truth table is the spec), so the passes
preserve equivalence unconditionally.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .aig import AIG, CONST0, lit, lit_compl, lit_not, lit_var
from .cuts import enumerate_cuts


def balance(aig: AIG) -> AIG:
    new = AIG(aig.n_pis)
    fanout = aig.fanout_counts()
    mapped: Dict[int, int] = {0: CONST0}
    for p in range(1, aig.n_pis + 1):
        mapped[p] = lit(p)

    def map_lit(l: int) -> int:
        return mapped[lit_var(l)] ^ lit_compl(l)

    def cone_leaves(root: int) -> List[int]:
        """Literals feeding the maximal conjunction cone rooted at an AND:
        expand through non-complemented, single-fanout AND edges."""
        leaves: List[int] = []
        stack = list(aig.fanins(root))
        while stack:
            l = stack.pop()
            n = lit_var(l)
            if (not lit_compl(l) and aig.is_and(n) and fanout[n] == 1):
                stack.extend(aig.fanins(n))
            else:
                leaves.append(l)
        return leaves

    # multi-fanout / complemented-edge ANDs are the cone roots; absorbed
    # single-fanout internals never get (and never need) an image of
    # their own, so process roots only.
    order = aig.topo_from(aig.outputs)
    root_set = set()
    for n in order:
        for l in aig.fanins(n):
            m = lit_var(l)
            if aig.is_and(m) and (lit_compl(l) or fanout[m] != 1):
                root_set.add(m)
    for o in aig.outputs:
        if aig.is_and(lit_var(o)):
            root_set.add(lit_var(o))
    for n in order:
        if n not in root_set:
            continue
        leaves = [map_lit(l) for l in cone_leaves(n)]
        mapped[n] = new.and_many(leaves)
    new.outputs = [map_lit(o) for o in aig.outputs]
    return new.compact()


def _tt_candidate(new: AIG, tt: int, m: int, leaf_lits: List[int]) -> int:
    """Resynthesize an m-var function from its minimized SOP into ``new``
    (cheaper phase of function/complement); returns the output literal."""
    from .from_sop import cover_to_aig, minimize_both_phases

    n_rows = 1 << m
    onset = np.zeros(n_rows, bool)
    for r in range(n_rows):
        if (tt >> r) & 1:
            onset[r] = True
    cov, inv = minimize_both_phases(onset)
    res = cover_to_aig(new, cov, leaf_lits)
    return lit_not(res) if inv else res


def rewrite(aig: AIG, k: int = 4, n_cuts: int = 6) -> AIG:
    cuts, _, _ = enumerate_cuts(aig, k=k, n_cuts=n_cuts)
    new = AIG(aig.n_pis)
    mapped: Dict[int, int] = {0: CONST0}
    for p in range(1, aig.n_pis + 1):
        mapped[p] = lit(p)

    def map_lit(l: int) -> int:
        return mapped[lit_var(l)] ^ lit_compl(l)

    for node in aig.topo_from(aig.outputs):
        f0, f1 = aig.fanins(node)
        # candidate 0: plain reconstruction (never structurally worse)
        before = new.n_nodes
        best = new.and2(map_lit(f0), map_lit(f1))
        best_cost = new.n_nodes - before
        best_level = new.level(lit_var(best))
        for cut in cuts[node]:
            m = len(cut.leaves)
            if m < 2 or m > k or cut.leaves == (node,):
                continue
            tt = aig.cut_tt(node, cut.leaves)
            leaf_lits = [mapped[x] for x in cut.leaves]
            before = new.n_nodes
            cand = _tt_candidate(new, tt, m, leaf_lits)
            cost = new.n_nodes - before
            lvl = new.level(lit_var(cand))
            if (cost, lvl) < (best_cost, best_level):
                best, best_cost, best_level = cand, cost, lvl
        mapped[node] = best
    new.outputs = [map_lit(o) for o in aig.outputs]
    return new.compact()


def optimize(aig: AIG, rounds: int = 1, rewrite_k: int = 4) -> AIG:
    """The standard script: (balance; rewrite)+ ; balance."""
    for _ in range(rounds):
        aig = balance(aig)
        aig = rewrite(aig, k=rewrite_k)
    return balance(aig)
