"""Bit-parallel AIG simulation and simulation-based equivalence checking.

Samples are packed 32 per uint32 lane, so one AND node evaluation is a
single bitwise op over a word vector — random simulation of thousands of
patterns costs one numpy pass over the node list (or one Pallas kernel
launch, ``repro.kernels.aig_sim``, where the node loop runs on-chip over
VMEM-resident value planes).

Equivalence checks come in two strengths:
  * ``exhaustive_equiv`` — all 2^n input patterns (n <= 16), a proof;
  * ``random_equiv`` — Monte-Carlo over packed random words, the
    fast-and-overwhelming check used for whole-network pipelines where
    exhaustive enumeration is infeasible (a single 32-lane word already
    tests 32 patterns per node pass).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .aig import AIG, lit_compl, lit_var

WORD_BITS = 32


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """(n, B) {0,1} -> (n, ceil(B/32)) uint32, sample s in bit s%32 of
    word s//32."""
    bits = np.asarray(bits).astype(np.uint32)
    n, b = bits.shape
    pad = (-b) % WORD_BITS
    if pad:
        bits = np.concatenate(
            [bits, np.zeros((n, pad), np.uint32)], axis=1)
    lanes = bits.reshape(n, -1, WORD_BITS)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return np.bitwise_or.reduce(lanes << shifts, axis=2).astype(np.uint32)


def unpack_bits(words: np.ndarray, n_samples: int) -> np.ndarray:
    """Inverse of ``pack_bits``: (n, W) uint32 -> (n, n_samples) uint8."""
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts) & np.uint32(1)
    return bits.reshape(words.shape[0], -1)[:, :n_samples].astype(np.uint8)


def input_patterns(n_vars: int) -> np.ndarray:
    """Packed exhaustive patterns: row v holds variable v over all 2^n
    minterms (minterm index little-endian in the variables)."""
    assert n_vars <= 16
    idx = np.arange(1 << n_vars, dtype=np.uint32)
    bits = np.stack([(idx >> v) & 1 for v in range(n_vars)])
    return pack_bits(bits)


def simulate(aig: AIG, pi_words: np.ndarray,
             use_pallas: bool = False) -> np.ndarray:
    """Evaluate all outputs on packed input words.

    pi_words: (n_pis, W) uint32 -> (n_outputs, W) uint32.
    """
    pi_words = np.ascontiguousarray(pi_words, np.uint32)
    assert pi_words.shape[0] == aig.n_pis
    if use_pallas:
        vals = _simulate_pallas(aig, pi_words)
    else:
        vals = _simulate_np(aig, pi_words)
    out = np.empty((len(aig.outputs), pi_words.shape[1]), np.uint32)
    for i, o in enumerate(aig.outputs):
        v = vals[lit_var(o)]
        out[i] = ~v if lit_compl(o) else v
    return out


def _simulate_np(aig: AIG, pi_words: np.ndarray) -> np.ndarray:
    n, w = aig.n_nodes, pi_words.shape[1]
    vals = np.zeros((n, w), np.uint32)
    vals[1: aig.n_pis + 1] = pi_words
    for node in range(aig.n_pis + 1, n):
        f0, f1 = aig.fanins(node)
        v0 = vals[lit_var(f0)]
        v1 = vals[lit_var(f1)]
        if lit_compl(f0):
            v0 = ~v0
        if lit_compl(f1):
            v1 = ~v1
        vals[node] = v0 & v1
    return vals


def _simulate_pallas(aig: AIG, pi_words: np.ndarray) -> np.ndarray:
    from repro.kernels.aig_sim import aig_sim
    f0, f1 = aig.fanin_arrays()
    return np.asarray(aig_sim(pi_words, f0, f1, aig.n_pis))


def random_words(n_rows: int, n_words: int,
                 seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << WORD_BITS, (n_rows, n_words),
                        dtype=np.uint32)


def random_equiv(a: AIG, b: AIG, n_words: int = 64,
                 seed: int = 0, use_pallas: bool = False) -> bool:
    """Monte-Carlo equivalence of two AIGs over the same PIs: 32*n_words
    random patterns. A miscompare is a proof of inequivalence; agreement
    is evidence (standard random-simulation filter)."""
    assert a.n_pis == b.n_pis and len(a.outputs) == len(b.outputs)
    words = random_words(a.n_pis, n_words, seed)
    return bool(np.array_equal(simulate(a, words, use_pallas=use_pallas),
                               simulate(b, words, use_pallas=use_pallas)))


def exhaustive_equiv(aig: AIG, tts) -> bool:
    """Prove each output equals the given truth table (python ints, bit r
    = minterm r) by exhaustive packed simulation. PIs <= 16."""
    n = aig.n_pis
    got = simulate(aig, input_patterns(n))
    bits = unpack_bits(got, 1 << n)
    for row, tt in zip(bits, tts):
        want = np.array([(tt >> r) & 1 for r in range(1 << n)], np.uint8)
        if not np.array_equal(row, want):
            return False
    return True
