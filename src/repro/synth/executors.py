"""Pluggable executor-engine registry for ``BitplaneNetwork``.

``BitplaneNetwork(engine=...)`` used to be a hard-coded string switch;
this module makes the engine a lookup. An *engine* is a name bound to a
factory ``factory(bitnet, *, interpret=None, spec=None) -> Executor``;
the returned object implements the three-method ``Executor`` protocol
(the exact call surface ``BitplaneNetwork`` delegates to). Built-ins
registered at import:

  * ``"numpy"``           — host bitplane fold (no jax on the path);
  * ``"pallas"``          — monolithic device kernel, wire plane in VMEM;
  * ``"pallas-streamed"`` — streamed/tiled kernel, wire plane in HBM,
    double-buffered plan DMA (the fast one; see
    ``repro.kernels.lut_eval``).

Registering a custom engine is one call and every call site that takes
``engine=`` (``BitplaneNetwork``, ``compile_logic_network``,
``LogicEngine``, ``launch.serve --engine``) picks it up with zero edits:

    from repro.synth import executors

    @executors.register("my-engine")
    def build(bitnet, interpret=None, spec=None):
        return MyExecutor(bitnet)

Unknown names raise ``UnknownEngineError`` (a ``KeyError``) naming the
registered engines, at ``BitplaneNetwork`` construction time — not on
the first batch.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class Executor(Protocol):
    """What an engine must implement (see ``_NumpyExecutor`` /
    ``_DeviceExecutor`` in ``repro.synth.executor`` for references).

    All three methods must be bit-identical to the numpy host fold on
    every reachable input — ``repro.check``'s miter passes and the
    engine-equivalence tests hold engines to that."""

    def apply_codes(self, codes: np.ndarray) -> np.ndarray:
        """(B, n_inputs) int codes -> (B, n_out_neurons) int64 codes."""
        ...

    def classify_codes(self, codes: np.ndarray,
                       n_classes: int) -> np.ndarray:
        """(B, n_inputs) int codes -> (B,) int32 argmax labels."""
        ...

    def classify_packed(self, pi_words: np.ndarray, n_rows: int,
                        n_classes: int) -> np.ndarray:
        """(n_pi_wires, W) uint32 packed bitplanes -> (n_rows,) int32
        argmax labels (the serve-aggregation hot path)."""
        ...


ExecutorFactory = Callable[..., Executor]


class UnknownEngineError(KeyError):
    """Raised for an ``engine=`` name with no registered executor."""

    def __init__(self, name: str, known: Tuple[str, ...]):
        self.name = name
        self.known = tuple(known)
        super().__init__(
            f"unknown bitplane engine {name!r} (registered engines: "
            f"{', '.join(self.known) if self.known else '<none>'})")

    def __str__(self) -> str:   # KeyError str() would quote the message
        return self.args[0]


_REGISTRY: Dict[str, ExecutorFactory] = {}


def register(name: str, factory: Optional[ExecutorFactory] = None):
    """Bind ``name`` to an executor factory (idempotent re-bind wins).

    Usable directly — ``register("x", build)`` — or as a decorator —
    ``@register("x")``. The factory is called lazily, on the first
    batch through a ``BitplaneNetwork`` configured with that engine.
    """
    if factory is None:
        def _bind(f: ExecutorFactory) -> ExecutorFactory:
            _REGISTRY[name] = f
            return f
        return _bind
    _REGISTRY[name] = factory
    return factory


def get(name: str) -> ExecutorFactory:
    """Factory for a registered engine; ``UnknownEngineError`` if not."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownEngineError(name, names()) from None


def names() -> Tuple[str, ...]:
    """Registered engine names, sorted (for CLIs and error messages)."""
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Built-in engines (factories import lazily: executor.py imports us)
# ---------------------------------------------------------------------------

@register("numpy")
def _numpy_engine(bitnet, interpret=None, spec=None):
    from .executor import _NumpyExecutor
    return _NumpyExecutor(bitnet, interpret=interpret, spec=spec)


@register("pallas")
def _pallas_engine(bitnet, interpret=None, spec=None):
    from .executor import _PallasExecutor
    return _PallasExecutor(bitnet, interpret=interpret, spec=spec)


@register("pallas-streamed")
def _streamed_engine(bitnet, interpret=None, spec=None):
    from .executor import _StreamedExecutor
    return _StreamedExecutor(bitnet, interpret=interpret, spec=spec)
