"""Technology mapping: depth-optimal k-feasible-cut covering into k-LUTs.

FlowMap-style flow on the priority-cut sets from ``cuts.py``:

  1. *Depth pass* — arrival times computed during cut enumeration give
     each node its depth-optimal cut (exact for the cuts kept; the
     priority scheme keeps the best-depth cut per node by construction).
  2. *Area recovery* — with the network depth fixed as the required time
     at the outputs, repeated passes re-select, for every node, the
     min-area-flow cut that still meets the node's required time, then
     re-extract the cover. Nodes off the critical path trade depth slack
     for LUT sharing — the classic area-flow recovery loop.
  3. *Cover extraction* — walk from the outputs through chosen cuts;
     every visited node becomes one LUT whose truth table is the cut
     function (computed exactly from the AIG cone).

The result is a ``MappedNetwork``: the measured LUT count / depth that
``core.lutmap``'s analytic model only estimates, and the executable form
behind the bitplane inference path and the Verilog emitter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.lutcost import LUT_K, MapReport

from .aig import AIG, lit_var
from .cuts import Cut, enumerate_cuts


@dataclasses.dataclass(frozen=True)
class MappedLUT:
    root: int                   # AIG node id this LUT implements
    leaves: Tuple[int, ...]     # AIG node ids (PIs or other LUT roots)
    tt: int                     # 2^len(leaves)-bit truth table (python int)


@dataclasses.dataclass
class MappedNetwork:
    """A k-LUT cover of an AIG. ``outputs`` are AIG literals whose vars
    are PIs, LUT roots, or the constant node 0."""

    n_pis: int
    k: int
    luts: List[MappedLUT]       # topological order (leaves before roots)
    outputs: List[int]

    @property
    def n_luts(self) -> int:
        return len(self.luts)

    def levels(self) -> Dict[int, int]:
        """LUT level per root node id (PIs/const are level 0)."""
        lvl: Dict[int, int] = {0: 0}
        for p in range(1, self.n_pis + 1):
            lvl[p] = 0
        for l in self.luts:
            lvl[l.root] = 1 + max((lvl[x] for x in l.leaves), default=0)
        return lvl

    @property
    def depth(self) -> int:
        lvl = self.levels()
        return max((lvl[lit_var(o)] for o in self.outputs), default=0)

    def report(self, ffs: int = 0) -> MapReport:
        """Measured LUTs/depth as a ``core.lutcost.MapReport`` so the
        structural numbers aggregate with the analytic cost model."""
        return MapReport(self.n_luts, self.depth, ffs)


def _extract_cover(aig: AIG, choice: List[Optional[Cut]],
                   ) -> List[MappedLUT]:
    """Cover = transitive closure of chosen cuts from the outputs down."""
    needed: List[int] = []
    seen = set()
    stack = [lit_var(o) for o in aig.outputs]
    while stack:
        n = stack.pop()
        if n in seen or not aig.is_and(n):
            continue
        seen.add(n)
        needed.append(n)
        stack.extend(choice[n].leaves)
    luts = []
    for n in sorted(needed):        # node ids ascend topologically
        cut = choice[n]
        luts.append(MappedLUT(n, cut.leaves, aig.cut_tt(n, cut.leaves)))
    return luts


def map_aig(aig: AIG, k: int = LUT_K, n_cuts: int = 8,
            area_passes: int = 2) -> MappedNetwork:
    cuts, arrival, _ = enumerate_cuts(aig, k=k, n_cuts=n_cuts)
    n = aig.n_nodes

    # ---- 1. depth-optimal choice (best cut is sorted first; skip the
    # trivial self-cut appended at the end of each list) ----
    def real_cuts(node: int) -> List[Cut]:
        return [c for c in cuts[node] if c.leaves != (node,)]

    choice: List[Optional[Cut]] = [None] * n
    for node in range(aig.n_pis + 1, n):
        choice[node] = real_cuts(node)[0]

    luts = _extract_cover(aig, choice)

    # ---- 2. area recovery under required times ----
    req_total = max((arrival[lit_var(o)] for o in aig.outputs), default=0)
    for _ in range(area_passes):
        # required times over the current cover
        req = [None] * n
        for o in aig.outputs:
            v = lit_var(o)
            req[v] = req_total
        for l in reversed(luts):
            r = req[l.root]
            if r is None:
                continue
            for x in l.leaves:
                rx = r - 1
                if req[x] is None or rx < req[x]:
                    req[x] = rx
        # cover references (how many chosen LUTs read each node)
        refs = [0] * n
        for l in luts:
            for x in l.leaves:
                refs[x] += 1
        for o in aig.outputs:
            refs[lit_var(o)] += 1
        # re-select: min-area cut meeting the required time, where leaf
        # arrivals are recomputed under the *new* selection (ascending ids
        # = topological order, so leaves are final when a node is visited).
        # Area score discounts leaves already referenced by the cover.
        new_choice: List[Optional[Cut]] = [None] * n
        new_arr = [0] * n
        for node in range(aig.n_pis + 1, n):
            limit = req[node] if req[node] is not None else req_total
            best, best_score = None, None
            fallback, fallback_d = None, None
            for c in real_cuts(node):
                d = 1 + max((new_arr[x] for x in c.leaves), default=0)
                if fallback_d is None or d < fallback_d:
                    fallback, fallback_d = c, d
                if d > limit:
                    continue
                score = (sum(1.0 / max(1, refs[x])
                             for x in c.leaves if aig.is_and(x)),
                         c.aflow, len(c.leaves))
                if best_score is None or score < best_score:
                    best, best_score = c, score
            if best is None:        # slack exhausted: take the fastest cut
                best = fallback
            new_choice[node] = best
            new_arr[node] = 1 + max((new_arr[x] for x in best.leaves),
                                    default=0)
        choice = new_choice
        luts = _extract_cover(aig, choice)

    return MappedNetwork(aig.n_pis, k, luts, list(aig.outputs))
