"""Public wrapper: bipolar matmul with packing + padding plumbing."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..spec import DEFAULT_SPEC, KernelSpec
from .ref import pack_bipolar
from .xnor_popcount import (DEFAULT_BB, DEFAULT_BN, DEFAULT_BW,
                            xnor_matmul_pallas)


@partial(jax.jit, static_argnames=("interpret", "spec"))
def xnor_matmul(x: jax.Array, w: jax.Array,
                interpret: Optional[bool] = None,
                spec: Optional[KernelSpec] = None) -> jax.Array:
    """Bipolar (±1) matmul: x (B, n) @ w (N, n)^T -> (B, N) int32.

    Packs both operands, pads every axis to kernel block multiples, and
    un-pads the result.
    """
    interpret = (DEFAULT_SPEC if spec is None
                 else spec).resolve_interpret(interpret)
    B, n = x.shape
    N = w.shape[0]
    xp = pack_bipolar(x)
    wp = pack_bipolar(w)

    def pad(a, axis, mult):
        p = (-a.shape[axis]) % mult
        if p == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, p)
        return jnp.pad(a, widths)

    bb = min(DEFAULT_BB, max(8, B))
    bn = min(DEFAULT_BN, max(8, N))
    bw = min(DEFAULT_BW, xp.shape[1])
    xp = pad(pad(xp, 0, bb), 1, bw)
    wp = pad(pad(wp, 0, bn), 1, bw)
    out = xnor_matmul_pallas(xp, wp, n, block_b=bb, block_n=bn, block_w=bw,
                             interpret=interpret)
    return out[:B, :N]
