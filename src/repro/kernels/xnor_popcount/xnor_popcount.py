"""Pallas kernel: bit-packed bipolar (±1) matmul via XNOR + popcount.

For bipolar vectors a, b in {-1, +1}^n packed as bits (1 ⇔ +1):

    a · b = n - 2 * popcount(bits(a) XOR bits(b))

This is the FPGA XNOR-gate MAC adapted to the TPU: 32 MACs collapse into
one uint32 XOR + popcount on the VPU. Weights arrive pre-packed; the
kernel tiles (batch × out) and loops the packed contraction dimension in
VMEM-sized chunks with an int32 accumulator.

Tiling: grid (B/bB, N/bN, W/bW); accumulation across the W axis uses the
revisiting-output pattern (out block indexed only by (i, j)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 128
DEFAULT_BN = 128
DEFAULT_BW = 128   # packed words per step = 4096 binary features


def _popcount_u32(v: jax.Array) -> jax.Array:
    """Branch-free SWAR popcount on uint32 lanes."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(x_ref, w_ref, out_ref, *, n_features: int, n_w_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]          # (bB, bW) uint32
    w = w_ref[...]          # (bN, bW) uint32
    # mismatch popcount: (bB, bN)
    xor = x[:, None, :] ^ w[None, :, :]
    mism = jnp.sum(_popcount_u32(xor), axis=-1, dtype=jnp.int32)
    out_ref[...] += mism

    @pl.when(k == n_w_steps - 1)
    def _fin():
        # dot = n_features - 2 * mismatches (padding words are zero in both
        # operands -> XOR 0 -> no mismatch contribution).
        out_ref[...] = n_features - 2 * out_ref[...]


@functools.partial(
    jax.jit, static_argnames=("n_features", "block_b", "block_n", "block_w",
                              "interpret"))
def xnor_matmul_pallas(x_packed: jax.Array, w_packed: jax.Array,
                       n_features: int,
                       block_b: int = DEFAULT_BB, block_n: int = DEFAULT_BN,
                       block_w: int = DEFAULT_BW,
                       interpret: bool = True) -> jax.Array:
    """x_packed: (B, W) uint32; w_packed: (N, W) uint32 -> (B, N) int32."""
    B, W = x_packed.shape
    N, W2 = w_packed.shape
    assert W == W2
    assert B % block_b == 0 and N % block_n == 0 and W % block_w == 0

    grid = (B // block_b, N // block_n, W // block_w)
    return pl.pallas_call(
        functools.partial(_kernel, n_features=n_features,
                          n_w_steps=W // block_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_w), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_n, block_w), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=interpret,
    )(x_packed, w_packed)
