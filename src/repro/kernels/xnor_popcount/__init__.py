from .ops import xnor_matmul  # noqa: F401
from .ref import pack_bipolar, xnor_matmul_ref  # noqa: F401
