"""Pure-jnp oracle + packing helpers for the xnor_popcount kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pack_bipolar(x: jax.Array) -> jax.Array:
    """(±1)-valued (B, n) -> bit-packed (B, ceil(n/32)) uint32 (bit ⇔ +1).

    Little-endian within each word: feature f lands in word f//32 bit f%32.
    """
    B, n = x.shape
    pad = (-n) % 32
    bits = (x > 0).astype(jnp.uint32)
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    bits = bits.reshape(B, -1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def xnor_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Float oracle: bipolar dot products. x: (B, n) ±1, w: (N, n) ±1."""
    return (x.astype(jnp.float32) @ w.astype(jnp.float32).T).astype(jnp.int32)
