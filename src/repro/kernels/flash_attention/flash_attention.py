"""Pallas kernel: flash attention forward (online softmax, VMEM-tiled).

TPU mapping of the chunked attention used by the LM at 32k+ contexts:
per (batch*head, q-block) the kernel streams KV blocks through VMEM,
maintaining running (max, sum, acc) in f32 scratch — the HBM traffic is
O(Sq*dh + Sk*dh) instead of O(Sq*Sk), and the MXU sees (bq x dh x bk)
matmuls with 128-aligned dims.

Grid: (B*H, Sq/bq, Sk/bk); the kv axis revisits the same output block
(accumulation pattern) with scratch carrying the softmax state. Causal
and sliding-window masks are applied in-block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            n_k: int, sk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                  # (bq, dh)
    k = k_ref[0]                  # (bk, dh)
    v = v_ref[0]                  # (bk, dh)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = iq * bq + jnp.arange(bq)
    kpos = ik * bk + jnp.arange(bk)
    mask = kpos[None, :] < sk
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _fin():
        out_ref[0] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "true_sk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, window: int = 0,
                           block_q: int = DEFAULT_BQ,
                           block_k: int = DEFAULT_BK,
                           true_sk: int = 0,
                           interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh), pre-padded to block multiples.

    ``true_sk`` = KV length before padding (padded slots are masked)."""
    bh, sq, dh = q.shape
    sk_pad = k.shape[1]
    sk = true_sk or sk_pad
    assert sq % block_q == 0 and sk_pad % block_k == 0
    n_k = sk_pad // block_k
    grid = (bh, sq // block_q, n_k)
    scale = 1.0 / math.sqrt(dh)

    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, bq=block_q, bk=block_k,
                          n_k=n_k, sk=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running sum
            pltpu.VMEM((block_q, dh), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
