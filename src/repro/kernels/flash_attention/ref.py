"""Pure-jnp oracle for the flash_attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh) -> (BH, Sq, dh)."""
    sq, sk = q.shape[1], k.shape[1]
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)
