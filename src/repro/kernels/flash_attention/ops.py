"""Public wrapper: multi-head (B, S, H, dh) plumbing + padding."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..spec import DEFAULT_SPEC, KernelSpec
from .flash_attention import DEFAULT_BK, DEFAULT_BQ, flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "window", "interpret", "spec"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    interpret: Optional[bool] = None,
                    spec: Optional[KernelSpec] = None) -> jax.Array:
    """q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh) with H % KV == 0.

    GQA handled by repeating KV head indices into the flattened (B*H)
    leading dim (no materialised repeat: gather of head slices).
    """
    interpret = (DEFAULT_SPEC if spec is None
                 else spec).resolve_interpret(interpret)
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kf = k.transpose(0, 2, 1, 3)                      # (B, KV, Sk, dh)
    kf = jnp.repeat(kf, rep, axis=1).reshape(b * h, sk, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1
                    ).reshape(b * h, sk, dh)

    bq = min(DEFAULT_BQ, max(8, sq))
    bk = min(DEFAULT_BK, max(8, sk))

    def pad(a, mult):
        p = (-a.shape[1]) % mult
        if p == 0:
            return a
        return jnp.pad(a, ((0, 0), (0, p), (0, 0)))

    qp, kp, vp = pad(qf, bq), pad(kf, bk), pad(vf, bk)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_k=bk, true_sk=sk,
                                 interpret=interpret)
    out = out[:, :sq].reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
    return out
