"""Pallas kernel: truth-table-lookup layer.

The FPGA maps each neuron to a LUT; the TPU analogue keeps each neuron's
2^(K·b) truth table resident in VMEM and evaluates a batch of inputs as

    rows[b, j] = sum_k codes[b, idx[j, k]] * n_levels^k     (bit-pack)
    out[b, j]  = tables[j, rows[b, j]]                      (VMEM gather)

Tiling: grid (batch_blocks, neuron_blocks). The code block carries the
*full* input width (logic-layer widths are small — JSC layers are <= a
few hundred codes), while neurons and their tables are tiled so the
per-step VMEM working set is

    bB * N_in * 4  +  bN * (K * 4 + R * 4)  +  bB * bN * 4   bytes,

which for the default bB=128, bN=128, K<=7, R<=2^14 stays well under
VMEM (~2 MiB at R=4096). Lane alignment: bB multiple of 8, bN multiple
of 128 where the caller's shapes allow (ops.py pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 128   # batch tile (sublane-aligned)
DEFAULT_BN = 128   # neuron tile (lane-aligned)


def _kernel(codes_ref, idx_ref, tables_ref, out_ref, *, n_levels: int,
            fanin: int):
    codes = codes_ref[...]            # (bB, N_in) int32
    idx = idx_ref[...]                # (bN, K)    int32
    tables = tables_ref[...]          # (bN, R)    int32

    # bit-pack: rows[b, j] = sum_k codes[b, idx[j, k]] * n_levels^k
    bB = codes.shape[0]
    bN = idx.shape[0]
    rows = jnp.zeros((bB, bN), jnp.int32)
    for k in range(fanin):           # K is tiny and static -> unrolled
        col = idx[:, k]              # (bN,)
        gathered = jnp.take(codes, col, axis=1)      # (bB, bN)
        rows = rows + gathered * (n_levels ** k)

    # table gather: out[b, j] = tables[j, rows[b, j]]
    out = jnp.take_along_axis(tables, rows.T, axis=1).T  # (bB, bN)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_levels", "fanin", "block_b", "block_n", "interpret"))
def lut_layer_pallas(codes: jax.Array, idx: jax.Array, tables: jax.Array,
                     n_levels: int, fanin: int,
                     block_b: int = DEFAULT_BB, block_n: int = DEFAULT_BN,
                     interpret: bool = True) -> jax.Array:
    """codes: (B, N_in) int32; idx: (N, K) int32; tables: (N, R) int32.

    Shapes must be pre-padded to multiples of the block sizes (ops.py
    handles padding/unpadding).
    """
    B, n_in = codes.shape
    N, K = idx.shape
    R = tables.shape[1]
    assert B % block_b == 0 and N % block_n == 0, (B, N, block_b, block_n)

    grid = (B // block_b, N // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, n_levels=n_levels, fanin=fanin),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, K), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, R), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.int32),
        interpret=interpret,
    )(codes, idx, tables)
