"""Pure-jnp oracle for the lut_layer kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_layer_ref(codes: jax.Array, idx: jax.Array, tables: jax.Array,
                  n_levels: int) -> jax.Array:
    """codes: (B, N_in) int; idx: (N, K); tables: (N, R). -> (B, N) int32."""
    codes = codes.astype(jnp.int32)
    tables = tables.astype(jnp.int32)
    gathered = codes[:, idx]                                  # (B, N, K)
    k = idx.shape[1]
    weights = jnp.asarray([n_levels ** i for i in range(k)], jnp.int32)
    rows = jnp.sum(gathered * weights, axis=-1)               # (B, N)
    return jax.vmap(lambda t, r: t[r], in_axes=(0, 1), out_axes=1)(
        tables, rows)
