from .ops import lut_layer  # noqa: F401
from .ref import lut_layer_ref  # noqa: F401
