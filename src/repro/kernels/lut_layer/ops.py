"""Public jit'd wrapper for the lut_layer Pallas kernel (pads + unpads)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..spec import DEFAULT_SPEC, KernelSpec
from .lut_layer import DEFAULT_BB, DEFAULT_BN, lut_layer_pallas


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("n_levels", "interpret", "spec"))
def lut_layer(codes: jax.Array, idx: jax.Array, tables: jax.Array,
              n_levels: int, interpret: Optional[bool] = None,
              spec: Optional[KernelSpec] = None) -> jax.Array:
    """Truth-table layer: (B, N_in) codes -> (B, N) output codes."""
    interpret = (DEFAULT_SPEC if spec is None
                 else spec).resolve_interpret(interpret)
    B, _ = codes.shape
    N, K = idx.shape
    bb = min(DEFAULT_BB, max(8, B))
    bn = min(DEFAULT_BN, max(128, N)) if N >= 128 else N
    codes_p = _pad_to(codes.astype(jnp.int32), 0, bb)
    idx_p = _pad_to(idx.astype(jnp.int32), 0, bn)
    tables_p = _pad_to(tables.astype(jnp.int32), 0, bn)
    out = lut_layer_pallas(codes_p, idx_p, tables_p, n_levels, K,
                           block_b=bb, block_n=bn, interpret=interpret)
    return out[:B, :N]
