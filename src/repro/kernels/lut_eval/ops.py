"""Public jit'd wrapper for the lut_eval Pallas kernel (pads + unpads)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .lut_eval import DEFAULT_BW, lut_eval_pallas


def default_interpret() -> bool:
    """Interpret on anything but a real TPU (same contract as aig_sim:
    CPU CI runs the kernel through the Pallas interpreter, a TPU runs
    the compiled Mosaic kernel)."""
    return jax.default_backend() != "tpu"


def lut_eval(pi_words: np.ndarray, leaf_idx: np.ndarray,
             tt_bits: np.ndarray, out_wires: np.ndarray,
             n_pis: int, n_wires: int,
             interpret: Optional[bool] = None) -> np.ndarray:
    """Evaluate a padded mapped-netlist plan on packed words; returns
    the (n_wires + 1, W) uint32 wire plane (row n_wires is the padded
    slots' dump row).

    pi_words: (n_pis, W) uint32. Plan tensors may be level-stacked
    ((n_levels, Lw, ...), as ``compile_device_plan`` builds them) or
    already flattened to (n_slots, ...); level-major flattening is a
    topological order, so both execute identically.
    """
    pi_words = np.ascontiguousarray(pi_words, np.uint32)
    leaf_idx = np.ascontiguousarray(leaf_idx, np.int32).reshape(
        -1, np.asarray(leaf_idx).shape[-1])
    tt_bits = np.ascontiguousarray(tt_bits, np.uint32).reshape(
        -1, np.asarray(tt_bits).shape[-1])
    out_wires = np.ascontiguousarray(out_wires, np.int32).reshape(-1)
    n_slots, k = leaf_idx.shape
    w = pi_words.shape[1]
    if interpret is None:
        interpret = default_interpret()
    if n_slots == 0 or n_pis == 0 or w == 0:
        vals = np.zeros((n_wires + 1, w), np.uint32)
        vals[1: n_pis + 1] = pi_words
        return vals
    bw = min(DEFAULT_BW, max(1, w))
    pad = (-w) % bw
    if pad:
        pi_words = np.concatenate(
            [pi_words, np.zeros((n_pis, pad), np.uint32)], axis=1)
    out = lut_eval_pallas(
        jnp.asarray(pi_words.view(np.int32)), jnp.asarray(leaf_idx),
        jnp.asarray(tt_bits.view(np.int32)), jnp.asarray(out_wires),
        n_pis=n_pis, n_slots=n_slots, n_wires=n_wires, k=k,
        block_w=bw, interpret=interpret)
    return np.ascontiguousarray(np.asarray(out)[:, :w]).view(np.uint32)
