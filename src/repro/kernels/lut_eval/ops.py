"""Public jit'd wrappers for the lut_eval Pallas kernels (pad + unpad).

``lut_eval`` launches the monolithic kernel over stacked ``DevicePlan``
tensors; ``lut_eval_streamed`` launches the streamed/tiled kernel over a
``repro.synth.executor.TilePlan``. Both take an optional ``spec=``
(``repro.kernels.spec.KernelSpec``) carrying tile geometry and the
interpret pin — the shared launch surface kernels_bench, kernelprof and
the autotuner sweep.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..spec import DEFAULT_SPEC, KernelSpec, default_interpret  # noqa: F401
from .lut_eval import DEFAULT_BW, lut_eval_pallas, lut_eval_streamed_pallas


def lut_eval(pi_words: np.ndarray, leaf_idx: np.ndarray,
             tt_bits: np.ndarray, out_wires: np.ndarray,
             n_pis: int, n_wires: int,
             interpret: Optional[bool] = None,
             spec: Optional[KernelSpec] = None) -> np.ndarray:
    """Evaluate a padded mapped-netlist plan on packed words; returns
    the (n_wires + 1, W) uint32 wire plane (row n_wires is the padded
    slots' dump row).

    pi_words: (n_pis, W) uint32. Plan tensors may be level-stacked
    ((n_levels, Lw, ...), as ``compile_device_plan`` builds them) or
    already flattened to (n_slots, ...); level-major flattening is a
    topological order, so both execute identically.
    """
    spec = DEFAULT_SPEC if spec is None else spec
    pi_words = np.ascontiguousarray(pi_words, np.uint32)
    leaf_idx = np.ascontiguousarray(leaf_idx, np.int32).reshape(
        -1, np.asarray(leaf_idx).shape[-1])
    tt_bits = np.ascontiguousarray(tt_bits, np.uint32).reshape(
        -1, np.asarray(tt_bits).shape[-1])
    out_wires = np.ascontiguousarray(out_wires, np.int32).reshape(-1)
    n_slots, k = leaf_idx.shape
    w = pi_words.shape[1]
    interpret = spec.resolve_interpret(interpret)
    if n_slots == 0 or n_pis == 0 or w == 0:
        vals = np.zeros((n_wires + 1, w), np.uint32)
        vals[1: n_pis + 1] = pi_words
        return vals
    bw = spec.tile.clamp_block_w(w)
    pad = (-w) % bw
    if pad:
        pi_words = np.concatenate(
            [pi_words, np.zeros((n_pis, pad), np.uint32)], axis=1)
    out = lut_eval_pallas(
        jnp.asarray(pi_words.view(np.int32)), jnp.asarray(leaf_idx),
        jnp.asarray(tt_bits.view(np.int32)), jnp.asarray(out_wires),
        n_pis=n_pis, n_slots=n_slots, n_wires=n_wires, k=k,
        block_w=bw, interpret=interpret)
    return np.ascontiguousarray(np.asarray(out)[:, :w]).view(np.uint32)


def lut_eval_streamed(pi_words: np.ndarray, tplan,
                      gather: Optional[str] = None,
                      interpret: Optional[bool] = None,
                      spec: Optional[KernelSpec] = None) -> np.ndarray:
    """Evaluate a ``TilePlan`` on packed words through the streamed
    kernel; returns the renumbered (tplan.n_rows, W) uint32 wire plane
    (use ``tplan.out_idx`` / ``tplan.row_of_wire`` to pull outputs).

    pi_words: (n_pis, W) uint32. ``gather=None`` picks the fancy-gather
    path under the interpreter and the staged-DMA path on a real TPU
    (``lut_eval.default_gather``); ``spec.tile.block_w`` sets the word
    tile (``tile_rows`` geometry is baked into the plan itself).
    """
    from .lut_eval import default_gather

    spec = DEFAULT_SPEC if spec is None else spec
    pi_words = np.ascontiguousarray(pi_words, np.uint32)
    assert pi_words.shape[0] == tplan.n_pis, \
        (pi_words.shape, tplan.n_pis)
    w = pi_words.shape[1]
    interpret = spec.resolve_interpret(interpret)
    if gather is None:
        gather = default_gather()
    if tplan.n_tiles == 0 or tplan.n_pis == 0 or w == 0:
        vals = np.zeros((tplan.n_rows, w), np.uint32)
        vals[1: tplan.n_pis + 1] = pi_words
        return vals
    bw = spec.tile.clamp_block_w(w)
    pad = (-w) % bw
    if pad:
        pi_words = np.concatenate(
            [pi_words, np.zeros((tplan.n_pis, pad), np.uint32)], axis=1)
    out = lut_eval_streamed_pallas(
        jnp.asarray(pi_words.view(np.int32)),
        jnp.asarray(np.ascontiguousarray(tplan.tt_tiles).view(np.int32)),
        jnp.asarray(tplan.leaf_tiles), jnp.asarray(tplan.leaf_loc),
        jnp.asarray(tplan.gather_rows), jnp.asarray(tplan.out_base),
        n_pis=tplan.n_pis, n_tiles=tplan.n_tiles,
        tile_rows=tplan.tile_rows, gather_cap=tplan.gather_cap,
        n_rows=tplan.n_rows, k=tplan.k, block_w=bw, gather=gather,
        interpret=interpret)
    return np.ascontiguousarray(np.asarray(out)[:, :w]).view(np.uint32)
