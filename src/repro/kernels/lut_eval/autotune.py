"""Persisted tile-size autotuning for the streamed lut_eval kernel.

The streamed kernel has two geometry knobs — ``tile_rows`` (LUT slots
folded per step; sets plan-DMA granularity and fold batch) and
``block_w`` (packed-word tile per grid step). The best point depends on
the netlist shape (level widths, fanin mix), so the sweep is run once
per netlist and the winner persisted, keyed by the plan's existing sha1
fingerprint (``repro.check.plan_check.plan_fingerprint``) plus the jax
backend and interpret flag — a retuned TPU never poisons the CPU cache
and vice versa.

The cache file defaults to ``~/.cache/repro/lut_eval_tiles.json``
(override with ``REPRO_AUTOTUNE_CACHE``; set it to an empty string to
disable persistence). ``_StreamedExecutor`` consults ``cached_tile`` on
construction, so serving picks up a tuned shape for free; the sweep
itself (``autotune_streamed``) only runs when explicitly invoked —
``benchmarks/kernels_bench.py --autotune`` or a direct call — because
it measures every candidate end to end.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, Optional, Sequence, Tuple

# (tile_rows, block_w) sweep grid: tile_rows trades plan-DMA count
# against fold width; block_w trades grid steps against VMEM per step.
DEFAULT_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (16, 128), (32, 128), (64, 128), (128, 128),
    (32, 256), (64, 256),
)

_ENV = "REPRO_AUTOTUNE_CACHE"


def cache_path() -> Optional[str]:
    """Cache file path, or ``None`` when persistence is disabled."""
    p = os.environ.get(_ENV)
    if p is not None:
        return p or None
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "lut_eval_tiles.json")


def _load(path: str) -> Dict[str, dict]:
    try:
        with open(path) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}


def _store(path: str, data: Dict[str, dict]) -> None:
    """Atomic write: unique temp file in the target directory, then
    ``os.replace``.  A pid-suffixed temp name is NOT enough — two
    threads of one process (or a recycled pid) would interleave writes
    into the same temp file; ``mkstemp`` gives each writer its own."""
    try:
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
    except OSError:
        pass                       # cache is advisory, never fatal


def _key(fingerprint: str, backend: str, interpret: bool) -> str:
    return f"{fingerprint}:{backend}:{'interp' if interpret else 'mosaic'}"


def lookup(fingerprint: str, backend: str,
           interpret: bool) -> Optional[Tuple[int, int]]:
    """Persisted (tile_rows, block_w) for a plan fingerprint, if any."""
    path = cache_path()
    if path is None:
        return None
    ent = _load(path).get(_key(fingerprint, backend, interpret))
    if not ent:
        return None
    try:
        return int(ent["tile_rows"]), int(ent["block_w"])
    except (KeyError, TypeError, ValueError):
        return None


def record(fingerprint: str, backend: str, interpret: bool,
           tile_rows: int, block_w: int, us: float) -> None:
    """Persist a tuned shape (last write wins)."""
    path = cache_path()
    if path is None:
        return
    data = _load(path)
    data[_key(fingerprint, backend, interpret)] = {
        "tile_rows": int(tile_rows), "block_w": int(block_w),
        "us": float(us), "stamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    _store(path, data)


def cached_tile(dplan, interpret: bool) -> Optional[Tuple[int, int]]:
    """Tuned (tile_rows, block_w) for a ``DevicePlan``, if persisted."""
    import jax
    from repro.check.plan_check import plan_fingerprint   # lazy: cycle
    return lookup(plan_fingerprint(dplan), jax.default_backend(),
                  interpret)


def _time_us(fn, iters: int = 5) -> float:
    import jax
    jax.block_until_ready(fn())          # compile / first trace
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def autotune_streamed(bitnet, pi_words,
                      candidates: Sequence[Tuple[int, int]]
                      = DEFAULT_CANDIDATES,
                      iters: int = 5, interpret: Optional[bool] = None,
                      persist: bool = True) -> Tuple[int, int, float]:
    """Sweep (tile_rows, block_w) over a real batch and persist the
    winner; returns (tile_rows, block_w, us).

    ``bitnet``: a ``BitplaneNetwork``; ``pi_words``: (n_pi_wires, W)
    uint32 packed bitplanes shaped like the serving batch (the tuned
    shape is only as good as the batch it was measured on).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.check.plan_check import plan_fingerprint   # lazy: cycle
    from repro.kernels.spec import DEFAULT_SPEC
    from repro.synth.executor import _StreamedExecutor, compile_device_plan

    words = jnp.asarray(
        np.ascontiguousarray(pi_words, np.uint32).view(np.int32))
    best: Optional[Tuple[int, int, float]] = None
    for tile_rows, block_w in candidates:
        ex = _StreamedExecutor(
            bitnet, interpret=interpret,
            spec=DEFAULT_SPEC.with_tile(tile_rows=tile_rows,
                                        block_w=block_w))
        run = jax.jit(ex._eval_words)
        us = _time_us(lambda: run(words), iters=iters)
        if best is None or us < best[2]:
            best = (tile_rows, block_w, us)
    assert best is not None
    if persist:
        dp = compile_device_plan(bitnet.mapped, bitnet._plan)
        record(plan_fingerprint(dp), jax.default_backend(),
               DEFAULT_SPEC.resolve_interpret(interpret),
               best[0], best[1], best[2])
    return best
