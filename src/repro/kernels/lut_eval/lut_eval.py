"""Pallas kernel: whole-netlist evaluation of a mapped k-LUT network.

The mapped netlist, levelized and padded to a uniform level width
(``repro.synth.executor.compile_device_plan``), is a linear program of
LUT evaluations: slot i gathers its k leaf planes from a dense wire
buffer and folds its 2^k-entry INIT vector over them Shannon-cofactor
style (k select steps, each one AND/ANDN/OR over the whole word tile).
Because every leaf of a LUT lives on a strictly earlier level, the
level-major slot walk is a topological order and a single ``fori_loop``
evaluates the entire network with the wire plane resident in VMEM as
the kernel's output block.

Layout mirrors ``kernels/aig_sim``: words pack 32 samples per int32
lane, the grid tiles the word (sample) axis, leaf/output wire indices
sit in SMEM so the per-slot address arithmetic is scalar, and the INIT
masks (row r = 0 or ~0 for truth-table bit r) are a VMEM-resident
(n_slots, 2^k) table loaded one row per slot. Padded slots read the
constant-0 wire and write a dump row one past the last real wire, so
the loop body is branch-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BW = 128   # word (packed-sample) tile, lane-aligned


def _kernel(leaf_ref, ow_ref, tt_ref, pis_ref, out_ref, *,
            n_pis: int, n_slots: int, k: int):
    bw = pis_ref.shape[1]
    n_tt = tt_ref.shape[1]
    out_ref[0, :] = jnp.zeros((bw,), jnp.int32)          # const-0 row
    out_ref[1: n_pis + 1, :] = pis_ref[...]

    def body(i, carry):
        # INIT masks for slot i, broadcast over the word tile
        tt = pl.load(tt_ref, (pl.ds(i, 1), slice(None)))         # (1, n_tt)
        state = jnp.broadcast_to(tt.reshape(n_tt, 1), (n_tt, bw))
        size = n_tt
        for j in range(k - 1, -1, -1):   # static unroll: Shannon fold
            half = size // 2
            sel = pl.load(out_ref,
                          (pl.ds(leaf_ref[i, j], 1), slice(None)))  # (1, bw)
            state = (state[:half] & ~sel) | (state[half:size] & sel)
            size = half
        pl.store(out_ref, (pl.ds(ow_ref[i], 1), slice(None)), state)
        return carry

    jax.lax.fori_loop(0, n_slots, body, 0)


@functools.partial(
    jax.jit,
    static_argnames=("n_pis", "n_slots", "n_wires", "k", "block_w",
                     "interpret"))
def lut_eval_pallas(pi_words: jax.Array, leaf_idx: jax.Array,
                    tt_bits: jax.Array, out_wires: jax.Array,
                    n_pis: int, n_slots: int, n_wires: int, k: int,
                    block_w: int = DEFAULT_BW,
                    interpret: bool = True) -> jax.Array:
    """pi_words: (n_pis, W) int32 packed samples; leaf_idx: (n_slots, k)
    int32 wire indices; tt_bits: (n_slots, 2^k) int32 INIT masks;
    out_wires: (n_slots,) int32 wire written per slot. Returns the full
    wire plane (n_wires + 1, W) int32 — row 0 is const-0, rows
    1..n_pis echo the inputs, row n_wires is the padded slots' dump."""
    _, w = pi_words.shape
    assert w % block_w == 0, (w, block_w)
    grid = (w // block_w,)
    return pl.pallas_call(
        functools.partial(_kernel, n_pis=n_pis, n_slots=n_slots, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # leaf_idx
            pl.BlockSpec(memory_space=pltpu.SMEM),               # out_wires
            pl.BlockSpec((n_slots, 1 << k), lambda i: (0, 0)),   # tt masks
            pl.BlockSpec((n_pis, block_w), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_wires + 1, block_w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_wires + 1, w), jnp.int32),
        interpret=interpret,
    )(leaf_idx, out_wires, tt_bits, pi_words)
