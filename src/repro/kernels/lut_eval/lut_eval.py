"""Pallas kernels: whole-netlist evaluation of a mapped k-LUT network.

Two kernels share the Shannon-cofactor fold (slot i gathers its k leaf
planes from the wire buffer and folds its 2^k-entry INIT vector over
them — k select steps, each one AND/ANDN/OR over the whole word tile):

``lut_eval_pallas`` — the original monolithic walk: the whole wire
plane is the kernel's VMEM output block and a ``fori_loop`` evaluates
one slot per step. Simple, but every slot pays a dynamic row store
against the full plane, and the plane must fit VMEM — both of which
cap it far below the jnp scan oracle and below JSC-M/L-scale netlists.

``lut_eval_streamed_pallas`` — the streamed, tiled, double-buffered
rebuild. The wire plane lives in HBM (``memory_space=ANY``) with rows
renumbered level-major (``repro.synth.executor.compile_tile_plan``) so
every tile of ``T`` slots writes one contiguous row band. The per-tile
plan tensors (INIT masks + leaf indices) stream HBM→VMEM through
two-slot scratch buffers: tile ``t+1``'s DMAs start before tile ``t``'s
fold, so the plan fetch hides behind compute (the double-buffering
idiom of the sglang-jax quad-buffered flash-attention bench). The fold
itself is batched over the whole tile — one ``(T, 2^k, bw)`` select
cascade instead of ``T`` scalar-indexed row walks — and the result is
stored as a single contiguous band write.

Leaf gathering is the one mode-dependent step (``gather=``):

  * ``"fancy"`` — one vector gather ``plane[leaf_rows]`` per tile.
    Interpreter-only: Mosaic has no arbitrary-row vector gather, but
    the Pallas interpreter (and therefore every CPU benchmark row and
    CI test in this repo) executes it as a single jnp gather, which is
    where the measured ~30x win over the monolithic kernel comes from.
  * ``"dma"`` — the TPU-shaped path: each tile's unique leaf rows are
    staged HBM→VMEM by per-row async copies into a two-slot stage
    buffer and slots fold from stage-local indices (SMEM scalars).
    Bit-identical to ``"fancy"`` (the test suite runs both); used by
    default on a real TPU backend.

Levelization guarantees every leaf lives on a strictly earlier level,
so tile-order execution is a topological order; padded slots inside a
band read the constant-0 row with all-zero INIT masks and write 0 to
their own (never-read) pad row — no dump-row branch needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BW = 128   # word (packed-sample) tile, lane-aligned

GATHER_MODES = ("fancy", "dma")


def default_gather() -> str:
    """``"fancy"`` under the interpreter, ``"dma"`` on a real TPU."""
    return "fancy" if jax.default_backend() != "tpu" else "dma"


# ---------------------------------------------------------------------------
# Legacy monolithic kernel (VMEM-resident wire plane, one slot per step)
# ---------------------------------------------------------------------------

def _kernel(leaf_ref, ow_ref, tt_ref, pis_ref, out_ref, *,
            n_pis: int, n_slots: int, k: int):
    bw = pis_ref.shape[1]
    n_tt = tt_ref.shape[1]
    out_ref[0, :] = jnp.zeros((bw,), jnp.int32)          # const-0 row
    out_ref[1: n_pis + 1, :] = pis_ref[...]

    def body(i, carry):
        # INIT masks for slot i, broadcast over the word tile
        tt = pl.load(tt_ref, (pl.ds(i, 1), slice(None)))         # (1, n_tt)
        state = jnp.broadcast_to(tt.reshape(n_tt, 1), (n_tt, bw))
        size = n_tt
        for j in range(k - 1, -1, -1):   # static unroll: Shannon fold
            half = size // 2
            sel = pl.load(out_ref,
                          (pl.ds(leaf_ref[i, j], 1), slice(None)))  # (1, bw)
            state = (state[:half] & ~sel) | (state[half:size] & sel)
            size = half
        pl.store(out_ref, (pl.ds(ow_ref[i], 1), slice(None)), state)
        return carry

    jax.lax.fori_loop(0, n_slots, body, 0)


@functools.partial(
    jax.jit,
    static_argnames=("n_pis", "n_slots", "n_wires", "k", "block_w",
                     "interpret"))
def lut_eval_pallas(pi_words: jax.Array, leaf_idx: jax.Array,
                    tt_bits: jax.Array, out_wires: jax.Array,
                    n_pis: int, n_slots: int, n_wires: int, k: int,
                    block_w: int = DEFAULT_BW,
                    interpret: bool = True) -> jax.Array:
    """pi_words: (n_pis, W) int32 packed samples; leaf_idx: (n_slots, k)
    int32 wire indices; tt_bits: (n_slots, 2^k) int32 INIT masks;
    out_wires: (n_slots,) int32 wire written per slot. Returns the full
    wire plane (n_wires + 1, W) int32 — row 0 is const-0, rows
    1..n_pis echo the inputs, row n_wires is the padded slots' dump."""
    _, w = pi_words.shape
    assert w % block_w == 0, (w, block_w)
    grid = (w // block_w,)
    return pl.pallas_call(
        functools.partial(_kernel, n_pis=n_pis, n_slots=n_slots, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # leaf_idx
            pl.BlockSpec(memory_space=pltpu.SMEM),               # out_wires
            pl.BlockSpec((n_slots, 1 << k), lambda i: (0, 0)),   # tt masks
            pl.BlockSpec((n_pis, block_w), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_wires + 1, block_w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_wires + 1, w), jnp.int32),
        interpret=interpret,
    )(leaf_idx, out_wires, tt_bits, pi_words)


# ---------------------------------------------------------------------------
# Streamed, tiled, double-buffered kernel (HBM wire plane, T slots/step)
# ---------------------------------------------------------------------------

def _tile_fold(tt_tile, ins, *, T: int, n_tt: int, k: int, bw: int):
    """Batched Shannon fold of one tile: tt_tile (T, 2^k) INIT masks,
    ins (T, k, bw) gathered leaf planes -> (T, bw) output planes."""
    state = jnp.broadcast_to(tt_tile[:, :, None], (T, n_tt, bw))
    size = n_tt
    for j in range(k - 1, -1, -1):
        half = size // 2
        sel = ins[:, j:j + 1, :]
        state = (state[:, :half] & ~sel) | (state[:, half:size] & sel)
        size = half
    return state[:, 0, :]


def _streamed_kernel(ob_ref, pi_ref, tt_hbm, leaf_hbm, loc_hbm, grow_hbm,
                     plane_ref, *, n_pis: int, n_tiles: int, T: int,
                     G: int, k: int, bw: int, gather: str):
    n_tt = 1 << k
    col = pl.program_id(0) * bw
    plane_ref[0, pl.ds(col, bw)] = jnp.zeros((bw,), jnp.int32)
    plane_ref[pl.ds(1, n_pis), pl.ds(col, bw)] = pi_ref[...]

    if gather == "fancy":
        def body(ttbuf, lfbuf, tt_sem, lf_sem):
            def tt_dma(slot, t):
                return pltpu.make_async_copy(tt_hbm.at[t], ttbuf.at[slot],
                                             tt_sem.at[slot])

            def lf_dma(slot, t):
                return pltpu.make_async_copy(leaf_hbm.at[t], lfbuf.at[slot],
                                             lf_sem.at[slot])

            tt_dma(0, 0).start()
            lf_dma(0, 0).start()

            def tile_step(t, carry):
                slot = jax.lax.rem(t, 2)
                nxt = jax.lax.rem(t + 1, 2)

                # double buffering: tile t+1's plan tensors stream in
                # while tile t folds
                @pl.when(t + 1 < n_tiles)
                def _():
                    tt_dma(nxt, t + 1).start()
                    lf_dma(nxt, t + 1).start()

                tt_dma(slot, t).wait()
                lf_dma(slot, t).wait()
                leaves = lfbuf[slot]                        # (T, k) rows
                ins = plane_ref[leaves, pl.ds(col, bw)]     # (T, k, bw)
                out = _tile_fold(ttbuf[slot], ins,
                                 T=T, n_tt=n_tt, k=k, bw=bw)
                plane_ref[pl.ds(ob_ref[t], T), pl.ds(col, bw)] = out
                return carry

            jax.lax.fori_loop(0, n_tiles, tile_step, 0)

        pl.run_scoped(body,
                      ttbuf=pltpu.VMEM((2, T, n_tt), jnp.int32),
                      lfbuf=pltpu.VMEM((2, T, k), jnp.int32),
                      tt_sem=pltpu.SemaphoreType.DMA((2,)),
                      lf_sem=pltpu.SemaphoreType.DMA((2,)))
        return

    # gather == "dma": stage each tile's unique leaf rows HBM->VMEM by
    # per-row async copies; slots fold from stage-local SMEM indices.
    def body(ttbuf, locbuf, growbuf, stage, outbuf,
             tt_sem, loc_sem, grow_sem, stage_sem, st_sem):
        def tt_dma(slot, t):
            return pltpu.make_async_copy(tt_hbm.at[t], ttbuf.at[slot],
                                         tt_sem.at[slot])

        def loc_dma(slot, t):
            return pltpu.make_async_copy(loc_hbm.at[t], locbuf.at[slot],
                                         loc_sem.at[slot])

        def grow_dma(slot, t):
            return pltpu.make_async_copy(grow_hbm.at[t], growbuf.at[slot],
                                         grow_sem.at[slot])

        def stage_row_dma(slot, g):
            row = growbuf[slot, g]
            return pltpu.make_async_copy(
                plane_ref.at[pl.ds(row, 1), pl.ds(col, bw)],
                stage.at[slot, pl.ds(g, 1)], stage_sem.at[slot])

        def issue_stage(slot):
            def start_one(g, carry):
                stage_row_dma(slot, g).start()
                return carry
            jax.lax.fori_loop(0, G, start_one, 0)

        def wait_stage(slot):
            def wait_one(g, carry):
                stage_row_dma(slot, g).wait()
                return carry
            jax.lax.fori_loop(0, G, wait_one, 0)

        # warmup: tile 0's plan tensors, then its staged leaf rows
        tt_dma(0, 0).start()
        loc_dma(0, 0).start()
        grow_dma(0, 0).start()
        grow_dma(0, 0).wait()
        issue_stage(0)

        def tile_step(t, carry):
            slot = jax.lax.rem(t, 2)
            nxt = jax.lax.rem(t + 1, 2)

            @pl.when(t + 1 < n_tiles)
            def _():
                tt_dma(nxt, t + 1).start()
                loc_dma(nxt, t + 1).start()
                grow_dma(nxt, t + 1).start()

            wait_stage(slot)
            tt_dma(slot, t).wait()
            loc_dma(slot, t).wait()

            def slot_step(s, carry):
                tt_row = ttbuf[slot, s]                       # (2^k,)
                state = jnp.broadcast_to(tt_row[:, None], (n_tt, bw))
                size = n_tt
                for j in range(k - 1, -1, -1):
                    half = size // 2
                    sel = pl.load(
                        stage, (slot, pl.ds(locbuf[slot, s, j], 1),
                                slice(None)))                 # (1, bw)
                    state = ((state[:half] & ~sel)
                             | (state[half:size] & sel))
                    size = half
                pl.store(outbuf, (pl.ds(s, 1), slice(None)), state)
                return carry

            jax.lax.fori_loop(0, T, slot_step, 0)
            st = pltpu.make_async_copy(
                outbuf,
                plane_ref.at[pl.ds(ob_ref[t], T), pl.ds(col, bw)],
                st_sem)
            st.start()
            st.wait()     # band landed: tile t+1 may stage-read any row

            @pl.when(t + 1 < n_tiles)
            def _():
                grow_dma(nxt, t + 1).wait()
                issue_stage(nxt)
            return carry

        jax.lax.fori_loop(0, n_tiles, tile_step, 0)

    pl.run_scoped(body,
                  ttbuf=pltpu.VMEM((2, T, n_tt), jnp.int32),
                  locbuf=pltpu.SMEM((2, T, k), jnp.int32),
                  growbuf=pltpu.SMEM((2, G), jnp.int32),
                  stage=pltpu.VMEM((2, G, bw), jnp.int32),
                  outbuf=pltpu.VMEM((T, bw), jnp.int32),
                  tt_sem=pltpu.SemaphoreType.DMA((2,)),
                  loc_sem=pltpu.SemaphoreType.DMA((2,)),
                  grow_sem=pltpu.SemaphoreType.DMA((2,)),
                  stage_sem=pltpu.SemaphoreType.DMA((2,)),
                  st_sem=pltpu.SemaphoreType.DMA)


@functools.partial(
    jax.jit,
    static_argnames=("n_pis", "n_tiles", "tile_rows", "gather_cap",
                     "n_rows", "k", "block_w", "gather", "interpret"))
def lut_eval_streamed_pallas(pi_words: jax.Array, tt_tiles: jax.Array,
                             leaf_tiles: jax.Array, leaf_loc: jax.Array,
                             gather_rows: jax.Array, out_base: jax.Array,
                             n_pis: int, n_tiles: int, tile_rows: int,
                             gather_cap: int, n_rows: int, k: int,
                             block_w: int = DEFAULT_BW,
                             gather: str = "fancy",
                             interpret: bool = True) -> jax.Array:
    """Streamed walk over a level-major tile plan (see
    ``repro.synth.executor.compile_tile_plan`` for the tensor layout).

    pi_words: (n_pis, W) int32; tt_tiles: (n_tiles, T, 2^k) int32 INIT
    masks; leaf_tiles: (n_tiles, T, k) int32 plane-row leaf indices;
    leaf_loc / gather_rows: the stage-local remap used by the ``"dma"``
    gather mode; out_base: (n_tiles,) int32 first plane row of each
    tile's contiguous output band. Returns the renumbered wire plane
    (n_rows, W) int32 — row 0 const-0, rows 1..n_pis the inputs, then
    one band of ``T`` rows per tile (pad rows hold 0).
    """
    if gather not in GATHER_MODES:
        raise ValueError(f"unknown gather mode {gather!r} "
                         f"(expected one of {GATHER_MODES})")
    _, w = pi_words.shape
    assert w % block_w == 0, (w, block_w)
    grid = (w // block_w,)
    return pl.pallas_call(
        functools.partial(_streamed_kernel, n_pis=n_pis, n_tiles=n_tiles,
                          T=tile_rows, G=gather_cap, k=k, bw=block_w,
                          gather=gather),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),               # out_base
            pl.BlockSpec((n_pis, block_w), lambda i: (0, i)),    # pi block
            pl.BlockSpec(memory_space=pltpu.ANY),                # tt tiles
            pl.BlockSpec(memory_space=pltpu.ANY),                # leaf rows
            pl.BlockSpec(memory_space=pltpu.ANY),                # leaf_loc
            pl.BlockSpec(memory_space=pltpu.ANY),                # gather_rows
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((n_rows, w), jnp.int32),
        interpret=interpret,
    )(out_base, pi_words, tt_tiles, leaf_tiles, leaf_loc, gather_rows)
