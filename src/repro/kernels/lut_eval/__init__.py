from .ops import default_interpret, lut_eval, lut_eval_streamed  # noqa: F401
from .ref import lut_eval_gather_ref, lut_eval_ref  # noqa: F401
