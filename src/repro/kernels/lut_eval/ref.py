"""Pure-jnp oracles for the lut_eval kernel.

``lut_eval_ref`` is the bitplane analogue of the kernel: a ``lax.scan``
over the flattened slot list, each step gathering k leaf planes and
folding the slot's INIT masks (the functional mirror of the kernel's
in-place row stores). ``lut_eval_gather_ref`` is the *per-sample* path:
unpacked bits, per level one select-index build and one table gather
per slot row — the netlist equivalent of the gather inference backend,
used as the baseline the bitplane fold is benchmarked against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_eval_ref(pi_words: jax.Array, leaf_idx: jax.Array,
                 tt_bits: jax.Array, out_wires: jax.Array,
                 n_pis: int, n_wires: int) -> jax.Array:
    """pi_words: (n_pis, W) int32; leaf_idx: (n_slots, k) int32;
    tt_bits: (n_slots, 2^k) int32 masks; out_wires: (n_slots,) int32.
    Returns the (n_wires + 1, W) int32 wire plane."""
    k = leaf_idx.shape[1]
    n_tt = tt_bits.shape[1]
    w = pi_words.shape[1]
    vals = jnp.zeros((n_wires + 1, w), jnp.int32)
    vals = vals.at[1: n_pis + 1].set(pi_words.astype(jnp.int32))

    def step(vals, inp):
        leaves, tt, ow = inp
        ins = vals[leaves]                                  # (k, W)
        state = jnp.broadcast_to(tt[:, None], (n_tt, w))
        size = n_tt
        for j in range(k - 1, -1, -1):
            half = size // 2
            sel = ins[j][None, :]
            state = (state[:half] & ~sel) | (state[half:size] & sel)
            size = half
        return vals.at[ow].set(state[0]), None

    vals, _ = jax.lax.scan(
        step, vals, (leaf_idx.astype(jnp.int32), tt_bits.astype(jnp.int32),
                     out_wires.astype(jnp.int32)))
    return vals


def lut_eval_gather_ref(pi_bits: jax.Array, leaf_idx: jax.Array,
                        tt01: jax.Array, out_wires: jax.Array,
                        n_pis: int, n_wires: int) -> jax.Array:
    """Per-sample gather evaluation on *unpacked* bits.

    pi_bits: (n_pis, B) int32 {0,1}; leaf_idx: (n_levels, Lw, k);
    tt01: (n_levels, Lw, 2^k) int32 {0,1} truth-table bits;
    out_wires: (n_levels, Lw). Per level, every slot builds its select
    index from the gathered leaf bits and looks its output bit up in
    its table — one gather per slot per sample instead of the fold's
    word-parallel bitwise ops. Returns the (n_wires + 1, B) bit plane.
    """
    b = pi_bits.shape[1]
    k = leaf_idx.shape[-1]
    bits = jnp.zeros((n_wires + 1, b), jnp.int32)
    bits = bits.at[1: n_pis + 1].set(pi_bits.astype(jnp.int32))
    for lvl in range(leaf_idx.shape[0]):    # static level count
        sel = sum((bits[leaf_idx[lvl, :, j]] << j) for j in range(k))
        out = jnp.take_along_axis(tt01[lvl], sel, axis=1)   # (Lw, B)
        bits = bits.at[out_wires[lvl]].set(out)
    return bits
