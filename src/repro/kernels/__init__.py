"""Pallas TPU kernels for the compute hot-spots of the NullaNet Tiny flow.

  lut_layer     — truth-table-lookup layer (the TPU analogue of the FPGA
                  LUT fabric): bit-pack fanin codes + VMEM table gather.
  xnor_popcount — bit-packed bipolar (±1) matmul via XNOR + popcount,
                  the binary-QAT inference/training forward primitive.
  fanin_matmul  — fanin-K gather-matmul for FCP-sparse linear layers.
  aig_sim       — bit-parallel AIG simulation: the node walk of the
                  synthesis-time equivalence checker run on-chip.
  lut_eval      — whole mapped-netlist execution: the levelized,
                  width-padded k-LUT plan evaluated as Shannon-cofactor
                  folds over a VMEM-resident wire plane (the serving
                  path of ``BitplaneNetwork(engine="pallas")``).
  flash_attention — online-softmax attention (VMEM-tiled), the LM-side
                  hot-spot at 32k+ contexts (GQA via grouped heads).

Each kernel directory holds <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper with shape plumbing) and ref.py (pure-jnp
oracle used by the allclose test sweeps).

All kernels are written against TPU VMEM tiling (blocks aligned to
(8, 128) lanes where applicable) and validated on CPU with
``interpret=True``.
"""
