"""One launch-configuration surface for every kernel in ``repro.kernels``.

Each kernel directory used to grow its own ad-hoc launch kwargs
(``block_w=...``, ``interpret=...``, per-kernel VMEM assumptions),
which meant ``benchmarks/kernels_bench``, ``repro.obs.kernelprof`` and
any autotuner had to know six different call conventions. ``KernelSpec``
is the single object they sweep instead:

  * ``TileConfig`` — the geometry knobs: word/lane tile (``block_w``),
    row tile for blocked kernels (``block_rows``), slot tile for the
    streamed netlist walks (``tile_rows``), and the per-core VMEM
    budget the tiling must respect;
  * ``KernelSpec`` — ties a ``TileConfig`` to the interpret decision
    (``interpret=None`` auto-resolves to "interpret everywhere but a
    real TPU", the contract every ops.py wrapper already used).

ops.py wrappers accept ``spec=`` and fall back to their historical
keyword arguments when it is omitted, so existing call sites keep
working unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

DEFAULT_BLOCK_W = 128       # lane-aligned word tile (last axis)
DEFAULT_TILE_ROWS = 32      # slot tile for streamed netlist walks
DEFAULT_VMEM_BUDGET = 16 << 20   # one TPU core's VMEM


def default_interpret() -> bool:
    """Interpret on anything but a real TPU: CPU CI runs kernels through
    the Pallas interpreter, a TPU runs the compiled Mosaic kernel."""
    import jax
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Geometry of one kernel launch.

    ``block_w`` tiles the packed-word / lane axis (the grid axis of the
    bitplane kernels), ``block_rows`` tiles row-blocked kernels, and
    ``tile_rows`` is the slot-tile of the streamed netlist walk (how
    many LUT slots one double-buffered step evaluates). All three are
    upper bounds: wrappers clamp to the actual problem size.
    """

    block_w: int = DEFAULT_BLOCK_W
    block_rows: int = 0                  # 0 = kernel default / unblocked
    tile_rows: int = DEFAULT_TILE_ROWS
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET

    def clamp_block_w(self, w: int) -> int:
        """Effective word tile for a ``w``-word problem."""
        return min(self.block_w, max(1, w))

    def clamp_tile_rows(self, rows: int) -> int:
        """Effective slot tile for a ``rows``-slot level walk."""
        return min(self.tile_rows, max(1, rows))


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A named, sweepable launch configuration for one kernel."""

    name: str = ""
    interpret: Optional[bool] = None     # None = auto (not on a TPU)
    tile: TileConfig = dataclasses.field(default_factory=TileConfig)

    def resolve_interpret(self, override: Optional[bool] = None) -> bool:
        """Explicit per-call override > spec pin > backend auto-detect."""
        if override is not None:
            return override
        if self.interpret is not None:
            return self.interpret
        return default_interpret()

    def with_tile(self, **kw) -> "KernelSpec":
        """Copy with tile-geometry fields replaced (sweep helper)."""
        return dataclasses.replace(
            self, tile=dataclasses.replace(self.tile, **kw))


DEFAULT_SPEC = KernelSpec()
