"""Public wrapper for fanin_matmul (padding plumbing)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..spec import DEFAULT_SPEC, KernelSpec
from .fanin_matmul import DEFAULT_BB, DEFAULT_BN, fanin_matmul_pallas


@partial(jax.jit, static_argnames=("interpret", "spec"))
def fanin_matmul(x: jax.Array, idx: jax.Array, w: jax.Array,
                 bias: jax.Array, interpret: Optional[bool] = None,
                 spec: Optional[KernelSpec] = None) -> jax.Array:
    """FCP-sparse linear: x (B, n_in), idx/w (N, K), bias (N,) -> (B, N)."""
    interpret = (DEFAULT_SPEC if spec is None
                 else spec).resolve_interpret(interpret)
    B, n_in = x.shape
    N, K = idx.shape

    def pad(a, axis, mult, value=0):
        p = (-a.shape[axis]) % mult
        if p == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, p)
        return jnp.pad(a, widths, constant_values=value)

    bb = min(DEFAULT_BB, max(8, B))
    bn = min(DEFAULT_BN, max(8, N))
    x_p = pad(x, 0, bb)
    idx_p = pad(idx.astype(jnp.int32), 0, bn)
    w_p = pad(w, 0, bn)
    bias_p = pad(bias, 0, bn)
    out = fanin_matmul_pallas(x_p, idx_p, w_p, bias_p, K,
                              block_b=bb, block_n=bn, interpret=interpret)
    return out[:B, :N]
