"""Pure-jnp oracle for fanin_matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fanin_matmul_ref(x: jax.Array, idx: jax.Array, w: jax.Array,
                     bias: jax.Array) -> jax.Array:
    """y[b, j] = sum_k x[b, idx[j,k]] * w[j,k] + bias[j]."""
    gathered = x[:, idx]                 # (B, N, K)
    return jnp.einsum("bnk,nk->bn", gathered, w) + bias[None, :]


def dense_equivalent(x: jax.Array, w_dense: jax.Array, bias: jax.Array
                     ) -> jax.Array:
    """Dense oracle given the masked dense weight (N, n_in)."""
    return x @ w_dense.T + bias[None, :]
