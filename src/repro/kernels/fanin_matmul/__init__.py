from .ops import fanin_matmul  # noqa: F401
from .ref import dense_equivalent, fanin_matmul_ref  # noqa: F401
