"""Pallas kernel: fanin-K gather-matmul for FCP-sparse linear layers.

After fanin-constrained pruning every output neuron reads exactly K
inputs. Dense matmul wastes (in_dim / K)x FLOPs and bytes; the sparse
form is

    y[b, j] = sum_k x[b, idx[j, k]] * w[j, k] + bias[j]

On TPU this is a VMEM gather + small contraction: the x block stays
resident across a neuron tile, idx/w tiles stream. Arithmetic intensity
per output element is K MACs over K*4 gathered bytes — memory-bound, so
the tiling keeps the batch tile tall (sublane-aligned) to amortise the
gathered rows.

Grid: (B/bB, N/bN); x block carries the full input width (FCP layers are
narrow by construction — that is the point of the paper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 128
DEFAULT_BN = 128


def _kernel(x_ref, idx_ref, w_ref, b_ref, out_ref, *, fanin: int):
    x = x_ref[...]           # (bB, n_in) f32
    idx = idx_ref[...]       # (bN, K)
    w = w_ref[...]           # (bN, K)
    bias = b_ref[...]        # (1, bN)

    bB = x.shape[0]
    acc = jnp.zeros((bB, idx.shape[0]), jnp.float32)
    for k in range(fanin):   # K static & small -> unrolled gather-MACs
        cols = idx[:, k]                      # (bN,)
        xg = jnp.take(x, cols, axis=1)        # (bB, bN)
        acc = acc + xg * w[None, :, k]
    out_ref[...] = (acc + bias).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("fanin", "block_b", "block_n", "interpret"))
def fanin_matmul_pallas(x: jax.Array, idx: jax.Array, w: jax.Array,
                        bias: jax.Array, fanin: int,
                        block_b: int = DEFAULT_BB,
                        block_n: int = DEFAULT_BN,
                        interpret: bool = True) -> jax.Array:
    """x: (B, n_in) f32; idx/w: (N, K); bias: (N,) -> (B, N) f32."""
    B, n_in = x.shape
    N, K = idx.shape
    assert B % block_b == 0 and N % block_n == 0

    grid = (B // block_b, N // block_n)
    bias2 = bias.reshape(1, N)
    return pl.pallas_call(
        functools.partial(_kernel, fanin=fanin),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, K), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, K), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=interpret,
    )(x, idx, w, bias2)
