"""Pallas kernel: bit-parallel AIG simulation.

The AIG node list is a linear program of bitwise ops: node i reads two
earlier value rows, complements per the edge literals, ANDs them, and
writes row i. The kernel keeps the whole value plane (n_nodes, block_w)
resident as its VMEM output block and walks the node list with a
``fori_loop`` of dynamic row loads/stores; fanin literals sit in SMEM so
the per-node address arithmetic is scalar. Words pack 32 samples per
int32 lane, and the grid tiles the word (sample) axis — each program
simulates the full netlist on its own slice of samples, so sample
throughput scales with the grid while the sequential node walk stays
on-chip.

Edge complement trick: literal l = 2*node + c, and XOR with ``-(l & 1)``
(0 or all-ones in two's complement) applies the complement branch-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BW = 128   # word (packed-sample) tile, lane-aligned


def _kernel(f0_ref, f1_ref, pis_ref, out_ref, *, n_pis: int, n_ands: int):
    bw = pis_ref.shape[1]
    out_ref[0, :] = jnp.zeros((bw,), jnp.int32)          # const-0 row
    out_ref[1: n_pis + 1, :] = pis_ref[...]

    def body(i, carry):
        l0 = f0_ref[i]
        l1 = f1_ref[i]
        v0 = pl.load(out_ref, (pl.ds(l0 >> 1, 1), slice(None)))
        v1 = pl.load(out_ref, (pl.ds(l1 >> 1, 1), slice(None)))
        v0 = v0 ^ (-(l0 & 1))
        v1 = v1 ^ (-(l1 & 1))
        pl.store(out_ref, (pl.ds(1 + n_pis + i, 1), slice(None)), v0 & v1)
        return carry

    jax.lax.fori_loop(0, n_ands, body, 0)


@functools.partial(
    jax.jit, static_argnames=("n_pis", "n_ands", "block_w", "interpret"))
def aig_sim_pallas(pi_words: jax.Array, f0: jax.Array, f1: jax.Array,
                   n_pis: int, n_ands: int, block_w: int = DEFAULT_BW,
                   interpret: bool = True) -> jax.Array:
    """pi_words: (n_pis, W) int32 packed samples; f0/f1: (n_ands,) int32
    fanin literals (node ids offset as in repro.synth.aig). Returns the
    full value plane (1 + n_pis + n_ands, W) int32 — row 0 is const-0,
    rows 1..n_pis echo the inputs, the rest are AND node values."""
    _, w = pi_words.shape
    assert w % block_w == 0, (w, block_w)
    n_total = 1 + n_pis + n_ands
    grid = (w // block_w,)
    return pl.pallas_call(
        functools.partial(_kernel, n_pis=n_pis, n_ands=n_ands),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((n_pis, block_w), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n_total, block_w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_total, w), jnp.int32),
        interpret=interpret,
    )(f0, f1, pi_words)
