"""Public wrapper for bit-parallel AIG simulation on device.

An AND gate is a k=2 LUT: the 4-entry truth table
``tt[a + 2b] = (a ^ c0) & (b ^ c1)`` encodes both edge complements, so
the whole AIG routes through the *streamed* lut_eval kernel — levelized,
renumbered level-major, tiled, double-buffered — instead of the
monolithic one-node-per-step walk in ``aig_sim.py``. That walk was
~200x slower than the jnp scan oracle (one dynamic row store per node
against the full value plane); the streamed route folds a whole tile of
ANDs per step and benches faster than the oracle. The returned plane is
inverse-permuted back to the original node numbering, so callers
(``repro.synth.simulate``) see the exact legacy layout.

The tile plan is pure netlist structure; a small keyed cache means
repeated simulation of the same AIG (sweeps, equivalence checks) pays
the levelize+tile cost once. The legacy kernel stays available as
``aig_sim_pallas`` for the bench's before/after row.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Optional, Tuple

import numpy as np

from ..spec import DEFAULT_SPEC, KernelSpec
from .aig_sim import DEFAULT_BW, aig_sim_pallas  # noqa: F401  (legacy)

_PLAN_CACHE: Dict[str, Tuple[object, np.ndarray]] = {}
_PLAN_CACHE_MAX = 64


def compile_aig_tile_plan(f0: np.ndarray, f1: np.ndarray, n_pis: int,
                          tile_rows: int = 32):
    """Levelize an AIG and tile it as k=2 LUT slots for the streamed
    kernel. Returns a ``repro.synth.executor.TilePlan`` whose
    ``row_of_wire`` maps original node ids to streamed plane rows."""
    from repro.synth.executor import _LevelArrays, _Plan, compile_tile_plan

    f0 = np.asarray(f0, np.int64)
    f1 = np.asarray(f1, np.int64)
    n_ands = f0.shape[0]
    v0, c0 = f0 >> 1, (f0 & 1)
    v1, c1 = f1 >> 1, (f1 & 1)
    # levelize: nodes are topologically ordered, fanins point earlier
    lvl = np.zeros(1 + n_pis + n_ands, np.int32)
    for i in range(n_ands):
        lvl[1 + n_pis + i] = max(lvl[v0[i]], lvl[v1[i]]) + 1
    node_lvl = lvl[1 + n_pis:]
    # 4-entry INIT masks: index r = a + 2b over the two fanin values
    r = np.arange(4)
    onset = ((r & 1)[None] ^ c0[:, None]) & (((r >> 1) & 1)[None]
                                             ^ c1[:, None])
    tt_all = (onset * np.uint32(0xFFFFFFFF)).astype(np.uint32)
    leaves_all = np.stack([v0, v1], axis=1).astype(np.int32)
    levels = []
    for l in range(1, (int(node_lvl.max()) if n_ands else 0) + 1):
        idx = np.nonzero(node_lvl == l)[0]
        levels.append(_LevelArrays(
            leaves_all[idx], tt_all[idx],
            (1 + n_pis + idx).astype(np.int32)))
    plan = _Plan(levels, np.zeros((0,), np.int32), np.zeros((0,), bool))
    return compile_tile_plan(plan, n_pis, 2, tile_rows)


def _cached_plan(f0: np.ndarray, f1: np.ndarray, n_pis: int,
                 tile_rows: int):
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(f0, np.int32).tobytes())
    h.update(np.ascontiguousarray(f1, np.int32).tobytes())
    h.update(f"{n_pis},{tile_rows}".encode())
    key = h.hexdigest()
    hit = _PLAN_CACHE.get(key)
    if hit is None:
        if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
            _PLAN_CACHE.clear()
        tplan = compile_aig_tile_plan(f0, f1, n_pis, tile_rows)
        hit = _PLAN_CACHE[key] = (tplan, tplan.row_of_wire.copy())
    return hit


def aig_sim(pi_words: np.ndarray, f0: np.ndarray, f1: np.ndarray,
            n_pis: int, interpret: Optional[bool] = None,
            spec: Optional[KernelSpec] = None) -> np.ndarray:
    """Simulate an AIG on packed words; returns the (n_nodes, W) uint32
    value plane (same layout as repro.synth.simulate._simulate_np).

    pi_words: (n_pis, W) uint32; f0/f1: (n_ands,) int32 fanin literals.
    """
    from repro.kernels.lut_eval import lut_eval_streamed

    spec = DEFAULT_SPEC if spec is None else spec
    pi_words = np.ascontiguousarray(pi_words, np.uint32)
    n_ands = int(np.asarray(f0).shape[0])
    w = pi_words.shape[1]
    if n_ands == 0 or n_pis == 0 or w == 0:
        vals = np.zeros((1 + n_pis + n_ands, w), np.uint32)
        vals[1: n_pis + 1] = pi_words
        return vals
    tplan, row_of_wire = _cached_plan(f0, f1, n_pis,
                                      spec.tile.tile_rows)
    plane = lut_eval_streamed(pi_words, tplan, interpret=interpret,
                              spec=spec)
    return np.ascontiguousarray(plane[row_of_wire])
