"""Public jit'd wrapper for the aig_sim Pallas kernel (pads + unpads)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .aig_sim import DEFAULT_BW, aig_sim_pallas


def aig_sim(pi_words: np.ndarray, f0: np.ndarray, f1: np.ndarray,
            n_pis: int, interpret: bool = True) -> np.ndarray:
    """Simulate an AIG on packed words; returns the (n_nodes, W) uint32
    value plane (same layout as repro.synth.simulate._simulate_np).

    pi_words: (n_pis, W) uint32; f0/f1: (n_ands,) int32 fanin literals.
    """
    pi_words = np.ascontiguousarray(pi_words, np.uint32)
    n_ands = int(np.asarray(f0).shape[0])
    w = pi_words.shape[1]
    if n_ands == 0 or n_pis == 0 or w == 0:
        vals = np.zeros((1 + n_pis + n_ands, w), np.uint32)
        vals[1: n_pis + 1] = pi_words
        return vals
    bw = min(DEFAULT_BW, max(1, w))
    pad = (-w) % bw
    if pad:
        pi_words = np.concatenate(
            [pi_words, np.zeros((n_pis, pad), np.uint32)], axis=1)
    out = aig_sim_pallas(
        jnp.asarray(pi_words.view(np.int32)), jnp.asarray(f0, jnp.int32),
        jnp.asarray(f1, jnp.int32), n_pis, n_ands, block_w=bw,
        interpret=interpret)
    return np.ascontiguousarray(np.asarray(out)[:, :w]).view(np.uint32)
