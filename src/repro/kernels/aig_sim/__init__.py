from .ops import aig_sim  # noqa: F401
from .ref import aig_sim_ref  # noqa: F401
