"""Pure-jnp oracle for the aig_sim kernel: same linear node walk, built
as a lax.scan over the fanin literal arrays with a dynamically-updated
value plane (functional analogue of the kernel's in-place row stores)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def aig_sim_ref(pi_words: jax.Array, f0: jax.Array, f1: jax.Array,
                n_pis: int) -> jax.Array:
    """pi_words: (n_pis, W) int32; f0/f1: (n_ands,) int32 literals.
    Returns the (1 + n_pis + n_ands, W) int32 value plane."""
    n_ands = f0.shape[0]
    w = pi_words.shape[1]
    vals = jnp.zeros((1 + n_pis + n_ands, w), jnp.int32)
    vals = vals.at[1: n_pis + 1].set(pi_words.astype(jnp.int32))

    def step(vals, inp):
        i, l0, l1 = inp
        v0 = vals[l0 >> 1] ^ (-(l0 & 1))
        v1 = vals[l1 >> 1] ^ (-(l1 & 1))
        return vals.at[1 + n_pis + i].set(v0 & v1), None

    idx = jnp.arange(n_ands, dtype=jnp.int32)
    vals, _ = jax.lax.scan(step, vals, (idx, f0.astype(jnp.int32),
                                        f1.astype(jnp.int32)))
    return vals
