"""Partitioning rules + mesh context for every launcher and test.

One module owns the whole layout story:

  * ``use_mesh`` / ``active_mesh`` — a dynamic mesh context read at trace
    time by the model code (no global jax state, composes with jit);
  * ``OPTS`` / ``set_opts`` — strategy flags that flip between layouts
    (expert parallelism, pure FSDP, serve-time tensor parallelism, ...)
    without touching model code;
  * ``param_pspec`` — the 2-D (fsdp x tensor) partition rule table for
    every parameter in the unified LM schema.  Stacked-layer leaves
    (leading L axis from the vmapped init) get a leading ``None``;
  * ``constrain_*`` — activation constraints the model inserts on its
    hot paths; all of them degrade to no-ops off-mesh and prune axes
    that do not divide the dimension they shard (smoke shapes on tiny
    meshes, 24-head archs on 16-way model axes, ...);
  * ``params_shardings`` / ``batch_shardings`` / ``cache_pspec`` —
    NamedSharding pytrees for device_put / pjit in/out shardings; the
    same rules serve the elastic-rescale restore path (a checkpoint
    written on one mesh restores onto any other).

Axis convention: ``"data"`` is the batch/fsdp axis, ``"model"`` the
tensor axis, and an optional leading ``"pod"`` axis extends data
parallelism across the DCN boundary (launch/mesh.py).
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# ---------------------------------------------------------------------------
# Mesh context
# ---------------------------------------------------------------------------

_ACTIVE_MESH: Optional[Any] = None


@contextlib.contextmanager
def use_mesh(mesh):
    """Dynamic-scope mesh: model code reads it via ``active_mesh()`` at
    trace time, so the same forward traces sharded or unsharded."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        yield mesh
    finally:
        _ACTIVE_MESH = prev


def active_mesh():
    return _ACTIVE_MESH


@contextlib.contextmanager
def suspend_mesh():
    """Temporarily hide the active mesh (no-op constraints).

    Used while tracing ``shard_map`` bodies (dist/pipeline.py): inside
    manual-sharding regions ``with_sharding_constraint`` on the global
    mesh is meaningless and must not fire.
    """
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = None
    try:
        yield
    finally:
        _ACTIVE_MESH = prev


# ---------------------------------------------------------------------------
# Strategy flags
# ---------------------------------------------------------------------------

OPTS = {
    "moe_ep": False,        # shard_map expert parallelism (models/layers.py)
    "fsdp_pure": False,     # every mesh axis is data-parallel; params fsdp
    "serve_tp_only": False,  # decode: tensor-parallel only, batch replicated
    "seq_parallel": False,  # shard activation sequence axis over 'model'
    "bf16_params": False,   # mixed-precision training (f32 master in opt)
}


def set_opts(**kwargs) -> dict:
    """Set strategy flags; returns the previous values of the flags set."""
    prev = {}
    for k, v in kwargs.items():
        if k not in OPTS:
            raise KeyError(f"unknown sharding opt '{k}'; have {sorted(OPTS)}")
        prev[k] = OPTS[k]
        OPTS[k] = bool(v)
    return prev


# ---------------------------------------------------------------------------
# Mesh-axis helpers
# ---------------------------------------------------------------------------

def _mesh_axis_size(mesh, name: str) -> int:
    return int(dict(mesh.shape).get(name, 1))


def _dp_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel axes in mesh order (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _dp_for(mesh, batch: int):
    """The widest data-parallel axis (group) that divides ``batch``.

    Tries the full dp-axis product first (('pod','data') on multi-pod
    meshes), then shorter prefixes, then the remaining single axes.
    Returns a bare axis name, a tuple of names, or None (replicate).
    """
    axes = _dp_axes(mesh)
    cands = [axes[:i] for i in range(len(axes), 0, -1)]
    cands += [(a,) for a in axes[1:]]
    best, best_size = None, 1
    for cand in cands:
        size = 1
        for a in cand:
            size *= _mesh_axis_size(mesh, a)
        if size > best_size and batch % size == 0:
            best, best_size = cand, size
    if best is None:
        return None
    return best[0] if len(best) == 1 else best


def batch_axes():
    """Axes the leading batch dim shards over under the active mesh."""
    mesh = active_mesh()
    if mesh is None or OPTS["serve_tp_only"]:
        return None
    axes = _dp_axes(mesh)
    if OPTS["fsdp_pure"]:
        axes = tuple(mesh.axis_names)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


# ---------------------------------------------------------------------------
# Parameter partition rules
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    """KeyPath -> 'layers/attn/wq' style string."""
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key",
                                 getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(parts)


# (regex on the path WITHOUT the stacked-layer prefix) -> spec for the
# unstacked leaf.  First match wins; unmatched leaves replicate.
_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"^embed$", ("model", "data")),         # (V, D): vocab=tensor, d=fsdp
    (r"^lm_head$", ("data", "model")),       # (D, V)
    (r"(^|/)(attn|cross)/(wq|wk|wv)$", ("data", "model")),
    (r"(^|/)(attn|cross)/wo$", ("model", "data")),
    (r"(^|/)mlp/(w1|w3|mask_w1)$", ("data", "model")),
    (r"(^|/)mlp/(w2|mask_w2)$", ("model", "data")),
    (r"(^|/)moe/router$", ("data", None)),   # (D, E): experts replicated
    (r"(^|/)moe/(w1|w3)$", (None, "data", "model")),   # (E, D, F)
    (r"(^|/)moe/w2$", (None, "model", "data")),        # (E, F, D)
    (r"(^|/)mamba/in_proj$", ("data", "model")),       # (D, 2*Di)
    (r"(^|/)mamba/out_proj$", ("model", "data")),      # (Di, D)
    (r"(^|/)mamba/x_proj$", ("model", None)),          # (Di, R+2N)
    (r"(^|/)mamba/dt_proj_w$", (None, "model")),       # (R, Di)
    (r"(^|/)mamba/conv_w$", (None, "model")),          # (CW, Di)
    (r"(^|/)mamba/A_log$", ("model", None)),           # (Di, N)
)

_STACKED = ("layers/", "enc_layers/")


def param_pspec(path, leaf) -> P:
    """Partition rule for one parameter leaf.

    ``path`` is a jax KeyPath (or any sequence accepted by
    ``_path_str``); ``leaf`` only contributes its ndim, so eval_shape
    ShapeDtypeStructs work. Specs always have exactly ``leaf.ndim``
    entries so rule tests can compare for equality.
    """
    name = _path_str(path)
    ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    stacked = any(name.startswith(s) for s in _STACKED)
    base = name.split("/", 1)[1] if stacked else name
    base_ndim = ndim - 1 if stacked else ndim
    spec: Tuple = (None,) * base_ndim
    for pat, rule in _RULES:
        if re.search(pat, base):
            if len(rule) == base_ndim:
                spec = rule
            break
    if stacked:
        spec = (None,) + tuple(spec)
    return P(*spec)


def _prune_spec(mesh, shape, spec) -> Tuple:
    """Drop sharded axes that are absent from ``mesh`` or do not divide
    their dimension — the guard that lets one rule table serve smoke
    configs, degraded meshes and full production shapes alike."""
    if len(spec) > len(shape):
        raise ValueError(
            f"spec {spec} has more entries than array rank {len(shape)}")
    names = set(mesh.axis_names)
    out = []
    used = set()
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        group = (ax,) if isinstance(ax, str) else tuple(ax)
        while group:
            if all(a in names for a in group) and not (set(group) & used):
                size = 1
                for a in group:
                    size *= _mesh_axis_size(mesh, a)
                if dim % size == 0:
                    break
            group = group[:-1]
        if group:
            used.update(group)
            out.append(group[0] if len(group) == 1 else group)
        else:
            out.append(None)
    return tuple(out)


def params_shardings(mesh, params: PyTree) -> PyTree:
    """NamedSharding pytree for a param (or param-shaped) pytree.

    Works on concrete arrays and ShapeDtypeStructs; used both to
    device_put fresh params and as the target shardings when restoring a
    checkpoint onto a different mesh (elastic rescale)."""
    def one(path, leaf):
        spec = _prune_spec(mesh, leaf.shape, tuple(param_pspec(path, leaf)))
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Batch / cache shardings (launchers + dry-run)
# ---------------------------------------------------------------------------

def batch_shardings(mesh, specs: PyTree) -> PyTree:
    """Shard every model input on its leading (batch) axis."""
    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        dp = _dp_for(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(one, specs)


def cache_pspec(mesh, cache: PyTree) -> PyTree:
    """Decode-cache shardings (see models/lm.py init_cache layout).

    KV tensors (L, B, W, KV, dh) shard heads over 'model' when the
    kv-head count divides it, else the ring axis W (flash-decode keeps
    the cache sequence-sharded; layers.decode_attention mirrors this
    choice) — never both.
    """
    msize = _mesh_axis_size(mesh, "model")

    def one(path, leaf):
        name = _path_str(path).rsplit("/", 1)[-1]
        shape = leaf.shape
        if name in ("k", "v"):
            dp = _dp_for(mesh, shape[1])
            if shape[3] % msize == 0:
                spec = (None, dp, None, "model", None)
            elif shape[2] % msize == 0:
                spec = (None, dp, "model", None, None)
            else:
                spec = (None, dp, None, None, None)
        elif name == "positions":
            spec = (_dp_for(mesh, shape[0]), None)
        elif name == "ssm":                   # (L, B, Di, N)
            spec = (None, _dp_for(mesh, shape[1]), "model", None)
        elif name == "conv":                  # (L, B, CW-1, Di)
            spec = (None, _dp_for(mesh, shape[1]), None, "model")
        elif name == "enc_out":               # (B, F, D)
            spec = (_dp_for(mesh, shape[0]), None, None)
        else:
            spec = (None,) * leaf.ndim
        return NamedSharding(mesh, P(*_prune_spec(mesh, shape, spec)))

    return jax.tree_util.tree_map_with_path(one, cache)


# ---------------------------------------------------------------------------
# Activation constraints (model hot paths)
# ---------------------------------------------------------------------------

def constraint(x, *spec):
    """with_sharding_constraint against the active mesh; no-op off-mesh.

    Axes that are missing from the mesh or do not divide the dimension
    are pruned instead of erroring."""
    mesh = active_mesh()
    if mesh is None:
        return x
    pruned = _prune_spec(mesh, x.shape, spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*pruned)))


def constrain_hidden(x):
    """(B, S, D) residual-stream states: batch over the dp axes (all
    axes under fsdp_pure), sequence over 'model' under seq_parallel."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = [batch_axes()] + [None] * (x.ndim - 1)
    if OPTS["seq_parallel"] and not OPTS["fsdp_pure"] and x.ndim >= 3:
        spec[1] = "model"
    return constraint(x, *spec)


def constrain_heads(q):
    """(B, S, H, dh) attention tensors: heads over 'model' (tensor
    parallelism); under fsdp_pure there is no tensor axis to use."""
    mesh = active_mesh()
    if mesh is None:
        return q
    spec = [batch_axes()] + [None] * (q.ndim - 1)
    if not OPTS["fsdp_pure"]:
        spec[-2] = "model"
    return constraint(q, *spec)


def constrain_logits(logits):
    """(B, C, Vp) loss-chunk logits: vocab over 'model' so the lse
    reduction stays sharded until the final scalar."""
    mesh = active_mesh()
    if mesh is None:
        return logits
    spec = [batch_axes()] + [None] * (logits.ndim - 1)
    if not OPTS["fsdp_pure"]:
        spec[-1] = "model"
    return constraint(logits, *spec)
