"""GPipe pipeline parallelism over the stacked layer axis.

``pipeline_lm_forward`` partitions the (L, ...) layer stack of the
unified LM across the 'model' mesh axis (one contiguous slab of layers
per stage) and streams microbatches through the stages with a
``shard_map`` + ``ppermute`` schedule:

  step t:  stage 0 ingests microbatch t (while any remain); every stage
           applies its layers to the microbatch it holds; every stage
           hands its output to stage s+1 via one collective-permute.

After ``n_micro + n_stages - 1`` steps every microbatch has crossed all
stages; the last stage's outputs are psum-broadcast back so the result
is replicated (bubble fraction (S-1)/(T), the classic GPipe schedule).
The schedule is a ``lax.scan``, so the HLO stays O(1 step), and both
``ppermute`` and ``psum`` are linear — ``jax.grad`` differentiates
straight through the schedule (the reverse pass runs the ring backwards).

Embedding and the final norm run outside the shard_map (they are not
layer-partitioned); activation sharding constraints are suspended inside
the manual region (see shardings.suspend_mesh).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist import shardings as sh

PyTree = Any

_STAGE_AXIS = "model"


def pipeline_lm_forward(cfg, params: PyTree, tokens, mesh,
                        n_micro: int = 2):
    """Stage-partitioned decoder forward. Returns (B, S, D) hidden
    states (post final-norm), numerically matching models.lm.forward.

    Requires cfg.n_layers % mesh.shape['model'] == 0 and
    batch % n_micro == 0. Dense/MoE/SSM decoder-only families only (no
    encoder-decoder cross-attention through the pipeline).
    """
    from repro.models import layers as L
    from repro.models import lm

    n_stages = int(dict(mesh.shape)[_STAGE_AXIS])
    n_layers = cfg.n_layers
    if n_layers % n_stages:
        raise ValueError(
            f"n_layers={n_layers} not divisible by {n_stages} stages")
    if cfg.is_encdec:
        raise NotImplementedError("pipeline over enc-dec not supported")

    dt = L.cdtype(cfg)
    x = params["embed"].astype(dt)[tokens]                     # (B, S, D)
    b, s, d = x.shape
    if b % n_micro:
        raise ValueError(f"batch={b} not divisible by n_micro={n_micro}")
    x_mb = x.reshape(n_micro, b // n_micro, s, d)
    positions = jnp.arange(s)
    n_steps = n_micro + n_stages - 1

    def device_fn(x_mb_local, layers_local):
        # x_mb_local: (n_micro, B/n_micro, S, D) replicated;
        # layers_local: the L/n_stages layer slab owned by this stage.
        stage = jax.lax.axis_index(_STAGE_AXIS)

        def apply_slab(h):
            def body(c, lp):
                y, _ = lm._dec_block(cfg, lp, c, positions, None, False)
                return y, None

            h, _ = jax.lax.scan(body, h, layers_local)
            return h

        def step(carry, t):
            state, outs = carry
            inp = jax.lax.dynamic_index_in_dim(
                x_mb_local, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            h = jnp.where(stage == 0, inp, state)
            y = apply_slab(h)
            # microbatch m exits the last stage at step m + n_stages - 1;
            # later (warm-down) iterations of stage 0 recirculate garbage
            # that never reaches the collection window.
            out_idx = t - (n_stages - 1)
            hit = (jnp.arange(n_micro) == out_idx) & (stage == n_stages - 1)
            outs = jnp.where(hit[:, None, None, None], y[None], outs)
            nxt = jax.lax.ppermute(
                y, _STAGE_AXIS,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        carry0 = (jnp.zeros_like(x_mb_local[0]), jnp.zeros_like(x_mb_local))
        (_, outs), _ = jax.lax.scan(step, carry0, jnp.arange(n_steps))
        # only the last stage wrote into outs; broadcast it everywhere
        return jax.lax.psum(outs, _STAGE_AXIS)

    fn = shard_map(device_fn, mesh=mesh,
                   in_specs=(P(), P(_STAGE_AXIS)),
                   out_specs=P(), check_rep=False)
    with sh.suspend_mesh():  # no global constraints inside manual region
        out = fn(x_mb, params["layers"])
    hidden = out.reshape(b, s, d)
    return L.rms_norm(hidden, params["final_norm"], cfg.norm_eps)
