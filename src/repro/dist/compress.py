"""Error-feedback gradient compression for the data-parallel reduction.

Both schemes keep the EF identity  compressed + residual == grad +
residual_prev  exactly (float tolerance), which is what makes biased
compressors converge (Karimireddy et al., "Error Feedback Fixes
SignSGD"):

  topk_compress — transmit only the largest ``frac`` of entries per
      leaf; the rest accumulates in the residual until it matters.
  sign_compress — 1-bit sign with a per-leaf mean-|.| scale (signSGD
      with majority-vote-compatible magnitudes).

State is a plain pytree (NamedTuple of a param-shaped tree), so it
rides inside TrainState through jit/pjit and checkpointing untouched.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class EFState(NamedTuple):
    residual: PyTree


def init_ef(params: PyTree) -> EFState:
    """Zero residuals shaped like the grads (f32 accumulation)."""
    return EFState(jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _accumulate(grads: PyTree, ef: EFState) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, ef.residual)


def topk_compress(grads: PyTree, ef: EFState, frac: float
                  ) -> Tuple[PyTree, EFState]:
    """Keep the top ``frac`` entries (by magnitude) of grad+residual per
    leaf; everything below the cut accumulates in the new residual."""
    acc = _accumulate(grads, ef)

    def one(a):
        flat = jnp.abs(a.reshape(-1))
        k = max(1, int(frac * flat.size))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(a) >= thresh, a, 0.0)

    sparse = jax.tree_util.tree_map(one, acc)
    residual = jax.tree_util.tree_map(jnp.subtract, acc, sparse)
    return sparse, EFState(residual)


def sign_compress(grads: PyTree, ef: EFState) -> Tuple[PyTree, EFState]:
    """1-bit-per-entry quantization: sign(acc) * mean(|acc|) per leaf."""
    acc = _accumulate(grads, ef)

    def one(a):
        scale = jnp.mean(jnp.abs(a))
        return jnp.sign(a) * scale

    q = jax.tree_util.tree_map(one, acc)
    residual = jax.tree_util.tree_map(jnp.subtract, acc, q)
    return q, EFState(residual)
