"""Distributed substrate: mesh/sharding rules, gradient compression,
fault tolerance and pipeline parallelism.

Modules:
  shardings — mesh context, strategy flags (OPTS), param/activation
              partition rules, cross-mesh resharding helpers.
  compress  — error-feedback gradient compression (top-k, signSGD).
  fault     — heartbeat files, step watchdog, retrying step wrapper.
  pipeline  — GPipe-style stage-parallel LM forward over the layer axis
              (imported explicitly; it depends on repro.models).
"""
from repro.dist import compress, fault, shardings  # noqa: F401
