"""Fault-tolerance hooks for multi-host training (train/loop.py).

  Heartbeat    — each host periodically writes a liveness file to shared
                 storage; any host can list the peers that stopped
                 beating (the controller's restart signal).
  StepWatchdog — online mean/variance of step wall time; a step beyond
                 mean + k*sigma flags a straggler. Outliers are excluded
                 from the running stats so one hiccup does not widen the
                 detection band.
  retry_step   — wrap the jitted train step with bounded retries +
                 exponential backoff for transient failures (preempted
                 collective, flaky interconnect).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Callable, List


class Heartbeat:
    """File-based liveness on a shared directory (one file per host)."""

    def __init__(self, hb_dir: str, host_id: int):
        self.dir = hb_dir
        self.host_id = int(host_id)
        os.makedirs(hb_dir, exist_ok=True)

    def _path(self, host_id: int) -> str:
        return os.path.join(self.dir, f"host_{host_id}.json")

    def beat(self, step: int) -> None:
        """Atomically publish (host, step, now)."""
        tmp = self._path(self.host_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host_id, "step": int(step),
                       "time": time.time()}, f)
        os.replace(tmp, self._path(self.host_id))

    def hosts(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("host_") and name.endswith(".json"):
                try:
                    out.append(int(name[5:-5]))
                except ValueError:
                    continue  # stray/foreign file in the shared dir
        return sorted(out)

    def stale_hosts(self, timeout_s: float) -> List[int]:
        """Hosts whose last beat is older than ``timeout_s``."""
        now = time.time()
        stale = []
        for h in self.hosts():
            try:
                with open(self._path(h)) as f:
                    info = json.load(f)
            except (OSError, ValueError):
                stale.append(h)  # unreadable == presumed dead
                continue
            if now - float(info.get("time", 0.0)) > timeout_s:
                stale.append(h)
        return stale


class StepWatchdog:
    """Flag steps slower than mean + k*sigma (Welford online stats)."""

    def __init__(self, min_steps: int = 10, k_sigma: float = 3.0):
        self.min_steps = min_steps
        self.k_sigma = k_sigma
        self.n = 0
        self.mean_step = 0.0
        self._m2 = 0.0
        self.straggler_events = 0

    @property
    def std(self) -> float:
        if self.n < 2:
            return 0.0
        return math.sqrt(self._m2 / (self.n - 1))

    def record(self, dt: float) -> bool:
        """Record one step time; True if it is a straggler step."""
        flagged = False
        if self.n >= self.min_steps:
            # relative sigma floor: a zero-variance warmup (coarse timer,
            # fully deterministic steps) must not flag every later step
            floor = max(self.std, 0.05 * abs(self.mean_step), 1e-9)
            limit = self.mean_step + self.k_sigma * floor
            if dt > limit:
                self.straggler_events += 1
                flagged = True
                # winsorize the outlier into the stats: a single spike
                # barely moves the band, but a sustained regime change
                # (longer seqs, new curriculum) walks the mean up until
                # the watchdog stops flagging the new normal
                dt = limit
        self.n += 1
        delta = dt - self.mean_step
        self.mean_step += delta / self.n
        self._m2 += delta * (dt - self.mean_step)
        return flagged


def retry_step(fn: Callable, max_retries: int = 3,
               backoff_s: float = 0.5) -> Callable:
    """Retry ``fn`` on exception, exponential backoff between attempts."""

    def wrapped(*args, **kwargs):
        attempts = 1 + max(0, int(max_retries))  # retries AFTER attempt 1
        for attempt in range(attempts):
            try:
                return fn(*args, **kwargs)
            except Exception:
                if attempt == attempts - 1:
                    raise
                if backoff_s > 0:
                    time.sleep(backoff_s * (2 ** attempt))

    return wrapped
