"""Synthetic jet-substructure-classification (JSC) dataset.

The real hls4ml/OpenML JSC data (16 HL features, 5 jet classes) is not
available offline; this generator produces a statistically similar task:
5 Gaussian class-conditional clusters in R^16 with anisotropic covariance
and controlled overlap, standardised to zero-mean/unit-variance features
(the real dataset is also standardised before QAT). Class overlap +
label noise are tuned so a strong (QDA) model tops out at ~77%, matching
the headroom structure of the published task (paper accuracies:
69.65–73.35% with LogicNets baselines 1.5–1.9 points lower).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

N_FEATURES = 16
N_CLASSES = 5


def make_jsc(n: int, seed: int = 0, spread: float = 0.5,
             label_noise: float = 0.1) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (n,16) float32 standardized, y (n,) int32)."""
    rng = np.random.default_rng(seed)
    # fixed class geometry (same for any seed -> train/test consistency)
    geo = np.random.default_rng(1234)
    means = geo.normal(size=(N_CLASSES, N_FEATURES)) * spread
    # anisotropic covariances via random rotations of diag scales
    covs = []
    for _ in range(N_CLASSES):
        q, _ = np.linalg.qr(geo.normal(size=(N_FEATURES, N_FEATURES)))
        scales = geo.uniform(0.5, 2.0, N_FEATURES)
        covs.append((q * scales) @ q.T)
    y = rng.integers(0, N_CLASSES, n)
    x = np.empty((n, N_FEATURES), np.float64)
    for c in range(N_CLASSES):
        idx = np.nonzero(y == c)[0]
        z = rng.normal(size=(len(idx), N_FEATURES))
        chol = np.linalg.cholesky(
            covs[c] + 1e-6 * np.eye(N_FEATURES))
        x[idx] = means[c] + z @ chol.T
    # standardise with FIXED stats (population level) so train/test agree
    x = (x - means.mean(0)) / x.std(0)
    if label_noise > 0:  # irreducible error, like the physical task
        flip = rng.random(n) < label_noise
        y = np.where(flip, rng.integers(0, N_CLASSES, n), y)
    return x.astype(np.float32), y.astype(np.int32)


def train_test(n_train: int = 20000, n_test: int = 5000,
               seed: int = 0):
    xtr, ytr = make_jsc(n_train, seed=seed)
    xte, yte = make_jsc(n_test, seed=seed + 1)
    return (xtr, ytr), (xte, yte)


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
    """Infinite shuffled batch iterator."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sl = perm[i: i + batch_size]
            yield x[sl], y[sl]
