"""LM token pipeline: deterministic synthetic stream, sharded placement,
background prefetch.

Production posture: each host materialises only its addressable shard of
the global batch (``jax.make_array_from_callback``), the stream is
deterministic in (seed, step) so any restarted/replacement node
regenerates identical data (checkpoint stores only the step), and a
prefetch thread keeps ``depth`` batches in flight ahead of the consumer.

The synthetic distribution is a Zipfian unigram mix with short-range
repetition structure, so small models have learnable signal (loss
decreases measurably within a few hundred steps).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig


def synth_tokens(cfg: ArchConfig, batch: int, seq: int, seed: int,
                 step: int) -> np.ndarray:
    """Deterministic (seed, step) -> (batch, seq) int32 batch."""
    rng = np.random.default_rng(np.uint64(seed) * 1000003 + np.uint64(step))
    v = cfg.vocab_size
    # Zipf over a clipped vocab + copy structure (periodic re-emission)
    base = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    tok = np.minimum(base, v - 1)
    # inject repetition: with p=.3, token t = token t-k for k in [1,8]
    rep = rng.random((batch, seq)) < 0.3
    lag = rng.integers(1, 9, (batch, seq))
    idx = np.maximum(np.arange(seq)[None, :] - lag, 0)
    tok = np.where(rep, np.take_along_axis(tok, idx, 1), tok)
    return tok.astype(np.int32)


def lm_batch(cfg: ArchConfig, batch: int, seq: int, seed: int, step: int
             ) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens, labels): labels are next-token shifted."""
    stream = synth_tokens(cfg, batch, seq + 1, seed, step)
    return stream[:, :-1], stream[:, 1:]


def sharded_batch(arrays, shardings):
    """Place host arrays onto the mesh (per-shard callbacks)."""
    def place(arr, sh):
        return jax.make_array_from_callback(
            arr.shape, sh, lambda idx: arr[idx])
    return jax.tree_util.tree_map(place, arrays, shardings)


class Prefetcher:
    """Background-thread pipeline: compute+place ``depth`` batches ahead."""

    def __init__(self, make_batch, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            item = self._make(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
