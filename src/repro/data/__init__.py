"""Data pipelines: synthetic JSC generator + LM token stream."""
