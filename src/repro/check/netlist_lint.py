"""Pass 1 — structural netlist lint over the AIG and the mapped LUT net.

Every invariant here is one the constructors in ``repro.synth`` are
supposed to maintain; the lint re-derives them from the raw structure so
a corrupted or hand-edited netlist (or a future transform with a bug)
is caught before it executes. Errors are violations that change or
undefine the computed function (cycles, fanin overflow, undefined
wires, INIT wider than the leaf count); warnings are redundancies a
correct-but-wasteful transform leaves behind (duplicate LUTs, vacuous
leaves, dangling logic).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.synth.aig import AIG, NONE, lit_var
from repro.synth.lutmap import MappedNetwork

from .report import CheckReport

PASS = "lint"


def lint_aig(aig: AIG, name: str = "aig") -> CheckReport:
    """Structural invariants of the And-Inverter Graph encoding."""
    rep = CheckReport(name)
    n = aig.n_nodes
    if aig.n_pis < 0 or aig.n_pis >= n:
        rep.error(PASS, "pi-range",
                  f"n_pis {aig.n_pis} outside [0, {n})")
        return rep
    # constant node + PI region must be fanin-free
    for node in range(aig.n_pis + 1):
        f0, f1 = aig._f0[node], aig._f1[node]
        rep.checked += 1
        if f0 != NONE or f1 != NONE:
            rep.error(PASS, "pi-fanin",
                      f"node {node} (const/PI) has fanins ({f0}, {f1})",
                      where=f"node {node}")
        if aig._level[node] != 0:
            rep.error(PASS, "level", f"const/PI node {node} at level "
                      f"{aig._level[node]} != 0", where=f"node {node}")
    # AND region: acyclicity (fanins strictly below), canonical operand
    # order, folded constants, strash uniqueness, consistent levels
    seen_pairs: Dict[Tuple[int, int], int] = {}
    for node in range(aig.n_pis + 1, n):
        f0, f1 = aig._f0[node], aig._f1[node]
        rep.checked += 1
        v0, v1 = lit_var(f0), lit_var(f1)
        if f0 < 0 or f1 < 0 or v0 >= n or v1 >= n:
            rep.error(PASS, "bad-fanin",
                      f"node {node} has out-of-range fanins ({f0}, {f1})",
                      where=f"node {node}")
            continue
        if v0 >= node or v1 >= node:
            rep.error(PASS, "cycle",
                      f"node {node} reads node {max(v0, v1)} — fanins must "
                      f"be strictly earlier (acyclic topological ids)",
                      where=f"node {node}")
            continue
        if v0 == 0 or v1 == 0:
            rep.error(PASS, "const-fanin",
                      f"node {node} has an un-propagated constant fanin "
                      f"(literal {f0 if v0 == 0 else f1})",
                      where=f"node {node}")
        if v0 == v1:
            rep.error(PASS, "trivial-and",
                      f"node {node} ANDs literal {f0} with {f1} over the "
                      f"same variable (folds to a constant or a copy)",
                      where=f"node {node}")
        if f0 > f1:
            rep.error(PASS, "operand-order",
                      f"node {node} fanins ({f0}, {f1}) not canonically "
                      f"sorted — strash keys are ambiguous",
                      where=f"node {node}")
        key = (min(f0, f1), max(f0, f1))
        if key in seen_pairs:
            rep.error(PASS, "duplicate-and",
                      f"nodes {seen_pairs[key]} and {node} implement the "
                      f"same AND{key} (structural-hash violation)",
                      where=f"node {node}")
        else:
            seen_pairs[key] = node
        want = 1 + max(aig._level[v0], aig._level[v1])
        if aig._level[node] != want:
            rep.error(PASS, "level",
                      f"node {node} at level {aig._level[node]}, fanin "
                      f"levels imply {want}", where=f"node {node}")
    # outputs must reference real nodes
    for i, o in enumerate(aig.outputs):
        rep.checked += 1
        if o < 0 or lit_var(o) >= n:
            rep.error(PASS, "bad-output",
                      f"output {i} literal {o} references node "
                      f"{lit_var(o)} outside [0, {n})",
                      where=f"output {i}")
    # dead logic: reachable set vs node count (a compact() away — wasteful
    # but function-preserving, so a warning)
    reachable = set(aig.topo_from(
        [o for o in aig.outputs if 0 <= lit_var(o) < n]))
    dead = aig.n_ands - len(reachable)
    rep.checked += 1
    if dead > 0:
        rep.warn(PASS, "dangling-node",
                 f"{dead} AND node(s) unreachable from any output "
                 f"(compact() would remove them)")
    rep.info["n_nodes"] = n
    rep.info["n_dead"] = dead
    return rep


def _tt_depends_on(tt: int, var: int, m: int) -> bool:
    """Does an m-variable truth table depend on variable ``var``?"""
    blk = 1 << var
    mask = 0
    for r in range(1 << m):
        if not (r >> var) & 1:
            mask |= 1 << r
    lo = tt & mask
    hi = (tt >> blk) & mask
    return lo != hi


def lint_mapped(mapped: MappedNetwork, name: str = "mapped") -> CheckReport:
    """Structural invariants of a k-LUT cover."""
    rep = CheckReport(name)
    k = mapped.k
    defined = {0: -1}                       # wire -> defining LUT index
    for p in range(1, mapped.n_pis + 1):
        defined[p] = -1
    seen_fn: Dict[Tuple[Tuple[int, ...], int], int] = {}
    for i, l in enumerate(mapped.luts):
        rep.checked += 1
        m = len(l.leaves)
        where = f"lut {i} (root {l.root})"
        if m > k:
            rep.error(PASS, "fanin-width",
                      f"LUT {i} has {m} leaves > k={k}", where=where)
            continue
        if l.root in defined:
            rep.error(PASS, "duplicate-root",
                      f"wire {l.root} defined twice (earlier LUT "
                      f"{defined[l.root]})", where=where)
        if l.root <= mapped.n_pis:
            rep.error(PASS, "root-range",
                      f"LUT root {l.root} collides with the const/PI "
                      f"wire range [0, {mapped.n_pis}]", where=where)
        for x in l.leaves:
            if x not in defined:
                rep.error(PASS, "undefined-leaf",
                          f"LUT {i} reads wire {x} before (or without) "
                          f"its definition — topological order broken",
                          where=where)
        if len(set(l.leaves)) != m:
            rep.warn(PASS, "repeated-leaf",
                     f"LUT {i} lists a leaf twice {l.leaves}", where=where)
        if not 0 <= l.tt < (1 << (1 << m)):
            rep.error(PASS, "init-width",
                      f"INIT vector needs {l.tt.bit_length()} bits but "
                      f"{m} leaves give only 2^{m}={1 << m}", where=where)
        else:
            if m > 0 and l.tt in (0, (1 << (1 << m)) - 1):
                rep.warn(PASS, "constant-lut",
                         f"LUT {i} computes constant "
                         f"{0 if l.tt == 0 else 1} — constant not "
                         f"propagated", where=where)
            for j in range(m):
                if not _tt_depends_on(l.tt, j, m):
                    rep.warn(PASS, "vacuous-leaf",
                             f"LUT {i} INIT does not depend on leaf "
                             f"{j} (wire {l.leaves[j]})", where=where)
        key = (l.leaves, l.tt)
        if key in seen_fn:
            rep.warn(PASS, "duplicate-lut",
                     f"LUT {i} recomputes LUT {seen_fn[key]} "
                     f"(same leaves and INIT)", where=where)
        else:
            seen_fn[key] = i
        defined.setdefault(l.root, i)
    for i, o in enumerate(mapped.outputs):
        rep.checked += 1
        if lit_var(o) not in defined:
            rep.error(PASS, "undefined-output",
                      f"output {i} reads undefined wire {lit_var(o)}",
                      where=f"output {i}")
    # reachability: LUTs no output cone uses (function-preserving waste)
    live = {lit_var(o) for o in mapped.outputs}
    for l in reversed(mapped.luts):
        if l.root in live:
            live.update(l.leaves)
    dead = sum(1 for l in mapped.luts if l.root not in live)
    rep.checked += 1
    if dead:
        rep.warn(PASS, "dangling-lut",
                 f"{dead} LUT(s) unreachable from any output")
    rep.info["n_luts"] = mapped.n_luts
    rep.info["n_dead_luts"] = dead
    return rep
