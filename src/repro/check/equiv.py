"""Pass 2 — miter-style functional equivalence between pipeline stages.

Each adjacency of the synth pipeline gets a check:

    SOP cover      <->  AIG built from it       (``equiv_cover_aig``)
    AIG            <->  rewritten/balanced AIG  (``equiv_aigs``)
    AIG            <->  mapped k-LUT netlist    (``equiv_aig_mapped``)
    mapped netlist <->  DevicePlan tensors      (``equiv_mapped_plan``)
    LogicNetwork   <->  mapped netlist          (``equiv_network_mapped``)

Cones with <= ``exhaustive_limit`` primary inputs are *proved* by
exhaustive packed simulation (chunked so a 2^20-minterm sweep never
materializes the whole plane); beyond that, corner vectors (all-zeros,
all-ones, one-hot, one-cold) plus packed random words give the standard
random-simulation filter. Either way a mismatch yields the concrete
counterexample input pattern in the report.

The DevicePlan side is evaluated by ``execute_plan_host`` — an
independent slot-by-slot interpreter of the plan tensors, deliberately
*not* sharing code with ``synth.executor.execute_packed`` so a bug in
the plan compiler cannot hide behind shared evaluation code.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from repro.synth.aig import AIG
from repro.synth.executor import DevicePlan, MappedNetwork, execute_packed
from repro.synth.simulate import (WORD_BITS, pack_bits, simulate,
                                  unpack_bits)

from .report import CheckReport, Counterexample

PASS = "equiv"
FORMAL_PASS = "formal"

# beyond this many PIs exhaustive enumeration (2^n patterns) is skipped
EXHAUSTIVE_LIMIT = 20
# words simulated per chunk: bounds peak memory at
# n_nodes * CHUNK_WORDS * 4 bytes during exhaustive sweeps
CHUNK_WORDS = 2048

_LOW_VAR_WORDS = (0xAAAAAAAA, 0xCCCCCCCC, 0xF0F0F0F0, 0xFF00FF00,
                  0xFFFF0000)


def exhaustive_chunk(n_pis: int, word0: int, n_words: int) -> np.ndarray:
    """Packed exhaustive patterns for minterms [32*word0, 32*(word0 +
    n_words)): row v is variable v. Bit b of word w is minterm
    32*(word0+w)+b, so variable v < 5 is a fixed bit pattern and
    variable v >= 5 selects on word index."""
    out = np.empty((n_pis, n_words), np.uint32)
    w = np.arange(word0, word0 + n_words, dtype=np.uint64)
    for v in range(n_pis):
        if v < 5:
            out[v] = _LOW_VAR_WORDS[v]
        else:
            out[v] = np.where((w >> np.uint64(v - 5)) & np.uint64(1),
                              np.uint32(0xFFFFFFFF), np.uint32(0))
    return out


def corner_words(n_pis: int) -> np.ndarray:
    """Packed corner patterns: all-zeros, all-ones, every one-hot and
    every one-cold input — the boundary cases random sampling is least
    likely to hit on wide cones."""
    pats = [np.zeros(n_pis, np.uint8), np.ones(n_pis, np.uint8)]
    for i in range(n_pis):
        hot = np.zeros(n_pis, np.uint8)
        hot[i] = 1
        pats.append(hot)
        pats.append(1 - hot)
    return pack_bits(np.stack(pats, axis=1))


def random_pi_words(n_pis: int, n_words: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << WORD_BITS, (n_pis, n_words),
                        dtype=np.uint32)


def _first_mismatch(a: np.ndarray, b: np.ndarray,
                    n_valid_lanes: Optional[int] = None
                    ) -> Optional[Tuple[int, int, int]]:
    """(output_row, word, bit) of the first differing packed bit."""
    diff = a ^ b
    if n_valid_lanes is not None:
        nw = diff.shape[1]
        valid = (np.arange(nw * WORD_BITS) < n_valid_lanes).astype(np.uint8)
        mask = pack_bits(valid[None, :])[0]
        diff = diff & mask[None, :]
    rows, words = np.nonzero(diff)
    if rows.size == 0:
        return None
    i = int(np.lexsort((rows, words))[0])   # earliest input pattern first
    r, w = int(rows[i]), int(words[i])
    d = int(diff[r, w])
    bit = (d & -d).bit_length() - 1
    return r, w, bit


def _lane_bits(pi_words: np.ndarray, word: int, bit: int) -> Tuple[int, ...]:
    return tuple(int((pi_words[v, word] >> bit) & 1)
                 for v in range(pi_words.shape[0]))


EvalFn = Callable[[np.ndarray], np.ndarray]


def miter(eval_ref: EvalFn, eval_dut: EvalFn, n_pis: int,
          rep: CheckReport, stage: str,
          exhaustive_limit: int = EXHAUSTIVE_LIMIT,
          n_random_words: int = 64, seed: int = 0,
          pass_name: str = PASS) -> bool:
    """Compare two (n_pis, W) -> (n_out, W) evaluators; on mismatch,
    record the first counterexample on ``rep``. Returns equivalence."""
    if n_pis == 0:      # constant network: a single empty pattern
        empty = np.zeros((0, 1), np.uint32)
        a, b = np.asarray(eval_ref(empty)), np.asarray(eval_dut(empty))
        rep.checked += 1
        hit = _first_mismatch(a, b, n_valid_lanes=1)
        if hit is None:
            return True
        r, w, bit = hit
        cex = Counterexample((), r, int((b[r, w] >> bit) & 1),
                             int((a[r, w] >> bit) & 1), exhaustive=True)
        rep.error(pass_name, stage, "stages disagree on the constant network",
                  counterexample=cex)
        return False
    if n_pis <= exhaustive_limit:
        total_words = max(1, (1 << n_pis) // WORD_BITS)
        valid = (1 << n_pis) if n_pis < 5 else None
        for w0 in range(0, total_words, CHUNK_WORDS):
            nw = min(CHUNK_WORDS, total_words - w0)
            words = exhaustive_chunk(n_pis, w0, nw)
            a = np.asarray(eval_ref(words))
            b = np.asarray(eval_dut(words))
            rep.checked += nw * WORD_BITS if valid is None else valid
            hit = _first_mismatch(a, b, n_valid_lanes=valid)
            if hit is not None:
                r, w, bit = hit
                cex = Counterexample(_lane_bits(words, w, bit), r,
                                     int((b[r, w] >> bit) & 1),
                                     int((a[r, w] >> bit) & 1),
                                     exhaustive=True)
                rep.error(pass_name, stage,
                          f"exhaustive miter found a mismatch "
                          f"(minterm {(w0 + w) * WORD_BITS + bit})",
                          counterexample=cex)
                return False
        return True
    # wide cone: corners + random words (mismatch = proof; agreement =
    # strong evidence, 32 patterns per word)
    batches = [("corner", corner_words(n_pis))]
    if n_random_words > 0:
        batches.append(("random", random_pi_words(n_pis, n_random_words,
                                                  seed)))
    for kind, words in batches:
        a = np.asarray(eval_ref(words))
        b = np.asarray(eval_dut(words))
        n_valid = (2 * n_pis + 2 if kind == "corner"
                   else words.shape[1] * WORD_BITS)
        rep.checked += n_valid
        hit = _first_mismatch(a, b,
                              n_valid_lanes=(n_valid if kind == "corner"
                                             else None))
        if hit is not None:
            r, w, bit = hit
            cex = Counterexample(_lane_bits(words, w, bit), r,
                                 int((b[r, w] >> bit) & 1),
                                 int((a[r, w] >> bit) & 1))
            rep.error(pass_name, stage,
                      f"{kind}-vector miter found a mismatch "
                      f"({n_pis} PIs, exhaustive skipped)",
                      counterexample=cex)
            return False
    return True


# ---------------------------------------------------------------------------
# Formal (SAT) escalation
# ---------------------------------------------------------------------------

def _report_formal(rep: CheckReport, stage: str, res, eval_ref: EvalFn,
                   eval_dut: EvalFn, n_pis: int) -> bool:
    """Fold a ``FormalResult`` into the report.

    Returns True when the formal engine settled the question (UNSAT
    proof or SAT counterexample) — the caller then skips sampling.
    UNPROVEN records a warning and returns False: the caller *must*
    fall back to the sampled miter, loudly, never silently pass.
    """
    from .sat import SAT, UNSAT

    stat_keys = ("nodes", "queries", "merged_struct", "merged_sat",
                 "refuted", "query_unknown", "conflicts", "decisions",
                 "propagations", "outputs", "outputs_merged")
    rep.info[f"formal[{stage}]"] = {
        "verdict": res.verdict,
        **{k: res.stats[k] for k in stat_keys if k in res.stats}}
    if res.verdict == UNSAT:
        rep.checked += res.stats.get("outputs", 0)
        return True
    if res.verdict == SAT:
        words = pack_bits(np.array(res.cex, np.uint8)[:, None])
        a, b = np.asarray(eval_ref(words)), np.asarray(eval_dut(words))
        hit = _first_mismatch(a, b, n_valid_lanes=1)
        if hit is None:       # engine said SAT but the sim disagrees
            rep.error(FORMAL_PASS, stage,
                      "SAT counterexample failed bitplane replay — "
                      "formal engine bug, treat the stage as unverified")
            return True
        r, w, bit = hit
        cex = Counterexample(res.cex, r, int((b[r, w] >> bit) & 1),
                             int((a[r, w] >> bit) & 1), formal=True)
        rep.error(FORMAL_PASS, stage,
                  f"SAT miter proved inequivalence ({n_pis} PIs, "
                  f"{res.stats['conflicts']} conflicts); counterexample "
                  f"replayed through the bitplane sim",
                  counterexample=cex)
        return True
    rep.warn(FORMAL_PASS, stage,
             f"UNPROVEN: conflict budget exhausted "
             f"({res.stats['conflicts']} conflicts, "
             f"{res.stats['queries']} queries) — falling back to the "
             f"sampled miter, which is a filter, not a proof")
    return False


def _formal_kwargs(conflict_budget, seed):
    kw = {"seed": seed}
    if conflict_budget is not None:
        kw["conflict_budget"] = conflict_budget
    return kw


# ---------------------------------------------------------------------------
# Stage adjacencies
# ---------------------------------------------------------------------------

def equiv_aigs(ref: AIG, dut: AIG, name: str = "aig-rewrite",
               formal: bool = False, conflict_budget: Optional[int] = None,
               **kw) -> CheckReport:
    """AIG <-> transformed AIG (balance / rewrite must preserve the
    function on *every* input — no don't-cares at this stage).

    ``formal=True`` escalates cones wider than the exhaustive limit to
    the SAT engine: UNSAT is a proof at any width, SAT yields a
    replayed counterexample, UNPROVEN falls back to sampling."""
    rep = CheckReport(name)
    if ref.n_pis != dut.n_pis or len(ref.outputs) != len(dut.outputs):
        rep.error(PASS, "aig-rewrite",
                  f"interface mismatch: {ref.n_pis} PIs/"
                  f"{len(ref.outputs)} POs vs {dut.n_pis}/"
                  f"{len(dut.outputs)}")
        return rep
    e_ref = lambda w: simulate(ref, w)
    e_dut = lambda w: simulate(dut, w)
    limit = kw.get("exhaustive_limit", EXHAUSTIVE_LIMIT)
    if formal and ref.n_pis > limit:
        from .sat import prove_aig_equiv
        res = prove_aig_equiv(ref, dut,
                              **_formal_kwargs(conflict_budget,
                                               kw.get("seed", 0)))
        if _report_formal(rep, "aig-rewrite", res, e_ref, e_dut, ref.n_pis):
            return rep
        kw.setdefault("pass_name", FORMAL_PASS)
    miter(e_ref, e_dut, ref.n_pis, rep, "aig-rewrite", **kw)
    return rep


def equiv_aig_mapped(aig: AIG, mapped: MappedNetwork,
                     name: str = "aig-mapped", formal: bool = False,
                     conflict_budget: Optional[int] = None,
                     **kw) -> CheckReport:
    """AIG <-> its k-LUT cover (mapping covers exact cone functions, so
    this too must hold on every input); ``formal=True`` as in
    :func:`equiv_aigs`."""
    rep = CheckReport(name)
    if aig.n_pis != mapped.n_pis or len(aig.outputs) != len(mapped.outputs):
        rep.error(PASS, "aig-mapped",
                  f"interface mismatch: {aig.n_pis} PIs/"
                  f"{len(aig.outputs)} POs vs {mapped.n_pis}/"
                  f"{len(mapped.outputs)}")
        return rep
    e_ref = lambda w: simulate(aig, w)
    e_dut = lambda w: execute_packed(mapped, w)
    limit = kw.get("exhaustive_limit", EXHAUSTIVE_LIMIT)
    if formal and aig.n_pis > limit:
        from .sat import prove_aig_mapped
        res = prove_aig_mapped(aig, mapped,
                               **_formal_kwargs(conflict_budget,
                                                kw.get("seed", 0)))
        if _report_formal(rep, "aig-mapped", res, e_ref, e_dut, aig.n_pis):
            return rep
        kw.setdefault("pass_name", FORMAL_PASS)
    miter(e_ref, e_dut, aig.n_pis, rep, "aig-mapped", **kw)
    return rep


def execute_plan_host(dplan: DevicePlan, pi_words: np.ndarray) -> np.ndarray:
    """Slot-by-slot host interpreter of the DevicePlan tensors — the
    reference semantics of the ``lut_eval`` kernel, sharing no code with
    ``execute_packed``'s level-vectorized fold."""
    pi_words = np.asarray(pi_words, np.uint32)
    w = pi_words.shape[1]
    wires = np.zeros((dplan.n_wires + 1, w), np.uint32)   # +1 = dump row
    wires[1: dplan.n_pis + 1] = pi_words
    n_levels, lw, k = dplan.leaf_idx.shape
    for lvl in range(n_levels):
        for s in range(lw):
            ins = wires[dplan.leaf_idx[lvl, s]]            # (k, W)
            state = np.repeat(dplan.tt_bits[lvl, s][:, None], w, axis=1)
            half = state.shape[0] // 2
            for j in range(k - 1, -1, -1):
                sel = ins[j]
                state = (state[:half] & ~sel) | (state[half:] & sel)
                half //= 2
            wires[dplan.out_wires[lvl, s]] = state[0]
    out = wires[dplan.out_idx]
    out[dplan.out_neg] = ~out[dplan.out_neg]
    return out


def equiv_mapped_plan(mapped: MappedNetwork, dplan: DevicePlan,
                      name: str = "mapped-plan", formal: bool = False,
                      conflict_budget: Optional[int] = None,
                      **kw) -> CheckReport:
    """Mapped netlist <-> its stacked/padded DevicePlan tensors;
    ``formal=True`` as in :func:`equiv_aigs`."""
    rep = CheckReport(name)
    if mapped.n_pis != dplan.n_pis or \
            len(mapped.outputs) != dplan.out_idx.shape[0]:
        rep.error(PASS, "mapped-plan",
                  f"interface mismatch: {mapped.n_pis} PIs/"
                  f"{len(mapped.outputs)} POs vs {dplan.n_pis}/"
                  f"{dplan.out_idx.shape[0]}")
        return rep
    e_ref = lambda w: execute_packed(mapped, w)
    e_dut = lambda w: execute_plan_host(dplan, w)
    limit = kw.get("exhaustive_limit", EXHAUSTIVE_LIMIT)
    if formal and mapped.n_pis > limit:
        from .sat import prove_mapped_plan
        res = prove_mapped_plan(mapped, dplan,
                                **_formal_kwargs(conflict_budget,
                                                 kw.get("seed", 0)))
        if _report_formal(rep, "mapped-plan", res, e_ref, e_dut,
                          mapped.n_pis):
            return rep
        kw.setdefault("pass_name", FORMAL_PASS)
    miter(e_ref, e_dut, mapped.n_pis, rep, "mapped-plan", **kw)
    return rep


def eval_cover_words(cover, pi_words: np.ndarray) -> np.ndarray:
    """Evaluate an espresso ``Cover`` (SOP) on packed words: OR over
    cubes of AND over literals. (1, W) output."""
    from repro.core.espresso import FREE

    w = pi_words.shape[1]
    acc = np.zeros(w, np.uint32)
    for cube in cover.cubes:
        term = np.full(w, 0xFFFFFFFF, np.uint32)
        for v in range(cover.n_vars):
            if cube[v] == FREE:
                continue
            pv = pi_words[v]
            term &= pv if cube[v] == 1 else ~pv
        acc |= term
    return acc[None, :]


def equiv_cover_aig(cover, aig: AIG, dc_mask=None,
                    name: str = "sop-aig", **kw) -> CheckReport:
    """SOP cover <-> single-output AIG built from it. ``dc_mask`` is an
    optional dense bool array over minterms: rows where the function is
    a don't-care are excluded (the AIG is free to differ there)."""
    rep = CheckReport(name)
    n = cover.n_vars
    if aig.n_pis != n or len(aig.outputs) != 1:
        rep.error(PASS, "sop-aig",
                  f"interface mismatch: cover has {n} vars, AIG has "
                  f"{aig.n_pis} PIs / {len(aig.outputs)} POs")
        return rep
    if dc_mask is None:
        miter(lambda w: eval_cover_words(cover, w),
              lambda w: simulate(aig, w), n, rep, "sop-aig", **kw)
        return rep
    dc_mask = np.asarray(dc_mask, bool)

    def masked(fn):
        def run(words):
            # zero the DC lanes on both sides so they always agree
            nw = words.shape[1]
            mint = np.arange(nw * WORD_BITS) % dc_mask.shape[0]
            care = pack_bits((~dc_mask[mint])[None, :].astype(np.uint8))
            return np.asarray(fn(words)) & care
        return run

    miter(masked(lambda w: eval_cover_words(cover, w)),
          masked(lambda w: simulate(aig, w)), n, rep, "sop-aig", **kw)
    return rep


def _net_mapped_eval(net, mapped: MappedNetwork, codes: np.ndarray):
    """(got, want) output codes of the mapped net vs the LogicNetwork
    oracle on a (n, n_inputs) batch of input codes."""
    n = codes.shape[0]
    want = np.asarray(net.apply_codes(codes))
    in_bits = net.in_spec.code_bits
    planes = np.empty((codes.shape[1] * in_bits, n), np.uint8)
    for b in range(in_bits):
        planes[b::in_bits] = ((codes >> b) & 1).T
    out_words = execute_packed(mapped, pack_bits(planes))
    out_bits_arr = unpack_bits(out_words, n)
    out_bits = net.layers[-1].out_spec.code_bits
    got = np.zeros((n, out_bits_arr.shape[0] // out_bits), np.int64)
    for b in range(out_bits):
        got |= out_bits_arr[b::out_bits].T.astype(np.int64) << b
    return got, want


def equiv_network_mapped(net, mapped: MappedNetwork,
                         n_samples: int = 1024, seed: int = 0,
                         formal: bool = False,
                         conflict_budget: Optional[int] = None,
                         name: str = "network-mapped") -> CheckReport:
    """LogicNetwork truth-table oracle <-> mapped netlist on *valid*
    input codes.

    The SOP extraction feeds espresso unreachable codes as don't-cares,
    so the mapped net only promises equality on codes the quantizer can
    produce — arbitrary bit patterns would yield false counterexamples.
    The counterexample here is therefore reported as an input *code*
    row, not a PI bit pattern.

    ``formal=True`` first runs the SAT engine with the quantizer care
    set encoded as CNF blocking clauses: UNSAT proves equality on every
    reachable code (any width), SAT yields a code-row counterexample
    replayed through the bitplane sim, UNPROVEN falls back to the
    sampled code check below.
    """
    rep = CheckReport(name)
    in_bits = net.in_spec.code_bits
    if formal:
        from .sat import SAT, UNSAT, prove_network_mapped
        res = prove_network_mapped(
            net, mapped, **_formal_kwargs(conflict_budget, seed))
        stage = "network-mapped"
        rep.info[f"formal[{stage}]"] = {
            "verdict": res.verdict,
            **{k: res.stats[k] for k in
               ("nodes", "queries", "merged_struct", "merged_sat",
                "refuted", "query_unknown", "conflicts", "outputs",
                "outputs_merged") if k in res.stats}}
        if res.verdict == UNSAT:
            rep.checked += res.stats.get("outputs", 0)
            return rep
        if res.verdict == SAT:
            bits = np.array(res.cex, np.int64)
            codes = np.zeros((1, net.n_inputs), np.int64)
            for b in range(in_bits):
                codes[0] |= bits[b::in_bits] << b
            got, want = _net_mapped_eval(net, mapped, codes)
            jbad = np.nonzero(got[0] != want[0])[0]
            if jbad.size == 0:
                rep.error(FORMAL_PASS, stage,
                          "SAT counterexample failed bitplane replay — "
                          "formal engine bug, treat the stage as "
                          "unverified")
                return rep
            j = int(jbad[0])
            cex = Counterexample(tuple(int(c) for c in codes[0]), j,
                                 int(got[0, j]), int(want[0, j]),
                                 formal=True)
            rep.error(FORMAL_PASS, stage,
                      f"SAT miter proved inequivalence on a reachable "
                      f"code row ({res.stats['conflicts']} conflicts; "
                      f"inputs below are quantizer *codes*, not PI "
                      f"bits); replayed through the bitplane sim",
                      counterexample=cex)
            return rep
        rep.warn(FORMAL_PASS, stage,
                 f"UNPROVEN: conflict budget exhausted "
                 f"({res.stats['conflicts']} conflicts) — falling back "
                 f"to sampled code rows, which is a filter, not a proof")
    rng = np.random.default_rng(seed)
    n_levels = net.in_spec.n_levels
    codes = rng.integers(0, n_levels, (n_samples, net.n_inputs),
                         dtype=np.int64)
    got, want = _net_mapped_eval(net, mapped, codes)
    rep.checked += n_samples
    bad = np.nonzero(np.any(got != want, axis=1))[0]
    if bad.size:
        r = int(bad[0])
        j = int(np.nonzero(got[r] != want[r])[0][0])
        cex = Counterexample(tuple(int(c) for c in codes[r]), j,
                             int(got[r, j]), int(want[r, j]))
        rep.error(PASS, "network-mapped",
                  f"mapped netlist disagrees with the truth-table oracle "
                  f"on {bad.size}/{n_samples} sampled code rows (inputs "
                  f"below are quantizer *codes*, not PI bits)",
                  counterexample=cex)
    return rep
