"""Typed findings shared by every ``repro.check`` pass.

A pass returns a ``CheckReport``: a list of ``Issue``s (error or
warning severity) plus a count of invariants/vectors it actually
examined, so "clean" is distinguishable from "didn't look". Equivalence
failures carry a ``Counterexample`` — the concrete PI bit pattern on
which the two stages disagree — because "not equivalent" without the
witness input is not actionable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Counterexample:
    """A witness input on which two pipeline stages disagree."""

    inputs: Tuple[int, ...]      # one {0,1} bit per primary input wire
    output: int                  # index of the first mismatching output
    got: int                     # value produced by the stage under test
    want: int                    # value produced by the reference stage
    exhaustive: bool = False     # found during exhaustive enumeration
    formal: bool = False         # decoded from a SAT model (and replayed)

    def __str__(self) -> str:
        bits = "".join(str(b) for b in self.inputs)
        kind = ("SAT" if self.formal
                else "exhaustive" if self.exhaustive else "sampled")
        return (f"output[{self.output}]: got {self.got}, want {self.want} "
                f"on PI pattern [pi0..pi{len(self.inputs) - 1}]={bits} "
                f"({kind})")


@dataclasses.dataclass
class Issue:
    pass_name: str               # "lint" | "equiv" | "plan" | "concurrency"
    code: str                    # machine-readable, e.g. "init-width"
    message: str
    severity: str = ERROR
    where: str = ""              # LUT index, wire, file:line, ...
    counterexample: Optional[Counterexample] = None

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        cex = f"\n      counterexample: {self.counterexample}" \
            if self.counterexample else ""
        return (f"{self.severity.upper()} {self.pass_name}/{self.code}"
                f"{loc}: {self.message}{cex}")


@dataclasses.dataclass
class CheckReport:
    name: str
    issues: List[Issue] = dataclasses.field(default_factory=list)
    checked: int = 0             # invariants / vectors examined
    info: Dict[str, object] = dataclasses.field(default_factory=dict)

    def error(self, pass_name: str, code: str, message: str,
              where: str = "",
              counterexample: Optional[Counterexample] = None) -> None:
        self.issues.append(Issue(pass_name, code, message, ERROR, where,
                                 counterexample))

    def warn(self, pass_name: str, code: str, message: str,
             where: str = "") -> None:
        self.issues.append(Issue(pass_name, code, message, WARNING, where))

    @property
    def errors(self) -> List[Issue]:
        return [i for i in self.issues if i.severity == ERROR]

    @property
    def warnings(self) -> List[Issue]:
        return [i for i in self.issues if i.severity == WARNING]

    @property
    def ok(self) -> bool:
        """No error-severity findings (warnings do not fail a check)."""
        return not self.errors

    def merge(self, other: "CheckReport") -> "CheckReport":
        self.issues.extend(other.issues)
        self.checked += other.checked
        for k, v in other.info.items():
            self.info.setdefault(k, v)
        return self

    def format(self, verbose: bool = False) -> str:
        head = (f"[check] {self.name}: "
                f"{'OK' if self.ok else 'FAIL'} "
                f"({self.checked} checks, {len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s))")
        shown = self.issues if verbose else self.errors
        return "\n".join([head] + [f"  {i}" for i in shown])


class CheckFailure(RuntimeError):
    """Raised by ``verify=True`` entry points when a pass finds errors."""

    def __init__(self, report: CheckReport):
        super().__init__(report.format())
        self.report = report


def require_ok(report: CheckReport) -> CheckReport:
    if not report.ok:
        raise CheckFailure(report)
    return report
