"""Pass 3 — static validation of ``DevicePlan`` tensors before launch.

``compile_device_plan`` output is what the ``lut_eval`` Pallas kernel
trusts blindly: wire indices become unchecked VMEM loads/stores, INIT
masks become the Shannon fold, and the dump-row convention turns padded
slots into silent no-ops. A malformed plan therefore fails *on device*
(or worse, silently corrupts the wire plane), so every contract is
checked here on the host first:

  * shape/dtype contracts of all six tensors;
  * leaf indices in [0, n_wires) — a leaf must never read the dump row;
  * every real wire written exactly once, only by its own level, and
    read only by strictly later levels (levelization);
  * no-op (padded) slots fully inert: const-wire leaves, all-zero INIT,
    dump-row output;
  * INIT masks restricted to the {0, 0xFFFFFFFF} bitplane encoding;
  * output gather indices/complements in range;
  * estimated VMEM footprint (wire plane + plan tensors at the kernel's
    word tile) against a configurable budget.

Results are cached by a content hash of the plan so the serving hot
path (which validates on every ``--check`` preflight) pays the cost
once per distinct netlist version.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Optional

import numpy as np

from repro.synth.executor import DevicePlan

from .report import CheckReport

PASS = "plan"

# mirrors kernels/lut_eval DEFAULT_BW without importing jax here
_DEFAULT_BLOCK_W = 128
# one TPU core's VMEM; the kernel wants the whole wire plane resident
DEFAULT_VMEM_BUDGET = 16 << 20

_FULL = np.uint32(0xFFFFFFFF)

_CACHE: Dict[str, CheckReport] = {}


def plan_fingerprint(dplan: DevicePlan) -> str:
    """Content hash over every tensor and scalar the kernel consumes."""
    h = hashlib.sha1()
    for arr in (dplan.leaf_idx, dplan.tt_bits, dplan.out_wires,
                dplan.out_idx, dplan.out_neg):
        a = np.ascontiguousarray(arr)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    h.update(f"{dplan.n_pis},{dplan.n_wires},{dplan.k}".encode())
    return h.hexdigest()


def estimate_vmem_bytes(dplan: DevicePlan,
                        block_w: int = _DEFAULT_BLOCK_W) -> int:
    """Working-set estimate for one *monolithic* lut_eval grid step:
    the (n_wires+1, block_w) wire plane plus the full plan tensors
    (leaf indices / INIT masks / output wires live on-chip for the
    whole slot walk)."""
    plane = (dplan.n_wires + 1) * block_w * 4
    plan = (dplan.leaf_idx.size * 4 + dplan.tt_bits.size * 4
            + dplan.out_wires.size * 4)
    return plane + plan


def estimate_tile_vmem_bytes(tplan, block_w: int = _DEFAULT_BLOCK_W) -> int:
    """Working-set estimate for one *streamed* tile step. The wire
    plane stays in HBM; on-chip the kernel holds the PI block, the
    double-buffered plan tensors for two tiles, the staged leaf rows
    (DMA-gather mode), the gathered-input/fold state of one tile, and
    the output band — so the budget scales with (tile_rows, gather_cap,
    block_w), never with netlist size."""
    t, k, g = tplan.tile_rows, tplan.k, tplan.gather_cap
    n_tt = 1 << k
    pis = tplan.n_pis * block_w * 4
    bufs = 2 * t * n_tt * 4 + 2 * t * k * 4        # double-buffered plans
    stage = 2 * g * block_w * 4                    # staged leaf rows (dma)
    fold = t * n_tt * block_w * 4 + t * k * block_w * 4   # state + gathers
    band = t * block_w * 4                         # contiguous out band
    return pis + bufs + stage + fold + band


def validate_device_plan(dplan: DevicePlan,
                         vmem_budget_bytes: Optional[int]
                         = DEFAULT_VMEM_BUDGET,
                         block_w: int = _DEFAULT_BLOCK_W,
                         use_cache: bool = True,
                         name: str = "device-plan") -> CheckReport:
    """Static checks on a compiled ``DevicePlan``; cached by plan hash."""
    tp = getattr(dplan, "tiles", None)
    key = None
    if use_cache:
        tile_key = (tp.tile_rows, tp.gather_cap) if tp is not None else None
        key = (plan_fingerprint(dplan), vmem_budget_bytes, block_w,
               tile_key)
        hit = _CACHE.get(key)
        if hit is not None:
            return hit
    rep = _validate(dplan, vmem_budget_bytes, block_w, name)
    if use_cache:
        _CACHE[key] = rep
    return rep


def _validate(dplan: DevicePlan, vmem_budget_bytes: Optional[int],
              block_w: int, name: str) -> CheckReport:
    rep = CheckReport(name)
    li, tt, ow = dplan.leaf_idx, dplan.tt_bits, dplan.out_wires
    oi, on = dplan.out_idx, dplan.out_neg
    nw, k, n_pis = dplan.n_wires, dplan.k, dplan.n_pis

    # ---- dtype / shape contracts ----
    rep.checked += 1
    for aname, arr, dt in (("leaf_idx", li, np.int32),
                           ("tt_bits", tt, np.uint32),
                           ("out_wires", ow, np.int32),
                           ("out_idx", oi, np.int32)):
        if arr.dtype != dt:
            rep.error(PASS, "dtype",
                      f"{aname} dtype {arr.dtype} != {np.dtype(dt)}",
                      where=aname)
    if on.dtype != np.bool_:
        rep.error(PASS, "dtype", f"out_neg dtype {on.dtype} != bool",
                  where="out_neg")
    if li.ndim != 3:
        rep.error(PASS, "shape", f"leaf_idx rank {li.ndim} != 3",
                  where="leaf_idx")
        return rep
    n_levels, lw, kk = li.shape
    rep.checked += 1
    if kk != k:
        rep.error(PASS, "shape",
                  f"leaf_idx last dim {kk} != k={k}", where="leaf_idx")
    if tt.shape != (n_levels, lw, 1 << k):
        rep.error(PASS, "shape",
                  f"tt_bits shape {tt.shape} != "
                  f"{(n_levels, lw, 1 << k)} (INIT width 2^k)",
                  where="tt_bits")
        return rep
    if ow.shape != (n_levels, lw):
        rep.error(PASS, "shape",
                  f"out_wires shape {ow.shape} != {(n_levels, lw)}",
                  where="out_wires")
        return rep
    if oi.shape != on.shape or oi.ndim != 1:
        rep.error(PASS, "shape",
                  f"out_idx {oi.shape} / out_neg {on.shape} must be "
                  f"equal rank-1 shapes")
        return rep
    n_luts = nw - 1 - n_pis
    rep.checked += 1
    if n_luts < 0:
        rep.error(PASS, "wire-count",
                  f"n_wires {nw} < 1 + n_pis {n_pis}")
        return rep

    # ---- INIT masks: bitplane {0, ~0} encoding only ----
    rep.checked += 1
    bad_tt = (tt != 0) & (tt != _FULL)
    if bad_tt.any():
        lvl, s, r = (int(x[0]) for x in np.nonzero(bad_tt))
        rep.error(PASS, "tt-encoding",
                  f"tt_bits[{lvl},{s},{r}] = {tt[lvl, s, r]:#x} is "
                  f"neither 0 nor 0xFFFFFFFF (bitplane mask encoding)",
                  where=f"level {lvl} slot {s}")

    # ---- leaf reads: in range, never the dump row, only earlier levels
    rep.checked += 1
    if li.size and (li.min() < 0 or li.max() >= nw):
        lvl, s, j = (int(x[0]) for x in
                     np.nonzero((li < 0) | (li >= nw)))
        rep.error(PASS, "leaf-range",
                  f"leaf_idx[{lvl},{s},{j}] = {li[lvl, s, j]} outside "
                  f"[0, {nw}) — reading the dump row or beyond",
                  where=f"level {lvl} slot {s}")

    # ---- output wires: pad slots use the dump row; real slots cover
    # every LUT wire exactly once at a consistent level ----
    pad = ow == nw
    rep.checked += 1
    if ow.size and ((ow < n_pis + 1) | (ow > nw)).any():
        lvl, s = (int(x[0]) for x in
                  np.nonzero((ow < n_pis + 1) | (ow > nw)))
        rep.error(PASS, "out-range",
                  f"out_wires[{lvl},{s}] = {ow[lvl, s]} outside the LUT "
                  f"wire range [{n_pis + 1}, {nw}]",
                  where=f"level {lvl} slot {s}")
        return rep
    real = ow[~pad]
    rep.checked += 1
    if real.size != n_luts or (real.size and
                               not np.array_equal(
                                   np.sort(real),
                                   np.arange(n_pis + 1, nw))):
        counts = np.bincount(real - (n_pis + 1), minlength=max(n_luts, 0)) \
            if real.size else np.zeros(max(n_luts, 0), np.int64)
        dup = np.nonzero(counts > 1)[0]
        missing = np.nonzero(counts == 0)[0]
        detail = []
        if dup.size:
            detail.append(f"wire {dup[0] + n_pis + 1} written "
                          f"{counts[dup[0]]}x")
        if missing.size:
            detail.append(f"wire {missing[0] + n_pis + 1} never written")
        rep.error(PASS, "wire-cover",
                  f"real slots write {real.size} wires but the plan "
                  f"declares {n_luts} LUTs"
                  + (f" ({'; '.join(detail)})" if detail else ""))

    # level of each wire (PIs/const = level 0; LUT wires = writing level+1)
    wire_level = np.zeros(nw + 1, np.int64)
    for lvl in range(n_levels):
        w = ow[lvl][~pad[lvl]]
        wire_level[w] = lvl + 1
    rep.checked += 1
    for lvl in range(n_levels):
        leaves = li[lvl][~pad[lvl]]          # (slots, k)
        if leaves.size and (wire_level[leaves] > lvl).any():
            s, j = (int(x[0]) for x in
                    np.nonzero(wire_level[leaves] > lvl))
            rep.error(PASS, "level-order",
                      f"level {lvl} reads wire {leaves[s, j]} which is "
                      f"written at level {wire_level[leaves[s, j]] - 1} "
                      f"(same level or later)",
                      where=f"level {lvl}")
            break

    # ---- no-op slot consistency ----
    rep.checked += 1
    for lvl in range(n_levels):
        p = pad[lvl]
        if not p.any():
            continue
        if li[lvl][p].any():
            s = int(np.nonzero(p)[0][np.nonzero(li[lvl][p].any(axis=1))
                                     [0][0]])
            rep.error(PASS, "pad-slot",
                      f"padded slot ({lvl},{s}) reads wire "
                      f"{int(li[lvl, s].max())} instead of the constant "
                      f"wire", where=f"level {lvl} slot {s}")
            break
        if tt[lvl][p].any():
            s = int(np.nonzero(p)[0][np.nonzero(tt[lvl][p].any(axis=1))
                                     [0][0]])
            rep.error(PASS, "pad-slot",
                      f"padded slot ({lvl},{s}) has nonzero INIT masks "
                      f"— it would write garbage to the dump row",
                      where=f"level {lvl} slot {s}")
            break

    # ---- output gather ----
    rep.checked += 1
    if oi.size and ((oi < 0) | (oi >= nw)).any():
        i = int(np.nonzero((oi < 0) | (oi >= nw))[0][0])
        rep.error(PASS, "out-idx",
                  f"out_idx[{i}] = {oi[i]} outside [0, {nw})",
                  where=f"output {i}")

    # ---- tile schedule consistency (streamed kernel) ----
    tp = getattr(dplan, "tiles", None)
    if tp is not None:
        rep.checked += 1
        staged = tp.gather_rows[
            np.arange(tp.n_tiles)[:, None, None], tp.leaf_loc]
        if not np.array_equal(staged, tp.leaf_tiles):
            t, s, j = (int(x[0]) for x in
                       np.nonzero(staged != tp.leaf_tiles))
            rep.error(PASS, "tile-gather",
                      f"gather_rows[{t}][leaf_loc[{t},{s},{j}]] = "
                      f"{staged[t, s, j]} != leaf_tiles[{t},{s},{j}] = "
                      f"{tp.leaf_tiles[t, s, j]} — the staged-DMA remap "
                      f"disagrees with the direct leaf rows",
                      where=f"tile {t} slot {s}")
        rep.checked += 1
        bad = tp.leaf_tiles >= tp.out_base[:, None, None]
        if bad.any():
            t, s, j = (int(x[0]) for x in np.nonzero(bad))
            rep.error(PASS, "tile-order",
                      f"tile {t} (band starts at row {tp.out_base[t]}) "
                      f"reads row {tp.leaf_tiles[t, s, j]} from its own "
                      f"or a later band — streamed tile order would "
                      f"read unwritten rows", where=f"tile {t} slot {s}")

    # ---- VMEM footprint ----
    # With a tile schedule attached the streamed kernel keeps the wire
    # plane in HBM, so the budget applies per tile step; otherwise the
    # monolithic kernel needs the whole plane resident.
    rep.info["n_levels"] = n_levels
    rep.info["level_width"] = lw
    rep.checked += 1
    if tp is not None:
        est = estimate_tile_vmem_bytes(tp, block_w)
        rep.info["vmem_bytes"] = est
        rep.info["tile_rows"] = tp.tile_rows
        rep.info["n_tiles"] = tp.n_tiles
        if vmem_budget_bytes is not None and est > vmem_budget_bytes:
            rep.error(PASS, "vmem-budget",
                      f"estimated per-tile VMEM working set "
                      f"{est / 2**20:.1f} MiB (tile_rows "
                      f"{tp.tile_rows} x {block_w} words, gather_cap "
                      f"{tp.gather_cap}) exceeds the "
                      f"{vmem_budget_bytes / 2**20:.1f} MiB budget — "
                      f"shrink tile_rows or block_w")
    else:
        est = estimate_vmem_bytes(dplan, block_w)
        rep.info["vmem_bytes"] = est
        if vmem_budget_bytes is not None and est > vmem_budget_bytes:
            rep.error(PASS, "vmem-budget",
                      f"estimated VMEM working set {est / 2**20:.1f} MiB "
                      f"(wire plane {nw + 1} x {block_w} words + plan "
                      f"tensors) exceeds the "
                      f"{vmem_budget_bytes / 2**20:.1f} MiB budget — use "
                      f"the streamed engine (engine=\"pallas-streamed\" "
                      f"/ compile_device_plan(tile_rows=...)) or a "
                      f"smaller block_w")
    return rep
