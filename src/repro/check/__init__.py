"""repro.check — static analysis and verification for the synth->serve
stack.

Five passes, all runnable via ``python -m repro.check``:

  1. **netlist lint** (``netlist_lint``) — structural invariants of the
     AIG and the mapped k-LUT netlist;
  2. **equivalence** (``equiv``) — miter-style functional equivalence
     between adjacent pipeline stages, exhaustive up to ~20 inputs,
     counterexample-reporting beyond;
  3. **device-plan validation** (``plan_check``) — shape/dtype/index/
     VMEM contracts of ``DevicePlan`` tensors, cached by plan hash;
  4. **concurrency lint** (``concurrency``) — AST lock-discipline and
     reject-reason coverage over ``repro.serve``;
  5. **trace schema** (``tracecheck``) — invariants of exported
     ``repro.obs`` traces: span-time monotonicity/nesting, async
     begin/end pairing with no orphans, flush-reason and terminal-
     outcome vocabularies;
  6. **formal equivalence** (``sat``) — SAT-proved miters for cones
     beyond the exhaustive limit: Tseitin/ISOP CNF of both sides,
     quantizer care set as blocking clauses, a self-contained CDCL
     solver, and a SAT-sweep duplicate-LUT lint; verdicts are UNSAT
     (proof), SAT (replayed counterexample) or UNPROVEN (budget
     exhausted, falls back to sampling loudly).

``pipeline.check_synth_pipeline`` chains 1–3 (and 6 with
``formal=True``) over a real synthesis run; ``pipeline.preflight`` is
the serving-startup subset behind ``python -m repro.launch.serve
--check``.
"""
from .concurrency import check_concurrency
from .equiv import (equiv_aig_mapped, equiv_aigs, equiv_cover_aig,
                    equiv_mapped_plan, equiv_network_mapped,
                    execute_plan_host, miter)
from .sat import (DEFAULT_CONFLICT_BUDGET, CareSet, FormalResult,
                  check_duplicate_lut_outputs, find_duplicate_lut_outputs,
                  merge_duplicate_lut_outputs, prove_aig_equiv,
                  prove_aig_mapped, prove_mapped_equiv, prove_mapped_plan,
                  prove_network_mapped)
from .netlist_lint import lint_aig, lint_mapped
from .pipeline import (check_sop_stage, check_static, check_synth_pipeline,
                       preflight, verify_plan, verify_synthesis)
from .plan_check import (DEFAULT_VMEM_BUDGET, estimate_tile_vmem_bytes,
                         estimate_vmem_bytes, plan_fingerprint,
                         validate_device_plan)
from .report import (Counterexample, CheckFailure, CheckReport, Issue,
                     require_ok)
from .srclint import check_duplicate_definitions
from .tracecheck import (check_trace, check_trace_file,
                         synthetic_trace_events)

__all__ = [
    "CheckFailure", "CheckReport", "Counterexample", "Issue",
    "CareSet", "FormalResult",
    "DEFAULT_CONFLICT_BUDGET", "DEFAULT_VMEM_BUDGET",
    "check_concurrency", "check_duplicate_definitions",
    "check_duplicate_lut_outputs", "check_sop_stage",
    "check_static", "check_synth_pipeline", "check_trace",
    "check_trace_file", "find_duplicate_lut_outputs",
    "merge_duplicate_lut_outputs",
    "prove_aig_equiv", "prove_aig_mapped", "prove_mapped_equiv",
    "prove_mapped_plan", "prove_network_mapped",
    "equiv_aig_mapped", "equiv_aigs", "equiv_cover_aig",
    "equiv_mapped_plan", "equiv_network_mapped", "execute_plan_host",
    "estimate_tile_vmem_bytes", "estimate_vmem_bytes", "lint_aig",
    "lint_mapped", "miter",
    "plan_fingerprint", "preflight", "require_ok",
    "synthetic_trace_events",
    "validate_device_plan", "verify_plan", "verify_synthesis",
]
