"""Pass 4 — AST concurrency lint over the serving stack.

Two checks, both purely static:

**Lock discipline.** A class opts in by declaring a ``_GUARDED_BY``
dict-literal class attribute mapping field names to the lock attribute
that guards them::

    class MicroBatchScheduler:
        _GUARDED_BY = {"_stopping": "_cond", "_shutdown": "_cond"}

The lint then walks every method (except ``__init__``, which runs
before the object is shared) and flags any ``self.<field>`` load or
store that is not lexically inside a ``with self.<lock>:`` block for
the declared lock. Lexical nesting is a conservative approximation —
it cannot see a lock held by a caller — so helpers that *require* the
lock already held can be exempted by listing them in a
``_LOCKED_METHODS`` tuple class attribute (the lint then also checks
they are never called from an unlocked context within the class).

Fields that are *intentionally* unguarded (single-writer counters,
append-before-serving callback lists, racy-but-monotonic timestamps)
are declared in a ``_LOCK_FREE`` tuple — that records the decision in
code instead of leaving the field looking forgotten, and the lint
rejects a field listed in both ``_GUARDED_BY`` and ``_LOCK_FREE`` as a
conflicting annotation. Both annotations cover ``repro.serve`` and the
shared-mutable classes of ``repro.obs`` (windowed metrics, burn-rate
monitor, online profiler — all fed from scheduler/executor/client
threads concurrently).

**Reject-reason coverage.** Every constant on ``RejectReason`` must
have (a) a real code path in ``repro.serve`` that raises/records it and
(b) at least one test referencing it — a reason nothing can raise, or
one no test pins down, is dead policy.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .report import CheckReport

PASS = "concurrency"

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
SERVE_DIR = _REPO_ROOT / "src" / "repro" / "serve"
OBS_DIR = _REPO_ROOT / "src" / "repro" / "obs"
TEST_DIR = _REPO_ROOT / "tests"
SERVE_FILES = ("sched.py", "replica.py", "aggregate.py")
OBS_FILES = ("window.py", "slo.py", "online.py")


# ---------------------------------------------------------------------------
# lock discipline
# ---------------------------------------------------------------------------

def _dict_literal(node: ast.AST) -> Optional[Dict[str, str]]:
    if not isinstance(node, ast.Dict):
        return None
    out = {}
    for k, v in zip(node.keys, node.values):
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)
                and isinstance(v, ast.Constant)
                and isinstance(v.value, str)):
            return None
        out[k.value] = v.value
    return out


def _str_tuple(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    return ()


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.<name>`` -> name, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class _MethodLockWalker(ast.NodeVisitor):
    """Collect guarded-field accesses with the set of self-locks held
    lexically at each access point."""

    def __init__(self, guarded: Dict[str, str]):
        self.guarded = guarded
        self.held: Set[str] = set()
        # (field, lock_required, lineno, held_snapshot)
        self.accesses: List[Tuple[str, str, int, Set[str]]] = []
        self.calls: List[Tuple[str, int, Set[str]]] = []  # self-method calls

    def visit_With(self, node: ast.With) -> None:
        locks = []
        for item in node.items:
            a = _self_attr(item.context_expr)
            if a is not None:
                locks.append(a)
        added = [a for a in locks if a not in self.held]
        self.held.update(added)
        for item in node.items:
            self.visit(item.context_expr)
        for stmt in node.body:
            self.visit(stmt)
        self.held.difference_update(added)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        a = _self_attr(node)
        if a is not None and a in self.guarded:
            self.accesses.append((a, self.guarded[a], node.lineno,
                                  set(self.held)))
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        a = _self_attr(node.func)
        if a is not None:
            self.calls.append((a, node.lineno, set(self.held)))
        self.generic_visit(node)

    # a nested function/lambda runs later, possibly without the lock —
    # treat its body as lock-free
    def _nested(self, node: ast.AST) -> None:
        saved, self.held = self.held, set()
        self.generic_visit(node)
        self.held = saved

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._nested(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._nested(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._nested(node)


def lint_class_locks(cls: ast.ClassDef, rep: CheckReport,
                     filename: str) -> None:
    guarded: Dict[str, str] = {}
    locked_methods: Tuple[str, ...] = ()
    lock_free: Tuple[str, ...] = ()
    for stmt in cls.body:
        if (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            if stmt.targets[0].id == "_GUARDED_BY":
                d = _dict_literal(stmt.value)
                if d is None:
                    rep.error(PASS, "bad-annotation",
                              f"{cls.name}._GUARDED_BY must be a dict "
                              f"literal of 'field': 'lockattr' strings",
                              where=f"{filename}:{stmt.lineno}")
                    return
                guarded = d
            elif stmt.targets[0].id == "_LOCKED_METHODS":
                locked_methods = _str_tuple(stmt.value)
            elif stmt.targets[0].id == "_LOCK_FREE":
                lock_free = _str_tuple(stmt.value)
    for field in lock_free:
        rep.checked += 1
        if field in guarded:
            rep.error(PASS, "conflicting-annotation",
                      f"{cls.name}.{field} is listed in both _GUARDED_BY "
                      f"(lock {guarded[field]!r}) and _LOCK_FREE — pick "
                      f"one", where=f"{filename}:{cls.lineno}")
    if not guarded:
        return
    rep.info.setdefault("guarded_classes", []).append(cls.name)
    for meth in cls.body:
        if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if meth.name == "__init__":
            continue
        walker = _MethodLockWalker(guarded)
        # visit statements directly so the method def itself is not
        # treated as a nested (lock-clearing) function
        for stmt in meth.body:
            walker.visit(stmt)
        assume = meth.name in locked_methods
        for field, lock, line, held in walker.accesses:
            rep.checked += 1
            if assume or lock in held:
                continue
            rep.error(PASS, "unlocked-access",
                      f"{cls.name}.{meth.name} touches self.{field} "
                      f"outside 'with self.{lock}:' "
                      f"(declared guarded by _GUARDED_BY)",
                      where=f"{filename}:{line}")
        for callee, line, held in walker.calls:
            if callee in locked_methods and not assume:
                rep.checked += 1
                # every lock any guarded field of this class needs
                locks_needed = set(guarded.values())
                if not locks_needed & held:
                    rep.error(PASS, "unlocked-call",
                              f"{cls.name}.{meth.name} calls "
                              f"self.{callee}() (listed in "
                              f"_LOCKED_METHODS) without holding the "
                              f"lock", where=f"{filename}:{line}")


def lint_file_locks(path: pathlib.Path, rep: CheckReport) -> None:
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        rep.error(PASS, "syntax", f"cannot parse {path.name}: {e}",
                  where=path.name)
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            lint_class_locks(node, rep, path.name)


# ---------------------------------------------------------------------------
# RejectReason coverage
# ---------------------------------------------------------------------------

def _reject_reasons(sched_path: pathlib.Path) -> Dict[str, str]:
    """name -> string value of every constant on ``RejectReason``."""
    tree = ast.parse(sched_path.read_text(), filename=str(sched_path))
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "RejectReason":
            out = {}
            for stmt in node.body:
                if (isinstance(stmt, ast.Assign)
                        and len(stmt.targets) == 1
                        and isinstance(stmt.targets[0], ast.Name)
                        and isinstance(stmt.value, ast.Constant)
                        and isinstance(stmt.value.value, str)):
                    out[stmt.targets[0].id] = stmt.value.value
            return out
    return {}


def _reason_refs(path: pathlib.Path, skip_class_def: bool) -> Set[str]:
    """Names referenced as ``RejectReason.<NAME>`` in a file."""
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return set()
    refs: Set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "RejectReason"):
            refs.add(node.attr)
    return refs


def check_reject_coverage(serve_dir: pathlib.Path, test_dir: pathlib.Path,
                          rep: CheckReport) -> None:
    sched = serve_dir / "sched.py"
    if not sched.exists():
        rep.error(PASS, "missing-file", f"{sched} not found")
        return
    reasons = _reject_reasons(sched)
    if not reasons:
        rep.error(PASS, "missing-class",
                  "no RejectReason constants found in sched.py")
        return
    rep.info["reject_reasons"] = sorted(reasons)
    code_refs: Set[str] = set()
    for p in sorted(serve_dir.glob("*.py")):
        code_refs |= _reason_refs(p, skip_class_def=True)
    test_refs: Set[str] = set()
    test_text = ""
    for p in sorted(test_dir.glob("test_*.py")):
        test_refs |= _reason_refs(p, skip_class_def=False)
        test_text += p.read_text()
    for name, value in sorted(reasons.items()):
        rep.checked += 2
        if name not in code_refs:
            rep.error(PASS, "unraisable-reason",
                      f"RejectReason.{name} is declared but no serve/ "
                      f"code path references it", where=name)
        if name not in test_refs and value not in test_text:
            rep.error(PASS, "untested-reason",
                      f"RejectReason.{name} has no test referencing it "
                      f"(neither the attribute nor the string "
                      f"'{value}' appears under {test_dir.name}/)",
                      where=name)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def check_concurrency(serve_dir: Optional[pathlib.Path] = None,
                      test_dir: Optional[pathlib.Path] = None,
                      files: Optional[Iterable[pathlib.Path]] = None,
                      name: str = "concurrency") -> CheckReport:
    """Run both concurrency checks over the serving stack (or, for
    tests, over an explicit ``files`` list with reason coverage skipped
    unless a serve_dir is given)."""
    rep = CheckReport(name)
    if files is not None:
        for p in files:
            lint_file_locks(pathlib.Path(p), rep)
        if serve_dir is None:
            return rep
    serve = pathlib.Path(serve_dir) if serve_dir else SERVE_DIR
    tests = pathlib.Path(test_dir) if test_dir else TEST_DIR
    if files is None:
        for fname in SERVE_FILES:
            p = serve / fname
            if p.exists():
                lint_file_locks(p, rep)
            else:
                rep.error(PASS, "missing-file", f"{p} not found")
        for fname in OBS_FILES:
            p = OBS_DIR / fname
            if p.exists():
                lint_file_locks(p, rep)
            else:
                rep.error(PASS, "missing-file", f"{p} not found")
    check_reject_coverage(serve, tests, rep)
    return rep
