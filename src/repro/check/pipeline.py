"""Orchestration: run the check passes over a real synth pipeline.

``check_synth_pipeline`` re-runs the stages of
``synth.compile_logic_network`` one at a time — raw AIG, optimized AIG,
k-LUT mapping, DevicePlan — linting each artifact and proving each
adjacent pair equivalent, so a regression in any single transform is
pinned to its stage rather than surfacing as a wrong argmax three
layers later. ``preflight`` is the cheap subset the serving entry point
runs before accepting traffic; ``verify_synthesis`` / ``verify_plan``
back the ``verify=`` flags on the synth entry points.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.synth.aig import AIG
from repro.synth.executor import (DevicePlan, MappedNetwork,
                                  compile_device_plan)
from repro.synth.from_sop import network_to_aig, table_to_aig
from repro.synth.lutmap import map_aig
from repro.synth.rewrite import optimize

from . import concurrency, srclint
from .equiv import (equiv_aig_mapped, equiv_aigs, equiv_cover_aig,
                    equiv_mapped_plan, equiv_network_mapped)
from .netlist_lint import lint_aig, lint_mapped
from .plan_check import DEFAULT_VMEM_BUDGET, validate_device_plan
from .report import CheckReport, require_ok


def check_sop_stage(net, n_samples: int = 4, seed: int = 0,
                    name: str = "sop-aig") -> CheckReport:
    """SOP <-> AIG on sampled neuron output-bit functions of the first
    layer: minimize the dense table with espresso, rebuild it with
    ``table_to_aig``, and miter cover against AIG on the care set."""
    from repro.core.espresso import minimize
    from repro.core.logic_infer import _bitexpand
    from repro.core.truthtable import onset_of

    rep = CheckReport(name)
    lt = net.layers[0]
    in_bits = lt.in_spec.code_bits
    out_bits = lt.out_spec.code_bits
    rng = np.random.default_rng(seed)
    pairs = [(int(j), int(ob))
             for j in range(lt.n_neurons) for ob in range(out_bits)]
    if len(pairs) > n_samples:
        pairs = [pairs[i] for i in
                 rng.choice(len(pairs), n_samples, replace=False)]
    n_vars = lt.fanin * in_bits
    for j, ob in pairs:
        onset, dc = _bitexpand(onset_of(np.asarray(lt.tables[j]), ob),
                               lt, in_bits)
        cover = minimize(np.asarray(onset, bool),
                         None if dc is None else np.asarray(dc, bool))
        a = AIG(n_vars)
        in_lits = [2 * (p + 1) for p in range(n_vars)]
        a.outputs = [table_to_aig(a, onset, dc, in_lits)]
        sub = equiv_cover_aig(cover, a, dc_mask=dc,
                              name=f"sop-aig[n{j}b{ob}]")
        rep.merge(sub)
    rep.info["sampled_functions"] = len(pairs)
    return rep


def check_synth_pipeline(net=None, aig: Optional[AIG] = None,
                         effort: int = 1, k: int = 6, fast: bool = False,
                         vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
                         seed: int = 0, formal: bool = False,
                         conflict_budget: Optional[int] = None
                         ) -> CheckReport:
    """Lint + stage-by-stage equivalence for one synthesis run.

    Accepts either a compiled ``LogicNetwork`` (full pipeline including
    the SOP stage and the valid-code oracle check) or a bare ``AIG``
    (transform stages only). ``fast`` trades vector count for CI time.
    ``formal=True`` escalates every wide-cone miter to the SAT engine
    (per-stage UNSAT/SAT/UNPROVEN verdicts land in ``info["formal[..]"]``)
    and runs the SAT-sweep duplicate-LUT lint over the mapped net.
    """
    assert (net is None) != (aig is None), "pass exactly one of net/aig"
    n_rand = 16 if fast else 64
    fkw = {"formal": formal, "conflict_budget": conflict_budget}
    rep = CheckReport("synth-pipeline")
    if net is not None:
        rep.merge(check_sop_stage(net, n_samples=2 if fast else 4,
                                  seed=seed))
        aig = network_to_aig(net)
    rep.merge(lint_aig(aig, "aig"))
    opt = optimize(aig, rounds=effort) if effort > 0 else aig
    if effort > 0:
        rep.merge(lint_aig(opt, "aig-optimized"))
        rep.merge(equiv_aigs(aig, opt, n_random_words=n_rand, seed=seed,
                             **fkw))
    mapped = map_aig(opt, k=k)
    rep.merge(lint_mapped(mapped))
    rep.merge(equiv_aig_mapped(opt, mapped, n_random_words=n_rand,
                               seed=seed, **fkw))
    dplan = compile_device_plan(mapped)
    rep.merge(validate_device_plan(dplan,
                                   vmem_budget_bytes=vmem_budget_bytes))
    rep.merge(equiv_mapped_plan(mapped, dplan, n_random_words=n_rand,
                                seed=seed, **fkw))
    if net is not None:
        rep.merge(equiv_network_mapped(net, mapped,
                                       n_samples=256 if fast else 1024,
                                       seed=seed, **fkw))
    if formal:
        from .sat import check_duplicate_lut_outputs
        rep.merge(check_duplicate_lut_outputs(
            mapped, seed=seed,
            **({} if conflict_budget is None
               else {"conflict_budget": conflict_budget})))
    rep.info["n_luts"] = mapped.n_luts
    rep.info["depth"] = mapped.depth
    return rep


def preflight(bitnet, vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
              n_samples: int = 256, seed: int = 0) -> CheckReport:
    """Serving preflight for a compiled ``BitplaneNetwork``: lint the
    mapped netlist, validate + miter its DevicePlan, and spot-check the
    netlist against the truth-table oracle on valid codes. Cheap enough
    to run at every ``launch.serve --check`` startup."""
    rep = CheckReport("preflight")
    rep.merge(lint_mapped(bitnet.mapped))
    dplan = compile_device_plan(bitnet.mapped)
    rep.merge(validate_device_plan(dplan,
                                   vmem_budget_bytes=vmem_budget_bytes))
    rep.merge(equiv_mapped_plan(bitnet.mapped, dplan, n_random_words=16,
                                seed=seed))
    if getattr(bitnet, "net", None) is not None:
        rep.merge(equiv_network_mapped(bitnet.net, bitnet.mapped,
                                       n_samples=n_samples, seed=seed))
    return rep


def check_static(fast: bool = False) -> CheckReport:
    """The pure-source passes (no model needed): concurrency lint over
    the serving stack and the duplicate-definition watchlist."""
    rep = CheckReport("static")
    rep.merge(concurrency.check_concurrency())
    rep.merge(srclint.check_duplicate_definitions())
    return rep


# ---------------------------------------------------------------------------
# verify= hooks (raise CheckFailure on any error)
# ---------------------------------------------------------------------------

def verify_synthesis(raw: AIG, opt: AIG, mapped: MappedNetwork,
                     formal: bool = False) -> None:
    """Backs ``synthesize(..., verify=True)``: the optimized AIG must
    match the raw one everywhere, and the mapping must match the
    optimized AIG everywhere. ``formal=True`` (``verify="formal"``)
    escalates wide cones to SAT proofs."""
    rep = CheckReport("verify-synthesis")
    rep.merge(lint_aig(opt, "aig-optimized"))
    if opt is not raw:
        rep.merge(equiv_aigs(raw, opt, n_random_words=16, formal=formal))
    rep.merge(lint_mapped(mapped))
    rep.merge(equiv_aig_mapped(opt, mapped, n_random_words=16,
                               formal=formal))
    require_ok(rep)


def verify_plan(mapped: MappedNetwork, dplan: DevicePlan,
                formal: bool = False) -> None:
    """Backs ``compile_device_plan(..., verify=True)``."""
    rep = CheckReport("verify-plan")
    rep.merge(validate_device_plan(dplan))
    rep.merge(equiv_mapped_plan(mapped, dplan, n_random_words=16,
                                formal=formal))
    require_ok(rep)
