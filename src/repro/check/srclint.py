"""Source-level duplicate-definition lint.

``core/lutmap.py`` and ``synth/lutmap.py`` historically each carried
their own copy of the LUT cost model (k, per-level delay, the
tree-decomposition LUT count) — and the two drifted. The cost model now
lives once in ``core/lutcost.py``; this lint keeps it that way by
scanning every module under ``src/repro`` and flagging any *watchlist*
symbol that is **defined** (def/class/assignment — imports don't count)
in more than one module.

The watchlist is deliberately small: these are the symbols whose
duplication has already bitten once. Growing it is the cheap way to
pin future de-duplications.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, Iterable, List, Optional, Tuple

from .report import CheckReport

PASS = "srclint"

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
SRC_DIR = _REPO_ROOT / "src" / "repro"

# symbols that must have exactly one defining module
WATCHLIST = (
    "MapReport",
    "logicnets_lut_cost",
    "tree_lut_cost",
    "LUT_K",
    "T_LEVEL_NS",
    "T_FF_NS",
)


def _definitions(tree: ast.Module) -> List[Tuple[str, int]]:
    """(name, lineno) for every top-level def/class/constant assignment."""
    out = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.append((node.name, node.lineno))
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.append((t.id, node.lineno))
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if isinstance(node.target, ast.Name):
                out.append((node.target.id, node.lineno))
    return out


def check_duplicate_definitions(src_dir: Optional[pathlib.Path] = None,
                                watchlist: Iterable[str] = WATCHLIST,
                                name: str = "srclint") -> CheckReport:
    rep = CheckReport(name)
    root = pathlib.Path(src_dir) if src_dir else SRC_DIR
    watch = set(watchlist)
    sites: Dict[str, List[str]] = {w: [] for w in watch}
    for path in sorted(root.rglob("*.py")):
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError as e:
            rep.error(PASS, "syntax", f"cannot parse {path.name}: {e}",
                      where=path.name)
            continue
        rel = path.relative_to(root.parent).as_posix()
        for dname, line in _definitions(tree):
            if dname in watch:
                sites[dname].append(f"{rel}:{line}")
    for sym in sorted(watch):
        rep.checked += 1
        if len(sites[sym]) > 1:
            rep.error(PASS, "duplicate-definition",
                      f"'{sym}' is defined in {len(sites[sym])} modules "
                      f"({', '.join(sites[sym])}) — keep one definition "
                      f"and import it", where=sym)
    rep.info["watchlist"] = sorted(watch)
    return rep
