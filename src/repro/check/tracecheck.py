"""Pass 6 — trace-schema validation over ``repro.obs`` traces.

A trace is only useful evidence if its invariants hold, so this pass
gates the properties downstream analysis leans on:

  * **phase vocabulary** — every event is one of ``X`` (thread span),
    ``b``/``n``/``e`` (async begin/instant/end) or ``i`` (instant);
  * **span times** — ``X`` spans have ``dur_us >= 0`` and finite
    timestamps, and same-thread spans properly nest or are disjoint
    (lexical ``with tracer.span()`` nesting guarantees time
    containment — a partial overlap means a clock or threading bug);
  * **async pairing** — per ``(cat, scope_id)``, begin/end events pair
    LIFO in recording order (``b request``, ``b queue_wait``,
    ``e queue_wait``, ``e request``) with scope-local timestamps
    non-decreasing. Ends without a begin and begins without an end are
    orphans. Scope ids that never open a span are *legal*: admission
    rejects allocate a trace id but record only an ``i reject``
    instant, never an async begin;
  * **flush reasons** — any ``flush_reason`` arg must come from
    ``repro.obs.trace.FLUSH_REASONS``;
  * **terminal outcomes** — every ``e request`` must state how the
    request ended (``ok``/``shed``/``error``/``shutdown``).

Pairing violations downgrade to warnings when the source ring buffer
dropped events (``n_dropped > 0``): a truncated trace legitimately
loses begins — raise the tracer capacity rather than fail the check.

Ordering caveat baked into the rules: ``X`` spans are recorded at
context *exit*, so an ``e request`` async end lands in the buffer
before the ``X scatter`` span that contains it. Async pairing is
therefore checked in buffer order, thread-span nesting by time — never
across the two families.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from .report import CheckReport

PASS = "trace"

VALID_PH = ("X", "b", "n", "e", "i")
TERMINAL_OUTCOMES = ("ok", "shed", "error", "shutdown")


def _flush_reasons() -> Tuple[str, ...]:
    from repro.obs.trace import FLUSH_REASONS
    return FLUSH_REASONS


def check_trace(events: Iterable, n_dropped: int = 0,
                report: Optional[CheckReport] = None) -> CheckReport:
    """Validate a sequence of ``TraceEvent`` records (from
    ``SpanTracer.events()`` or ``repro.obs.load_trace_events``)."""
    rep = report if report is not None else CheckReport("trace")
    evs = list(events)
    reasons = _flush_reasons()
    truncated = n_dropped > 0

    def pairing_issue(code: str, msg: str, where: str) -> None:
        if truncated:
            rep.warn(PASS, code, msg + " (ring buffer dropped "
                     f"{n_dropped} events; raise tracer capacity)", where)
        else:
            rep.error(PASS, code, msg, where)

    # per-thread X spans for the nesting sweep; per-scope async stacks
    by_tid: Dict[int, List] = {}
    open_spans: Dict[Tuple[str, Optional[int]], List[str]] = {}
    last_ts: Dict[Tuple[str, Optional[int]], float] = {}

    for idx, ev in enumerate(evs):
        where = f"event {idx} ({ev.ph} {ev.name!r})"
        if ev.ph not in VALID_PH:
            rep.error(PASS, "bad-phase",
                      f"unknown phase {ev.ph!r} (valid: {VALID_PH})", where)
            continue
        if not (ev.ts_us == ev.ts_us and abs(ev.ts_us) != float("inf")):
            rep.error(PASS, "bad-timestamp",
                      f"non-finite timestamp {ev.ts_us!r}", where)
            continue
        if ev.args and "flush_reason" in ev.args \
                and ev.args["flush_reason"] not in reasons:
            rep.error(PASS, "bad-flush-reason",
                      f"flush_reason {ev.args['flush_reason']!r} not in "
                      f"{reasons}", where)
        rep.checked += 1

        if ev.ph == "X":
            if ev.dur_us < 0:
                rep.error(PASS, "negative-dur",
                          f"negative duration {ev.dur_us} us", where)
            else:
                by_tid.setdefault(ev.tid, []).append(ev)
            continue
        if ev.ph == "i":
            continue

        # async events: LIFO pairing per (cat, scope_id) in buffer order
        key = (ev.cat, ev.scope_id)
        if ev.scope_id is None:
            rep.error(PASS, "missing-scope",
                      "async event without a scope id", where)
            continue
        if key in last_ts and ev.ts_us < last_ts[key]:
            rep.error(PASS, "time-regression",
                      f"scope {ev.scope_id} time went backwards "
                      f"({last_ts[key]} -> {ev.ts_us} us)", where)
        last_ts[key] = ev.ts_us
        stack = open_spans.setdefault(key, [])
        if ev.ph == "b":
            stack.append(ev.name)
        elif ev.ph == "n":
            if not stack:
                rep.warn(PASS, "instant-outside-span",
                         f"async instant on scope {ev.scope_id} with no "
                         "open span", where)
        else:                            # "e"
            if not stack:
                pairing_issue("orphan-end",
                              f"end without begin on scope {ev.scope_id}",
                              where)
            elif stack[-1] != ev.name:
                rep.error(PASS, "end-mismatch",
                          f"end {ev.name!r} but innermost open span on "
                          f"scope {ev.scope_id} is {stack[-1]!r}", where)
                if ev.name in stack:     # resync so one slip != cascade
                    del stack[stack.index(ev.name):]
            else:
                stack.pop()
            if ev.name == "request":
                outcome = (ev.args or {}).get("outcome")
                if outcome not in TERMINAL_OUTCOMES:
                    rep.error(PASS, "bad-outcome",
                              f"request end outcome {outcome!r} not in "
                              f"{TERMINAL_OUTCOMES}", where)

    for (cat, sid), stack in open_spans.items():
        if stack:
            pairing_issue("unterminated-span",
                          f"scope {sid} ({cat}) left open: {stack}",
                          f"scope {sid}")

    # thread-span nesting: same-tid spans must nest or be disjoint
    for tid, spans in by_tid.items():
        spans.sort(key=lambda e: (e.ts_us, -e.dur_us))
        stack: List = []
        for ev in spans:
            end = ev.ts_us + ev.dur_us
            while stack and ev.ts_us >= stack[-1].ts_us + stack[-1].dur_us:
                stack.pop()
            if stack and end > stack[-1].ts_us + stack[-1].dur_us:
                outer = stack[-1]
                rep.error(PASS, "span-overlap",
                          f"{ev.name!r} [{ev.ts_us}, {end}] partially "
                          f"overlaps {outer.name!r} "
                          f"[{outer.ts_us}, "
                          f"{outer.ts_us + outer.dur_us}] on tid {tid}",
                          f"tid {tid}")
            stack.append(ev)
            rep.checked += 1

    rep.info["events"] = len(evs)
    rep.info["n_dropped"] = int(n_dropped)
    return rep


def check_phase_reconciliation(events: Iterable, n_dropped: int = 0,
                               tol: float = None,
                               report: Optional[CheckReport] = None
                               ) -> CheckReport:
    """Validate the phase-reconciliation invariant over a trace: for
    every completed (``outcome == "ok"``) request, the attributed phase
    times must account for its end-to-end latency —

        ``queue_wait + batch_form + exec ~= latency_us``

    within the analyzer tolerance (``repro.obs.analyze.DEFAULT_TOL``).
    A request whose phases do not sum to its latency means a span is
    missing, double-counted, or stamped with the wrong clock — the
    trace can no longer answer "where did the time go". Downgraded to a
    warning when the ring buffer dropped events (a truncated trace
    legitimately loses the spans the sum needs), or while the trace as
    a whole stays within the analyzer's straggler allowance (an OS
    preemption between two clock stamps inflates one request's gap;
    a real mis-attribution shows up across every request)."""
    from repro.obs.analyze import DEFAULT_TOL, analyze_events
    rep = report if report is not None else CheckReport("trace")
    tol = DEFAULT_TOL if tol is None else tol
    truncated = n_dropped > 0

    rpt = analyze_events(events, tol=tol)
    recon = rpt.reconciliation()
    rep.checked += recon["n_checked"]
    rep.info["phase_recon"] = recon
    if recon["n_checked"] == 0:
        if not truncated and rpt.requests:
            rep.warn(PASS, "phase-recon-empty",
                     f"{len(rpt.requests)} request(s) in trace but none "
                     "completed ok — reconciliation not checkable",
                     "phase reconciliation")
        return rep
    for r in rpt.requests:
        if r.outcome != "ok":
            continue
        err = r.recon_error()
        if err is None or err <= tol:
            continue
        attributed = r.wait_us + r.batch.form_us + r.batch.exec_us
        msg = (f"request {r.sid}: phases sum to {attributed:.1f} us "
               f"but latency is {r.latency_us:.1f} us "
               f"({err:.1%} > {tol:.0%} tolerance)")
        if truncated:
            rep.warn(PASS, "phase-reconcile", msg + " (ring buffer "
                     f"dropped {n_dropped} events)", f"request {r.sid}")
        elif recon["ok"]:
            rep.warn(PASS, "phase-reconcile", msg + " (within the "
                     f"{recon['n_allowed']}-straggler allowance)",
                     f"request {r.sid}")
        else:
            rep.error(PASS, "phase-reconcile", msg, f"request {r.sid}")
    return rep


def check_trace_file(path: str,
                     report: Optional[CheckReport] = None) -> CheckReport:
    """Validate an exported trace file (Chrome JSON or JSONL)."""
    from repro.obs.export import load_trace_events
    rep = report if report is not None else CheckReport("trace")
    try:
        events = load_trace_events(path)
    except (OSError, ValueError, KeyError) as e:
        rep.error(PASS, "unreadable",
                  f"cannot parse trace file: {e}", path)
        return rep
    if not events:
        rep.warn(PASS, "empty-trace", "trace file contains no events",
                 path)
    rep.info["file"] = path
    check_trace(events, report=rep)
    return check_phase_reconciliation(events, report=rep)


def synthetic_trace_events() -> Tuple[List, int]:
    """Drive a FakeClock scheduler through every lifecycle edge — size
    flush, max-wait flush, expiry shed, admission reject, drain — and
    return ``(events, n_dropped)``. The ``--passes trace`` fallback
    when no ``--trace-file`` is given: validates the *live*
    instrumentation, not a canned fixture."""
    import numpy as np

    from repro.obs.trace import SpanTracer
    from repro.serve import (MicroBatchScheduler, RequestRejected,
                             SchedConfig, FakeClock)

    clk = FakeClock()
    tracer = SpanTracer(clock=clk, capacity=4096)
    s = MicroBatchScheduler(
        lambda x: x.sum(axis=-1),
        SchedConfig(max_batch=4, max_wait_us=200.0, max_queue=8,
                    n_priorities=1, lane_slo_us=(1000.0,)),
        clock=clk, tracer=tracer)
    futs = [s.submit(np.full((1, 3), i, np.float32)) for i in range(4)]
    s.poll()                             # size flush
    futs.append(s.submit(np.ones((2, 3), np.float32)))
    clk.advance_us(250.0)
    s.poll()                             # max-wait flush
    futs.append(s.submit(np.ones((1, 3), np.float32)))
    clk.advance_us(1500.0)               # past the lane SLO
    try:
        s.submit(np.ones((9, 3), np.float32))   # rows > max_batch
    except RequestRejected:
        pass
    s.drain()                            # expiry shed for the stale one
    for f in futs:
        try:
            f.result(0)
        except RequestRejected:
            pass
    return tracer.events(), tracer.n_dropped
