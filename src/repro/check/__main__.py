"""``python -m repro.check`` — run the static-analysis passes.

By default trains a tiny JSC-S model, compiles it to logic, and runs
every pass (netlist lint, stage equivalence, device-plan validation)
over the real pipeline, plus the source-level passes (concurrency
lint, duplicate-definition watchlist) and the trace-schema pass
(``--trace-file`` validates an exported repro.obs trace; without it a
synthetic FakeClock scheduler run is traced and validated). ``--fast`` shrinks the training
run and vector counts so the whole thing fits a CI minute; ``--static``
skips the model entirely.

Exit status: 0 = all passes clean, 1 = errors found.
"""
from __future__ import annotations

import argparse
import sys
import time

from .pipeline import check_synth_pipeline
from .plan_check import DEFAULT_VMEM_BUDGET
from .report import CheckReport

PASS_CHOICES = ("lint", "equiv", "plan", "concurrency", "srclint",
                "trace", "formal")


def _build_jsc(fast: bool, seed: int):
    from repro.configs.jsc import JSC_S
    from repro.data.jsc import train_test
    from repro.models.mlp import to_logic
    from repro.train.jsc_trainer import train_jsc

    n_train, n_test = (2000, 500) if fast else (3000, 800)
    steps = 100 if fast else 200
    data = train_test(n_train, n_test, seed=seed)
    res = train_jsc(JSC_S, steps=steps, batch=128, data=data)
    return to_logic(JSC_S, res.params, res.masks, res.bn_state)


def _print_formal(rep: CheckReport) -> None:
    """Per-stage UNSAT-proof / conflict statistics + a verdict tally.

    The tally line is machine-greppable — CI gates on ``SAT=0`` (no
    proven inequivalence) and ``UNPROVEN=0`` (every wide cone actually
    proved within the conflict budget).
    """
    tally = {"UNSAT": 0, "SAT": 0, "UNPROVEN": 0}
    for key in sorted(rep.info):
        if not key.startswith("formal["):
            continue
        st = rep.info[key]
        stage = key[len("formal["):-1]
        tally[st["verdict"]] = tally.get(st["verdict"], 0) + 1
        print(f"[check] formal {stage}: {st['verdict']} "
              f"({st.get('outputs', '?')} outputs, "
              f"{st.get('outputs_merged', '?')} merged by sweep, "
              f"{st.get('queries', 0)} SAT queries, "
              f"{st.get('conflicts', 0)} conflicts, "
              f"{st.get('nodes', 0)} miter nodes)")
    sw = rep.info.get("sat_sweep")
    if sw:
        print(f"[check] formal sat-sweep: {sw['dup_lut_outputs']} duplicate "
              f"LUT output(s); {sw['luts']} -> {sw['luts_after_sweep']} "
              f"LUTs after merge ({sw['sat_queries']} queries, "
              f"{sw['conflicts']} conflicts)")
    print(f"[check] formal verdicts: UNSAT={tally['UNSAT']} "
          f"SAT={tally['SAT']} UNPROVEN={tally['UNPROVEN']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Static netlist verification, device-plan validation "
                    "and concurrency lint for the synth->serve stack.")
    ap.add_argument("--fast", action="store_true",
                    help="small training run + fewer miter vectors "
                    "(CI budget, < ~60 s)")
    ap.add_argument("--static", action="store_true",
                    help="source-level passes only (no model training)")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of: "
                    + ",".join(PASS_CHOICES))
    ap.add_argument("--effort", type=int, default=1,
                    help="rewrite/balance rounds before mapping")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vmem-budget-mb", type=float, default=None,
                    help="device-plan VMEM budget (default "
                    f"{DEFAULT_VMEM_BUDGET / 2**20:.0f} MiB)")
    ap.add_argument("--conflict-budget", type=int, default=None,
                    help="SAT conflict budget for the formal pass "
                    "(default: repro.check.sat.DEFAULT_CONFLICT_BUDGET); "
                    "exceeding it yields UNPROVEN warnings, not a pass")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="exported trace (Chrome JSON or JSONL) for the "
                    "trace pass; without it a synthetic FakeClock "
                    "scheduler run is validated instead")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="show warnings, not just errors")
    args = ap.parse_args(argv)

    wanted = (set(p.strip() for p in args.passes.split(","))
              if args.passes else set(PASS_CHOICES))
    bad = wanted - set(PASS_CHOICES)
    if bad:
        ap.error(f"unknown pass(es): {', '.join(sorted(bad))}")

    budget = (DEFAULT_VMEM_BUDGET if args.vmem_budget_mb is None
              else int(args.vmem_budget_mb * 2**20))
    t0 = time.time()
    reports = []

    if "trace" in wanted:
        from .tracecheck import (check_phase_reconciliation, check_trace,
                                 check_trace_file,
                                 synthetic_trace_events)
        if args.trace_file:
            reports.append(check_trace_file(args.trace_file))
        else:
            print("[check] no --trace-file: validating a synthetic "
                  "FakeClock scheduler trace ...", flush=True)
            events, n_dropped = synthetic_trace_events()
            rep = check_trace(events, n_dropped=n_dropped)
            reports.append(check_phase_reconciliation(
                events, n_dropped=n_dropped, report=rep))

    if wanted & {"concurrency", "srclint"}:
        static = CheckReport("static")
        if "concurrency" in wanted:
            from .concurrency import check_concurrency
            static.merge(check_concurrency())
        if "srclint" in wanted:
            from .srclint import check_duplicate_definitions
            static.merge(check_duplicate_definitions())
        reports.append(static)

    if not args.static and wanted & {"lint", "equiv", "plan", "formal"}:
        print("[check] building JSC-S artifacts "
              f"({'fast' if args.fast else 'full'}) ...", flush=True)
        net = _build_jsc(args.fast, args.seed)
        rep = check_synth_pipeline(net=net, effort=args.effort,
                                   fast=args.fast,
                                   vmem_budget_bytes=budget,
                                   seed=args.seed,
                                   formal="formal" in wanted,
                                   conflict_budget=args.conflict_budget)
        if "formal" in wanted:
            _print_formal(rep)
        if wanted != set(PASS_CHOICES):
            rep.issues = [i for i in rep.issues if i.pass_name in wanted]
        reports.append(rep)

    ok = True
    for rep in reports:
        print(rep.format(verbose=args.verbose))
        ok = ok and rep.ok
    print(f"[check] {'PASS' if ok else 'FAIL'} in {time.time() - t0:.1f} s")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
