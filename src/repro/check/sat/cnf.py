"""CNF construction for the formal equivalence engine.

Builds clause sets in the same literal encoding as :mod:`.solver`
(variable ``v`` -> literals ``2v`` / ``2v+1``).  Three gate encodings:

  * ``and_clauses`` — Tseitin encoding of a 2-input AND
    (``out <-> a & b``, 3 clauses);
  * ``lut_clauses(mode="rows")`` — one clause per INIT row: for minterm
    ``r`` the clause "inputs differ from r, or out takes tt[r]"
    (``2^m`` clauses, exact);
  * ``lut_clauses(mode="isop")`` — irredundant sum-of-products via the
    Minato-Morreale ISOP recursion over the truth table and its
    complement: onset cubes imply ``out``, offset cubes imply ``¬out``
    (usually far fewer clauses than per-row for structured INITs).

``care_code_clauses`` encodes the quantizer care set: for every
*invalid* code of a PI bit-group, one clause blocking that assignment —
the miter is then proved only over reachable activations, matching
espresso's don't-care treatment exactly.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Dict, List, Sequence, Tuple

Cube = Tuple[int, int]   # (pos_mask, neg_mask) over local var indices


class CNF:
    """A growable clause set; feeds :class:`~.solver.Solver`."""

    def __init__(self):
        self.n_vars = 0
        self.clauses: List[List[int]] = []

    def new_var(self) -> int:
        v = self.n_vars
        self.n_vars += 1
        return v

    def add(self, *lits: int) -> None:
        self.clauses.append(list(lits))

    def solver(self):
        from .solver import Solver
        s = Solver(self.n_vars)
        for c in self.clauses:
            if not s.add_clause(c):
                break
        return s


def and_clauses(cnf: CNF, out: int, a: int, b: int) -> None:
    """Tseitin ``out <-> a AND b`` (literals, complement via ``^1``)."""
    cnf.add(out ^ 1, a)
    cnf.add(out ^ 1, b)
    cnf.add(out, a ^ 1, b ^ 1)


def xor_clauses(cnf: CNF, out: int, a: int, b: int) -> None:
    """Tseitin ``out <-> a XOR b`` (4 clauses)."""
    cnf.add(out ^ 1, a, b)
    cnf.add(out ^ 1, a ^ 1, b ^ 1)
    cnf.add(out, a, b ^ 1)
    cnf.add(out, a ^ 1, b)


def equal_clauses(cnf: CNF, a: int, b: int) -> None:
    """Force ``a == b``."""
    cnf.add(a ^ 1, b)
    cnf.add(a, b ^ 1)


# --------------------------------------------------------------- ISOP
def isop(tt: int, m: int) -> List[Cube]:
    """Irredundant sum-of-products of an ``m``-input truth table.

    Minato-Morreale recursion computing a cover between lower bound
    ``L`` (must cover) and upper bound ``U`` (may cover); called with
    ``L == U == tt`` it returns an exact irredundant cover.  Cubes are
    ``(pos_mask, neg_mask)`` bitmasks over input indices.
    """
    full = (1 << (1 << m)) - 1
    cubes, cover = _isop(tt & full, tt & full, m)
    assert cover == tt & full
    return cubes


def _isop(L: int, U: int, m: int) -> Tuple[List[Cube], int]:
    if L == 0:
        return [], 0
    full = (1 << (1 << m)) - 1
    if U == full:
        return [(0, 0)], full
    assert m > 0
    half = 1 << (m - 1)
    lo_mask = (1 << half) - 1
    L0, L1 = L & lo_mask, L >> half
    U0, U1 = U & lo_mask, U >> half
    var = m - 1
    # cubes that must carry ¬x (cover onset rows not allowed under x)
    c0, cov0 = _isop(L0 & ~U1 & lo_mask, U0, m - 1)
    # cubes that must carry x
    c1, cov1 = _isop(L1 & ~U0 & lo_mask, U1, m - 1)
    # remainder is covered independently of x
    Lrest = (L0 & ~cov0 & lo_mask) | (L1 & ~cov1 & lo_mask)
    cd, covd = _isop(Lrest, U0 & U1, m - 1)
    cubes = ([(p, n | (1 << var)) for p, n in c0]
             + [(p | (1 << var), n) for p, n in c1]
             + cd)
    cover = ((cov0 | covd) & lo_mask) | (((cov1 | covd) & lo_mask) << half)
    return cubes, cover


def eval_cubes(cubes: Sequence[Cube], m: int) -> int:
    """Truth table of a cube cover (for testing ISOP round-trips)."""
    tt = 0
    for r in range(1 << m):
        for p, n in cubes:
            if (r & p) == p and (r & n) == 0:
                tt |= 1 << r
                break
    return tt


@lru_cache(maxsize=4096)
def _isop_cached(tt: int, m: int) -> Tuple[Tuple[Cube, ...], Tuple[Cube, ...]]:
    full = (1 << (1 << m)) - 1
    return tuple(isop(tt, m)), tuple(isop(~tt & full, m))


# ---------------------------------------------------------------- LUTs
def lut_clauses(cnf: CNF, out: int, in_lits: Sequence[int], tt: int,
                mode: str = "isop") -> None:
    """Constrain ``out`` to the ``tt``-function of ``in_lits``.

    ``mode="rows"``: one clause per INIT row.  ``mode="isop"``: onset
    cubes imply ``out``, offset cubes imply ``¬out`` (cached per tt).
    """
    m = len(in_lits)
    full = (1 << (1 << m)) - 1
    tt &= full
    if m == 0:
        cnf.add(out ^ (0 if tt & 1 else 1))
        return
    if mode == "rows":
        for r in range(1 << m):
            head = out if (tt >> r) & 1 else out ^ 1
            clause = [head]
            for j, l in enumerate(in_lits):
                # block row r: literal true iff input j differs from r_j
                clause.append(l ^ 1 if (r >> j) & 1 else l)
            cnf.add(*clause)
        return
    if mode != "isop":
        raise ValueError(f"unknown LUT encoding mode: {mode!r}")
    on, off = _isop_cached(tt, m)
    for cubes, head in ((on, out), (off, out ^ 1)):
        for p, n in cubes:
            clause = [head]
            for j, l in enumerate(in_lits):
                if (p >> j) & 1:
                    clause.append(l ^ 1)
                elif (n >> j) & 1:
                    clause.append(l)
            cnf.add(*clause)


# ------------------------------------------------------------ care set
def care_code_clauses(cnf: CNF, group_lits: Sequence[int],
                      n_valid: int) -> None:
    """Restrict a little-endian bit-group to codes ``< n_valid``.

    One blocking clause per invalid code — e.g. a "signed" 2-bit
    activation with 3 levels gets the single clause ``(¬b0 ∨ ¬b1)``
    ruling code 3 out of the miter's search space.
    """
    bits = len(group_lits)
    for code in range(n_valid, 1 << bits):
        clause = []
        for b, l in enumerate(group_lits):
            clause.append(l ^ 1 if (code >> b) & 1 else l)
        cnf.add(*clause)


def miter_clauses(cnf: CNF, pairs: Sequence[Tuple[int, int]]) -> None:
    """Assert "some pair differs": XOR each pair, OR the XORs.

    A satisfying assignment is a counterexample; UNSAT proves the pairs
    pointwise equal (over whatever care clauses are present).
    """
    if len(pairs) == 1:
        a, b = pairs[0]
        # inequality directly, no fresh var needed
        cnf.add(a, b)
        cnf.add(a ^ 1, b ^ 1)
        return
    diffs = []
    for a, b in pairs:
        d = 2 * cnf.new_var()
        xor_clauses(cnf, d, a, b)
        diffs.append(d)
    cnf.add(*diffs)
