"""SAT-based formal equivalence: prove miters instead of sampling them.

The sampled miter in ``check.equiv`` is exhaustive (a proof) up to 20
PIs and a filter beyond.  This engine closes the gap: both sides of a
stage adjacency are imported into one *unified netlist* sharing primary
inputs, and equivalence is proved by SAT sweeping:

  1. **Import.**  AIGs become AND gates, mapped netlists / DevicePlans
     become LUT gates.  Every gate is normalized (complemented fanins
     folded into the truth table, constant / duplicate / vacuous inputs
     removed, inputs sorted, output phase canonicalized) and
     structurally hashed, so identical structure across the two sides
     merges for free.
  2. **Simulate.**  2048 random patterns (care-set-respecting when a
     quantizer care set is given) give every node a signature; nodes
     sharing a signature up to complement are equivalence candidates.
  3. **Sweep.**  Candidates are proved bottom-up with small windowed
     CNF queries (cone capped, frontier nodes become free variables —
     sound, because a merge happens only on UNSAT, i.e. equivalence
     over *all* frontier valuations).  Proven merges rewrite fanins via
     a union-find over literals, shrinking every later query.
  4. **Final miter.**  Output pairs whose literals merged are proved;
     any remainder gets a full-cone miter CNF (with the care set as
     blocking clauses).  ``SAT`` yields a concrete PI counterexample —
     always replayed through the bitplane simulator before reporting —
     ``UNSAT`` a proof, and an exhausted conflict budget ``UNPROVEN``,
     which callers must surface (and back with sampling), never hide.
"""
from __future__ import annotations

import dataclasses
import heapq
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.synth.aig import AIG, lit_var
from repro.synth.simulate import WORD_BITS, pack_bits

from .cnf import (CNF, and_clauses, care_code_clauses, lut_clauses,
                  miter_clauses)
from .solver import Solver

UNSAT = "UNSAT"          # proved equivalent (on the care set)
SAT = "SAT"              # proved *in*equivalent; counterexample attached
UNPROVEN = "UNPROVEN"    # conflict budget exhausted; fall back to sampling

DEFAULT_CONFLICT_BUDGET = 200_000
_QUERY_CONFLICTS = 2_000         # per internal sweep query
_WINDOW_CAP = 1_000              # gates expanded per sweep query
_SIM_WORDS = 64                  # 2048 signature patterns
_AND_TT = 0b1000                 # tt of a 2-input AND over (a, b)

_FULL_WORD = np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# truth-table surgery (python ints, row r bit j = input j of minterm r)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _mask0(m: int, j: int) -> int:
    """Rows of an m-var table whose bit j is 0."""
    mask = 0
    for r in range(1 << m):
        if not (r >> j) & 1:
            mask |= 1 << r
    return mask


def _flip_var(tt: int, m: int, j: int) -> int:
    """tt with input j complemented: bit r <- bit (r ^ 2^j)."""
    m0 = _mask0(m, j)
    step = 1 << j
    full = (1 << (1 << m)) - 1
    return (((tt & m0) << step) | ((tt & ~m0 & full) >> step)) & full


def _cofactor(tt: int, m: int, j: int, val: int) -> int:
    """tt with input j fixed to val (result has m-1 inputs)."""
    out = 0
    idx = 0
    for r in range(1 << m):
        if ((r >> j) & 1) == val:
            if (tt >> r) & 1:
                out |= 1 << idx
            idx += 1
    return out


def _tie_vars(tt: int, m: int, i: int, j: int) -> int:
    """tt with input i (> j) forced equal to input j, then removed."""
    out = 0
    for rp in range(1 << (m - 1)):
        low = rp & ((1 << i) - 1)
        high = rp >> i
        bj = (rp >> j) & 1
        r = low | (bj << i) | (high << (i + 1))
        if (tt >> r) & 1:
            out |= 1 << rp
    return out


def _permute_vars(tt: int, m: int, perm: Sequence[int]) -> int:
    """Reindex inputs: new input j reads old input perm[j]."""
    out = 0
    for r in range(1 << m):
        ro = 0
        for jn in range(m):
            if (r >> jn) & 1:
                ro |= 1 << perm[jn]
        if (tt >> ro) & 1:
            out |= 1 << r
    return out


def _normalize(fanins: Sequence[int], tt: int):
    """Canonicalize a LUT gate.

    Returns ``("lit", l)`` when the gate degenerates to a constant or a
    single (possibly complemented) fanin, else ``("gate", fanins, tt,
    compl)`` with positive sorted fanins, no constant/duplicate/vacuous
    inputs, and tt's minterm 0 false (output phase in ``compl``).
    """
    fanins = list(fanins)
    m = len(fanins)
    full = (1 << (1 << m)) - 1
    tt &= full
    # fold fanin complements into the table
    for j, f in enumerate(fanins):
        if f & 1:
            tt = _flip_var(tt, m, j)
            fanins[j] = f ^ 1
    # drop constant inputs (only const-FALSE survives complement fold)
    j = 0
    while j < len(fanins):
        if fanins[j] == 0:
            tt = _cofactor(tt, len(fanins), j, 0)
            fanins.pop(j)
        else:
            j += 1
    # merge duplicate inputs
    i = 1
    while i < len(fanins):
        j = fanins.index(fanins[i])
        if j < i:
            tt = _tie_vars(tt, len(fanins), i, j)
            fanins.pop(i)
        else:
            i += 1
    # drop vacuous inputs
    j = 0
    while j < len(fanins):
        c0 = _cofactor(tt, len(fanins), j, 0)
        if c0 == _cofactor(tt, len(fanins), j, 1):
            tt = c0
            fanins.pop(j)
        else:
            j += 1
    m = len(fanins)
    if m == 0:
        return ("lit", 1 if tt & 1 else 0)
    if m == 1:
        return ("lit", fanins[0] ^ (0 if tt == 0b10 else 1))
    order = sorted(range(m), key=lambda p: fanins[p])
    if order != list(range(m)):
        tt = _permute_vars(tt, m, order)
        fanins = [fanins[p] for p in order]
    compl = tt & 1
    if compl:
        tt = ~tt & ((1 << (1 << m)) - 1)
    return ("gate", tuple(fanins), tt, compl)


def _tt_words(tt: int, m: int) -> np.ndarray:
    nbytes = max(1, ((1 << m) + 7) >> 3)
    raw = np.frombuffer(tt.to_bytes(nbytes, "little"), np.uint8)
    return np.unpackbits(raw, bitorder="little")[: 1 << m].astype(np.uint32)


# ---------------------------------------------------------------------------
# unified netlist
# ---------------------------------------------------------------------------

class UNet:
    """Both miter sides in one gate list over shared PIs.

    Node ids: 0 = const-FALSE, 1..n_pis = PIs, then gates.  Literals
    follow the AIG convention ``2*node | compl``.  Gates are stored
    normalized (see :func:`_normalize`) and structurally hashed.
    """

    def __init__(self, n_pis: int):
        self.n_pis = n_pis
        self.gates: List[Tuple[Tuple[int, ...], int]] = []
        self._strash: Dict[Tuple[Tuple[int, ...], int], int] = {}

    @property
    def n_nodes(self) -> int:
        return self.n_pis + 1 + len(self.gates)

    def is_gate(self, node: int) -> bool:
        return node > self.n_pis

    def gate(self, node: int) -> Tuple[Tuple[int, ...], int]:
        return self.gates[node - self.n_pis - 1]

    def add(self, fanins: Sequence[int], tt: int) -> int:
        norm = _normalize(fanins, tt)
        if norm[0] == "lit":
            return norm[1]
        _, fans, tt, compl = norm
        key = (fans, tt)
        node = self._strash.get(key)
        if node is None:
            node = self.n_nodes
            self.gates.append(key)
            self._strash[key] = node
        return 2 * node | compl

    def and2(self, a: int, b: int) -> int:
        return self.add((a, b), _AND_TT)

    def simulate(self, pi_words: np.ndarray) -> np.ndarray:
        """(n_pis, W) packed words -> (n_nodes, W) node values."""
        w = pi_words.shape[1]
        vals = np.zeros((self.n_nodes, w), np.uint32)
        vals[1: self.n_pis + 1] = pi_words
        for i, (fanins, tt) in enumerate(self.gates):
            ins = [vals[f >> 1] ^ (_FULL_WORD if f & 1 else np.uint32(0))
                   for f in fanins]
            if tt == _AND_TT and len(fanins) == 2:
                vals[self.n_pis + 1 + i] = ins[0] & ins[1]
                continue
            m = len(fanins)
            state = np.where(_tt_words(tt, m)[:, None].astype(bool),
                             _FULL_WORD, np.uint32(0))
            state = np.broadcast_to(state, (1 << m, w))
            half = (1 << m) >> 1
            for j in range(m - 1, -1, -1):
                sel = ins[j]
                state = (state[:half] & ~sel) | (state[half:] & sel)
                half >>= 1
            vals[self.n_pis + 1 + i] = state[0]
        return vals


# ---------------------------------------------------------------------------
# importers
# ---------------------------------------------------------------------------

def import_aig(unet: UNet, aig: AIG) -> List[int]:
    """Add an AIG's AND gates; returns its output literals in unet."""
    assert aig.n_pis == unet.n_pis
    nm = [0] * aig.n_nodes
    for p in range(1, aig.n_pis + 1):
        nm[p] = 2 * p
    for node in range(aig.n_pis + 1, aig.n_nodes):
        if not aig.is_and(node):
            continue
        f0, f1 = aig.fanins(node)
        a = nm[lit_var(f0)] ^ (f0 & 1)
        b = nm[lit_var(f1)] ^ (f1 & 1)
        nm[node] = unet.and2(a, b)
    return [nm[lit_var(o)] ^ (o & 1) for o in aig.outputs]


def import_mapped(unet: UNet, mapped) -> List[int]:
    """Add a mapped k-LUT netlist as LUT gates (per-INIT semantics)."""
    assert mapped.n_pis == unet.n_pis
    nm = {0: 0}
    for p in range(1, mapped.n_pis + 1):
        nm[p] = 2 * p
    for l in mapped.luts:
        ins = tuple(nm[leaf] for leaf in l.leaves)
        nm[l.root] = unet.add(ins, l.tt)
    return [nm[lit_var(o)] ^ (o & 1) for o in mapped.outputs]


def import_plan(unet: UNet, dplan) -> List[int]:
    """Add a DevicePlan slot by slot (pad slots skipped), independent of
    the MappedNetwork it was compiled from."""
    assert dplan.n_pis == unet.n_pis
    wm = {0: 0}
    for p in range(1, dplan.n_pis + 1):
        wm[p] = 2 * p
    n_levels, lw, _k = dplan.leaf_idx.shape
    for lvl in range(n_levels):
        for s in range(lw):
            ow = int(dplan.out_wires[lvl, s])
            if ow >= dplan.n_wires:          # pad slot writes the dump row
                continue
            ins = tuple(wm[int(wi)] for wi in dplan.leaf_idx[lvl, s])
            tt = 0
            for r, bit in enumerate(dplan.tt_bits[lvl, s]):
                if bit:
                    tt |= 1 << r
            wm[ow] = unet.add(ins, tt)
    return [wm[int(i)] ^ (1 if neg else 0)
            for i, neg in zip(dplan.out_idx, dplan.out_neg)]


# ---------------------------------------------------------------------------
# care set
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CareSet:
    """Reachable-code constraint: each group is (0-based PI indices of
    one little-endian code, number of valid codes)."""

    groups: Tuple[Tuple[Tuple[int, ...], int], ...]

    @staticmethod
    def from_network(net) -> "CareSet":
        bits = net.in_spec.code_bits
        n_valid = net.in_spec.n_levels
        return CareSet(tuple(
            (tuple(range(i * bits, (i + 1) * bits)), n_valid)
            for i in range(net.n_inputs)))

    def random_words(self, n_pis: int, n_words: int,
                     rng: np.random.Generator) -> np.ndarray:
        """Random packed PI words drawing every group from its valid
        codes (free PIs uniform)."""
        lanes = n_words * WORD_BITS
        planes = rng.integers(0, 2, (n_pis, lanes), dtype=np.uint8)
        for pis, n_valid in self.groups:
            codes = rng.integers(0, n_valid, lanes)
            for b, p in enumerate(pis):
                planes[p] = (codes >> b) & 1
        return pack_bits(planes)


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

class _Repr:
    """Union-find over literals: rep[node] is the literal the node was
    proved equal to (its var is always a smaller node id)."""

    def __init__(self, n_nodes: int):
        self.rep = [2 * n for n in range(n_nodes)]

    def find(self, node: int) -> int:
        l = self.rep[node]
        if l >> 1 == node:
            return l
        r = self.find(l >> 1) ^ (l & 1)
        self.rep[node] = r
        return r

    def find_lit(self, lit: int) -> int:
        return self.find(lit >> 1) ^ (lit & 1)


@dataclasses.dataclass
class FormalResult:
    """Outcome of a formal equivalence query.

    ``verdict``: ``UNSAT`` (proved equivalent on the care set), ``SAT``
    (inequivalent; ``cex`` holds the PI bit vector, already replayed on
    the unified netlist), or ``UNPROVEN`` (budget exhausted — the
    caller must fall back to sampling and say so).
    """

    verdict: str
    stats: Dict[str, int]
    cex: Optional[Tuple[int, ...]] = None

    @property
    def proved(self) -> bool:
        return self.verdict == UNSAT


class _Engine:
    def __init__(self, unet: UNet, care: Optional[CareSet],
                 budget: int, seed: int):
        self.unet = unet
        self.care = care
        self.budget = budget
        self.seed = seed
        self.rep = _Repr(unet.n_nodes)
        self.stats: Dict[str, int] = {
            "nodes": unet.n_nodes, "queries": 0, "merged_struct": 0,
            "merged_sat": 0, "refuted": 0, "query_unknown": 0,
            "conflicts": 0, "decisions": 0, "propagations": 0,
            "restarts": 0, "learned": 0,
        }

    def _remaining(self) -> int:
        return self.budget - self.stats["conflicts"]

    def _absorb(self, solver: Solver) -> None:
        for k in ("conflicts", "decisions", "propagations", "restarts",
                  "learned"):
            self.stats[k] += solver.stats[k]

    # ----------------------------------------------------- CNF windows
    def _collect(self, roots: Sequence[int], cap: int):
        """Expand cones (through reprs) from ``roots`` in descending
        node-id order; returns (expanded gates, frontier nodes)."""
        unet, rep = self.unet, self.rep
        heap = []
        seen = set()
        for n in roots:
            if n not in seen:
                seen.add(n)
                heapq.heappush(heap, -n)
        expanded, frontier = set(), set()
        while heap:
            node = -heapq.heappop(heap)
            if not unet.is_gate(node) or len(expanded) >= cap:
                frontier.add(node)
                continue
            expanded.add(node)
            for f in unet.gate(node)[0]:
                v = rep.find_lit(f) >> 1
                if v not in seen:
                    seen.add(v)
                    heapq.heappush(heap, -v)
        return expanded, frontier

    def _build_cnf(self, roots: Sequence[int], cap: int,
                   extra_lits: Sequence[int] = ()):
        """CNF over the window: clauses for expanded gates (fanins
        mapped through reprs), frontier nodes free, care clauses for
        touched PI groups.  ``extra_lits`` (e.g. the miter literals the
        caller will constrain) are allocated *before* the const/care
        clauses so a bare-const or bare-PI miter leg still gets its
        FALSE unit / care constraint.  Returns (cnf, var_of node->var,
        vlit)."""
        expanded, _ = self._collect(roots, cap)
        cnf = CNF()
        var_of: Dict[int, int] = {}

        def vlit(net_lit: int) -> int:
            v = net_lit >> 1
            var = var_of.get(v)
            if var is None:
                var = var_of[v] = cnf.new_var()
            return 2 * var | (net_lit & 1)

        for node in sorted(expanded):
            fanins, tt = self.unet.gate(node)
            ins = [vlit(self.rep.find_lit(f)) for f in fanins]
            out = vlit(2 * node)
            if tt == _AND_TT and len(ins) == 2:
                and_clauses(cnf, out, ins[0], ins[1])
            else:
                lut_clauses(cnf, out, ins, tt)
        for l in extra_lits:
            vlit(l)
        if 0 in var_of:
            cnf.add(2 * var_of[0] ^ 1)      # const node is FALSE
        if self.care is not None:
            for pis, n_valid in self.care.groups:
                if any((p + 1) in var_of for p in pis):
                    care_code_clauses(cnf, [vlit(2 * (p + 1)) for p in pis],
                                      n_valid)
        return cnf, var_of, vlit

    def _query_equal(self, lit_a: int, lit_b: int, conflicts: int):
        """SAT query: can lit_a != lit_b?  Returns solver verdict."""
        self.stats["queries"] += 1
        roots = [l >> 1 for l in (lit_a, lit_b) if (l >> 1) != 0]
        cnf, _, vlit = self._build_cnf(roots, _WINDOW_CAP,
                                       extra_lits=(lit_a, lit_b))
        miter_clauses(cnf, [(vlit(lit_a), vlit(lit_b))])
        s = cnf.solver()
        verdict = s.solve(conflict_budget=conflicts)
        self._absorb(s)
        return verdict

    # ------------------------------------------------------- sweeping
    def sweep(self, sim_words: int = _SIM_WORDS) -> None:
        unet, rep = self.unet, self.rep
        rng = np.random.default_rng(self.seed)
        if self.care is not None:
            pi_words = self.care.random_words(unet.n_pis, sim_words, rng)
        else:
            pi_words = rng.integers(0, 1 << WORD_BITS,
                                    (unet.n_pis, sim_words), dtype=np.uint32)
        vals = unet.simulate(pi_words)
        inv = ~vals
        sig_class: Dict[bytes, Tuple[int, int]] = {}
        strash: Dict[Tuple[Tuple[int, ...], int], Tuple[int, int]] = {}
        for node in range(unet.n_nodes):
            s0, s1 = vals[node].tobytes(), inv[node].tobytes()
            flip = s1 < s0
            canon = s1 if flip else s0
            if not unet.is_gate(node):
                sig_class.setdefault(canon, (node, flip))
                continue
            # structural rehash through current reprs
            fanins, tt = unet.gate(node)
            norm = _normalize([rep.find_lit(f) for f in fanins], tt)
            if norm[0] == "lit":
                rep.rep[node] = rep.find_lit(norm[1])
                self.stats["merged_struct"] += 1
                continue
            _, fans, ntt, compl = norm
            prev = strash.get((fans, ntt))
            if prev is not None and prev[0] != node:
                # node = f^compl, prev_node = f^prev_compl for the same
                # phase-canonical f => node = prev_node ^ (compl ^ pc)
                rep.rep[node] = rep.find(prev[0]) ^ (compl ^ prev[1])
                self.stats["merged_struct"] += 1
                continue
            strash.setdefault((fans, ntt), (node, compl))
            # signature candidate
            hit = sig_class.get(canon)
            if hit is None:
                sig_class[canon] = (node, flip)
                continue
            cand, cflip = hit
            target = rep.find(cand) ^ (flip ^ cflip)
            if target == rep.find(node):
                continue
            if self._remaining() <= 0:
                self.stats["query_unknown"] += 1
                continue
            cap = min(_QUERY_CONFLICTS, self._remaining())
            verdict = self._query_equal(2 * node, target, cap)
            if verdict == "UNSAT":
                rep.rep[node] = target
                self.stats["merged_sat"] += 1
            elif verdict == "SAT":
                self.stats["refuted"] += 1
            else:
                self.stats["query_unknown"] += 1

    # ---------------------------------------------------- final miter
    def prove(self, pairs: Sequence[Tuple[int, int]],
              sim_words: int = _SIM_WORDS) -> FormalResult:
        self.sweep(sim_words=sim_words)
        rep = self.rep
        unresolved = [(a, b) for a, b in pairs
                      if rep.find_lit(a) != rep.find_lit(b)]
        self.stats["outputs"] = len(pairs)
        self.stats["outputs_merged"] = len(pairs) - len(unresolved)
        if not unresolved:
            return FormalResult(UNSAT, self.stats)
        remaining = self._remaining()
        if remaining <= 0:
            return FormalResult(UNPROVEN, self.stats)
        miter_lits = [rep.find_lit(l) for ab in unresolved for l in ab]
        cnf, var_of, vlit = self._build_cnf(
            [l >> 1 for l in miter_lits if (l >> 1) != 0],
            cap=self.unet.n_nodes + 1, extra_lits=miter_lits)
        miter_clauses(cnf, [(vlit(rep.find_lit(a)), vlit(rep.find_lit(b)))
                            for a, b in unresolved])
        s = cnf.solver()
        verdict = s.solve(conflict_budget=remaining)
        self._absorb(s)
        if verdict == "UNSAT":
            return FormalResult(UNSAT, self.stats)
        if verdict != "SAT":
            return FormalResult(UNPROVEN, self.stats)
        model = s.model()
        bits = tuple(
            model[var_of[p + 1]] if (p + 1) in var_of else 0
            for p in range(self.unet.n_pis))
        # replay on the unified netlist: the model must actually split
        # some output pair, else the engine (not the netlist) is broken
        words = pack_bits(np.array(bits, np.uint8)[:, None])
        vals = self.unet.simulate(words)

        def bit(lit: int) -> int:
            return int(vals[lit >> 1][0] & 1) ^ (lit & 1)

        if not any(bit(a) != bit(b) for a, b in pairs):
            self.stats["bad_cex"] = self.stats.get("bad_cex", 0) + 1
            return FormalResult(UNPROVEN, self.stats)
        return FormalResult(SAT, self.stats, cex=bits)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def prove_pairs(unet: UNet, outs_a: Sequence[int], outs_b: Sequence[int],
                care: Optional[CareSet] = None,
                conflict_budget: int = DEFAULT_CONFLICT_BUDGET,
                seed: int = 0, sim_words: int = _SIM_WORDS) -> FormalResult:
    """Prove pointwise equality of two output-literal lists of a UNet."""
    eng = _Engine(unet, care, conflict_budget, seed)
    return eng.prove(list(zip(outs_a, outs_b)), sim_words=sim_words)


def prove_aig_equiv(ref: AIG, dut: AIG, *, care: Optional[CareSet] = None,
                    conflict_budget: int = DEFAULT_CONFLICT_BUDGET,
                    seed: int = 0) -> FormalResult:
    unet = UNet(ref.n_pis)
    oa = import_aig(unet, ref)
    ob = import_aig(unet, dut)
    return prove_pairs(unet, oa, ob, care, conflict_budget, seed)


def prove_aig_mapped(aig: AIG, mapped, *, care: Optional[CareSet] = None,
                     conflict_budget: int = DEFAULT_CONFLICT_BUDGET,
                     seed: int = 0) -> FormalResult:
    unet = UNet(aig.n_pis)
    oa = import_aig(unet, aig)
    ob = import_mapped(unet, mapped)
    return prove_pairs(unet, oa, ob, care, conflict_budget, seed)


def prove_mapped_equiv(a, b, *, care: Optional[CareSet] = None,
                       conflict_budget: int = DEFAULT_CONFLICT_BUDGET,
                       seed: int = 0) -> FormalResult:
    unet = UNet(a.n_pis)
    oa = import_mapped(unet, a)
    ob = import_mapped(unet, b)
    return prove_pairs(unet, oa, ob, care, conflict_budget, seed)


def prove_mapped_plan(mapped, dplan, *, care: Optional[CareSet] = None,
                      conflict_budget: int = DEFAULT_CONFLICT_BUDGET,
                      seed: int = 0) -> FormalResult:
    unet = UNet(mapped.n_pis)
    oa = import_mapped(unet, mapped)
    ob = import_plan(unet, dplan)
    return prove_pairs(unet, oa, ob, care, conflict_budget, seed)


def prove_network_mapped(net, mapped, *,
                         conflict_budget: int = DEFAULT_CONFLICT_BUDGET,
                         seed: int = 0) -> FormalResult:
    """LogicNetwork (via its SOP-derived AIG) <-> mapped netlist, on the
    quantizer care set: unreachable activation codes are excluded by
    CNF blocking clauses, exactly mirroring espresso's don't-cares."""
    from repro.synth.from_sop import network_to_aig
    ref = network_to_aig(net)
    unet = UNet(ref.n_pis)
    oa = import_aig(unet, ref)
    ob = import_mapped(unet, mapped)
    return prove_pairs(unet, oa, ob, CareSet.from_network(net),
                       conflict_budget, seed)
