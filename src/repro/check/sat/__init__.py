"""repro.check.sat — formal equivalence via a self-contained CDCL SAT
solver.

The sampled miter in :mod:`repro.check.equiv` is a proof only up to 20
primary inputs.  This package turns the wide-cone check into a proof at
any width:

  * :mod:`.solver` — CDCL (two-watched-literal propagation, VSIDS
    activity, Luby restarts, learned-clause DB reduction, conflict
    budget), pure stdlib;
  * :mod:`.cnf` — Tseitin encoding of AND gates, per-INIT-row and
    ISOP (Minato-Morreale) encodings of LUTs, quantizer care-set
    blocking clauses, miter construction;
  * :mod:`.engine` — unified-netlist import of both miter sides plus
    simulation-guided SAT sweeping; verdicts are ``UNSAT`` (proved),
    ``SAT`` (counterexample, replayed before reporting) or
    ``UNPROVEN`` (budget exhausted — callers fall back to sampling
    *loudly*);
  * :mod:`.sweep` — duplicate-LUT-output detection/merge over the
    mapped net (signature candidates, SAT confirmation).
"""
from .engine import (DEFAULT_CONFLICT_BUDGET, SAT, UNPROVEN, UNSAT,
                     CareSet, FormalResult, UNet, import_aig,
                     import_mapped, import_plan, prove_aig_equiv,
                     prove_aig_mapped, prove_mapped_equiv,
                     prove_mapped_plan, prove_network_mapped, prove_pairs)
from .solver import Solver, luby
from .sweep import (check_duplicate_lut_outputs, find_duplicate_lut_outputs,
                    merge_duplicate_lut_outputs)

__all__ = [
    "DEFAULT_CONFLICT_BUDGET", "SAT", "UNPROVEN", "UNSAT",
    "CareSet", "FormalResult", "Solver", "UNet",
    "check_duplicate_lut_outputs", "find_duplicate_lut_outputs",
    "import_aig", "import_mapped", "import_plan", "luby",
    "merge_duplicate_lut_outputs",
    "prove_aig_equiv", "prove_aig_mapped", "prove_mapped_equiv",
    "prove_mapped_plan", "prove_network_mapped", "prove_pairs",
]
