"""Self-contained CDCL SAT solver (MiniSat-style, pure stdlib).

The formal equivalence engine needs exact answers on miter CNFs whose
cones exceed the 20-PI exhaustive limit.  External solvers are off the
table (no new deps), so this module implements the classic conflict-
driven clause-learning loop:

  * two-watched-literal unit propagation (watch invariant: the first
    two literals of every clause are the watched ones);
  * first-UIP conflict analysis with on-the-fly variable bumping;
  * VSIDS-style decision heuristic (activity heap with lazy deletion)
    plus phase saving;
  * Luby-sequence restarts;
  * a learned-clause database reduced by activity when it outgrows a
    geometrically increasing cap;
  * a *conflict budget*: ``solve`` returns ``UNKNOWN`` instead of
    looping forever, which the engine maps to an ``UNPROVEN`` verdict
    and a fall back to sampling.

Literal encoding matches the AIG convention used across ``repro.synth``:
variable ``v`` (0-based) has positive literal ``2*v`` and negative
literal ``2*v + 1``; ``lit ^ 1`` negates.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence

SAT = "SAT"
UNSAT = "UNSAT"
UNKNOWN = "UNKNOWN"

_RESCALE = 1e100
_VAR_DECAY = 0.95
_CLA_DECAY = 0.999
_RESTART_UNIT = 128          # Luby base, in conflicts


def luby(i: int) -> int:
    """i-th term (1-based) of the Luby restart sequence 1,1,2,1,1,2,4,…"""
    while True:
        k = 1
        while (1 << k) - 1 < i:
            k += 1
        if (1 << k) - 1 == i:
            return 1 << (k - 1)
        i -= (1 << (k - 1)) - 1


class _Clause:
    __slots__ = ("lits", "learned", "act")

    def __init__(self, lits: List[int], learned: bool):
        self.lits = lits
        self.learned = learned
        self.act = 0.0


class Solver:
    """CDCL solver over literals ``2*var | sign`` (sign 1 = negated)."""

    def __init__(self, n_vars: int = 0):
        self.n_vars = 0
        self.assigns: List[int] = []       # -1 unassigned / 0 false / 1 true
        self.level: List[int] = []
        self.reason: List[Optional[_Clause]] = []
        self.watches: List[List[_Clause]] = []
        self.activity: List[float] = []
        self.polarity: List[int] = []      # saved phase (1 = last true)
        self.trail: List[int] = []
        self.trail_lim: List[int] = []
        self.qhead = 0
        self.clauses: List[_Clause] = []
        self.learnts: List[_Clause] = []
        self.var_inc = 1.0
        self.cla_inc = 1.0
        self._heap: List = []              # (-activity, var), lazy deletes
        self.ok = True
        self.stats: Dict[str, int] = {
            "conflicts": 0, "decisions": 0, "propagations": 0,
            "restarts": 0, "learned": 0, "db_reductions": 0,
        }
        for _ in range(n_vars):
            self.new_var()

    # ------------------------------------------------------------- setup
    def new_var(self) -> int:
        v = self.n_vars
        self.n_vars += 1
        self.assigns.append(-1)
        self.level.append(-1)
        self.reason.append(None)
        self.watches.append([])
        self.watches.append([])
        self.activity.append(0.0)
        self.polarity.append(0)
        heapq.heappush(self._heap, (0.0, v))
        return v

    def value(self, lit: int) -> int:
        va = self.assigns[lit >> 1]
        return va if va < 0 else va ^ (lit & 1)

    def add_clause(self, lits: Sequence[int]) -> bool:
        """Add a problem clause; returns False on a root-level conflict."""
        if not self.ok:
            return False
        seen = set()
        out: List[int] = []
        for l in lits:
            if l ^ 1 in seen:
                return True                          # tautology
            if l in seen:
                continue
            if self.value(l) == 1 and self.level[l >> 1] == 0:
                return True                          # already satisfied
            if self.value(l) == 0 and self.level[l >> 1] == 0:
                continue                             # falsified at root
            seen.add(l)
            out.append(l)
        if not out:
            self.ok = False
            return False
        if len(out) == 1:
            if not self._enqueue(out[0], None):
                self.ok = False
                return False
            self.ok = self._propagate() is None
            return self.ok
        c = _Clause(out, learned=False)
        self.clauses.append(c)
        self._watch(c)
        return True

    def _watch(self, c: _Clause) -> None:
        self.watches[c.lits[0] ^ 1].append(c)
        self.watches[c.lits[1] ^ 1].append(c)

    # ------------------------------------------------------ assignments
    def _enqueue(self, lit: int, frm: Optional[_Clause]) -> bool:
        val = self.value(lit)
        if val >= 0:
            return val == 1
        v = lit >> 1
        self.assigns[v] = 1 - (lit & 1)
        self.level[v] = len(self.trail_lim)
        self.reason[v] = frm
        self.polarity[v] = 1 - (lit & 1)
        self.trail.append(lit)
        return True

    def _propagate(self) -> Optional[_Clause]:
        while self.qhead < len(self.trail):
            p = self.trail[self.qhead]
            self.qhead += 1
            self.stats["propagations"] += 1
            ws = self.watches[p]
            self.watches[p] = []
            i = 0
            n = len(ws)
            while i < n:
                c = ws[i]
                i += 1
                lits = c.lits
                # ensure the falsified watch (¬p) sits at slot 1
                if lits[0] == p ^ 1:
                    lits[0], lits[1] = lits[1], lits[0]
                if self.value(lits[0]) == 1:
                    self.watches[p].append(c)
                    continue
                moved = False
                for j in range(2, len(lits)):
                    if self.value(lits[j]) != 0:
                        lits[1], lits[j] = lits[j], lits[1]
                        self.watches[lits[1] ^ 1].append(c)
                        moved = True
                        break
                if moved:
                    continue
                # unit or conflicting
                self.watches[p].append(c)
                if not self._enqueue(lits[0], c):
                    self.watches[p].extend(ws[i:])
                    self.qhead = len(self.trail)
                    return c
        return None

    # -------------------------------------------------------- conflicts
    def _bump_var(self, v: int) -> None:
        self.activity[v] += self.var_inc
        if self.activity[v] > _RESCALE:
            inv = 1.0 / _RESCALE
            for u in range(self.n_vars):
                self.activity[u] *= inv
            self.var_inc *= inv
        heapq.heappush(self._heap, (-self.activity[v], v))

    def _bump_cla(self, c: _Clause) -> None:
        c.act += self.cla_inc
        if c.act > _RESCALE:
            inv = 1.0 / _RESCALE
            for d in self.learnts:
                d.act *= inv
            self.cla_inc *= inv

    def _analyze(self, confl: _Clause):
        learnt: List[int] = [0]
        seen = bytearray(self.n_vars)
        counter = 0
        p = -1
        index = len(self.trail) - 1
        cur_level = len(self.trail_lim)
        c: Optional[_Clause] = confl
        while True:
            assert c is not None
            if c.learned:
                self._bump_cla(c)
            for q in c.lits:
                if q == p:
                    continue
                v = q >> 1
                if not seen[v] and self.level[v] > 0:
                    seen[v] = 1
                    self._bump_var(v)
                    if self.level[v] >= cur_level:
                        counter += 1
                    else:
                        learnt.append(q)
            while not seen[self.trail[index] >> 1]:
                index -= 1
            p = self.trail[index]
            v = p >> 1
            c = self.reason[v]
            seen[v] = 0
            index -= 1
            counter -= 1
            if counter == 0:
                break
        learnt[0] = p ^ 1
        if len(learnt) == 1:
            bt = 0
        else:
            # move the highest-level tail literal to slot 1 (watch it)
            mi = 1
            for j in range(2, len(learnt)):
                if self.level[learnt[j] >> 1] > self.level[learnt[mi] >> 1]:
                    mi = j
            learnt[1], learnt[mi] = learnt[mi], learnt[1]
            bt = self.level[learnt[1] >> 1]
        return learnt, bt

    def _backtrack(self, lvl: int) -> None:
        if len(self.trail_lim) <= lvl:
            return
        bound = self.trail_lim[lvl]
        for i in range(len(self.trail) - 1, bound - 1, -1):
            v = self.trail[i] >> 1
            self.assigns[v] = -1
            self.reason[v] = None
            heapq.heappush(self._heap, (-self.activity[v], v))
        del self.trail[bound:]
        del self.trail_lim[lvl:]
        self.qhead = len(self.trail)

    # -------------------------------------------------------- decisions
    def _pick_branch(self) -> int:
        while self._heap:
            act, v = heapq.heappop(self._heap)
            if self.assigns[v] < 0 and -act == self.activity[v]:
                return v
        for v in range(self.n_vars):          # heap starved: linear scan
            if self.assigns[v] < 0:
                return v
        return -1

    # ---------------------------------------------------------- DB care
    def _reduce_db(self) -> None:
        self.stats["db_reductions"] += 1
        locked = {id(self.reason[l >> 1]) for l in self.trail
                  if self.reason[l >> 1] is not None}
        self.learnts.sort(key=lambda c: c.act)
        keep: List[_Clause] = []
        half = len(self.learnts) // 2
        for i, c in enumerate(self.learnts):
            if len(c.lits) <= 2 or id(c) in locked or i >= half:
                keep.append(c)
        kept = {id(c) for c in keep}
        self.learnts = keep
        for wl in range(2 * self.n_vars):
            self.watches[wl] = [c for c in self.watches[wl]
                                if not c.learned or id(c) in kept]

    # ------------------------------------------------------------ solve
    def solve(self, conflict_budget: Optional[int] = None) -> str:
        """Run CDCL search; returns ``SAT`` / ``UNSAT`` / ``UNKNOWN``.

        After ``SAT`` the model is in :attr:`assigns` (see
        :meth:`model`); ``UNKNOWN`` means the conflict budget ran out.
        """
        if not self.ok:
            return UNSAT
        if self._propagate() is not None:
            self.ok = False
            return UNSAT
        max_learnts = max(1000, len(self.clauses) // 3)
        restart_idx = 1
        restart_lim = luby(restart_idx) * _RESTART_UNIT
        since_restart = 0
        while True:
            confl = self._propagate()
            if confl is not None:
                self.stats["conflicts"] += 1
                since_restart += 1
                if not self.trail_lim:
                    self.ok = False
                    return UNSAT
                learnt, bt = self._analyze(confl)
                self._backtrack(bt)
                if len(learnt) == 1:
                    self._enqueue(learnt[0], None)
                else:
                    c = _Clause(learnt, learned=True)
                    c.act = self.cla_inc
                    self.learnts.append(c)
                    self.stats["learned"] += 1
                    self._watch(c)
                    self._enqueue(learnt[0], c)
                self.var_inc /= _VAR_DECAY
                self.cla_inc /= _CLA_DECAY
                if (conflict_budget is not None
                        and self.stats["conflicts"] >= conflict_budget):
                    self._backtrack(0)
                    return UNKNOWN
                if since_restart >= restart_lim:
                    self.stats["restarts"] += 1
                    restart_idx += 1
                    restart_lim = luby(restart_idx) * _RESTART_UNIT
                    since_restart = 0
                    self._backtrack(0)
                if len(self.learnts) >= max_learnts + len(self.trail):
                    self._reduce_db()
                    max_learnts = int(max_learnts * 1.5)
            else:
                v = self._pick_branch()
                if v < 0:
                    return SAT
                self.stats["decisions"] += 1
                self.trail_lim.append(len(self.trail))
                self._enqueue(2 * v | (self.polarity[v] ^ 1), None)

    def model(self) -> List[int]:
        """Assignment after ``SAT``: ``model()[v]`` is 0/1 (unassigned
        vars default to 0)."""
        return [a if a >= 0 else 0 for a in self.assigns]
