"""SAT sweeping over the mapped netlist: find and merge duplicate LUTs.

Technology mapping covers each output cone independently, so two LUTs
can compute the same function (possibly complemented) of the same
support — wasted area the analytic cost model never sees.  This pass
finds them the fraig way: candidate pairs from simulation signatures,
confirmed by SAT (a merge happens only on an UNSAT miter, so it is a
proof, never a heuristic), reported as lint warnings, and optionally
merged — consumers are rewired onto the surviving root (a complemented
merge flips the consumer's truth-table variable), dead LUTs dropped.
Measured LUT savings feed the Table-1 report.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.synth.aig import lit_var
from repro.synth.lutmap import MappedLUT, MappedNetwork

from ..report import CheckReport
from .engine import DEFAULT_CONFLICT_BUDGET, UNet, _Engine, _flip_var

PASS = "formal"

# (keep_lut_index, duplicate_lut_index, complemented)
DupPair = Tuple[int, int, bool]


def find_duplicate_lut_outputs(mapped: MappedNetwork,
                               conflict_budget: int = DEFAULT_CONFLICT_BUDGET,
                               seed: int = 0
                               ) -> Tuple[List[DupPair], Dict[str, int]]:
    """SAT-proven pairs of LUTs whose outputs are equal (or complements).

    Only pairs both of whose proofs fit the conflict budget are
    returned — an unproven candidate is simply not reported, so the
    result is always sound.
    """
    unet = UNet(mapped.n_pis)
    nm = {0: 0}
    for p in range(1, mapped.n_pis + 1):
        nm[p] = 2 * p
    root_lits: List[int] = []
    for l in mapped.luts:
        out = unet.add(tuple(nm[leaf] for leaf in l.leaves), l.tt)
        nm[l.root] = out
        root_lits.append(out)
    eng = _Engine(unet, None, conflict_budget, seed)
    eng.sweep()
    classes: Dict[int, Tuple[int, int]] = {}
    pairs: List[DupPair] = []
    for i, out in enumerate(root_lits):
        r = eng.rep.find_lit(out)
        prev = classes.get(r >> 1)
        if prev is None:
            classes[r >> 1] = (i, r & 1)
        else:
            keep, keep_sign = prev
            pairs.append((keep, i, bool((r & 1) ^ keep_sign)))
    return pairs, eng.stats


def merge_duplicate_lut_outputs(mapped: MappedNetwork,
                                pairs: List[DupPair]) -> MappedNetwork:
    """Rewire consumers of each duplicate onto the kept LUT and drop
    dead LUTs.  The result computes the same outputs (each merge was
    SAT-proven), usually with fewer LUTs."""
    if not pairs:
        return mapped
    # dup root node -> (keep root node, complemented)
    redirect = {mapped.luts[dup].root: (mapped.luts[keep].root, neg)
                for keep, dup, neg in pairs}
    luts: List[MappedLUT] = []
    for l in mapped.luts:
        if l.root in redirect:
            continue
        leaves = list(l.leaves)
        tt = l.tt
        for j, leaf in enumerate(leaves):
            tgt = redirect.get(leaf)
            if tgt is not None:
                leaves[j] = tgt[0]
                if tgt[1]:
                    tt = _flip_var(tt, len(leaves), j)
        luts.append(MappedLUT(l.root, tuple(leaves), tt))
    outputs = []
    for o in mapped.outputs:
        tgt = redirect.get(lit_var(o))
        if tgt is None:
            outputs.append(o)
        else:
            outputs.append(2 * tgt[0] | ((o & 1) ^ int(tgt[1])))
    # drop LUTs no longer reachable from the outputs
    needed = set()
    stack = [lit_var(o) for o in outputs]
    by_root = {l.root: l for l in luts}
    while stack:
        n = stack.pop()
        if n in needed or n not in by_root:
            continue
        needed.add(n)
        stack.extend(by_root[n].leaves)
    luts = [l for l in luts if l.root in needed]
    return MappedNetwork(mapped.n_pis, mapped.k, luts, outputs)


def check_duplicate_lut_outputs(mapped: MappedNetwork,
                                conflict_budget: int
                                = DEFAULT_CONFLICT_BUDGET,
                                seed: int = 0,
                                name: str = "sat-sweep") -> CheckReport:
    """Lint: warn on every SAT-proven duplicate LUT output and record
    the measured LUT count a merge would reach."""
    rep = CheckReport(name)
    pairs, stats = find_duplicate_lut_outputs(
        mapped, conflict_budget=conflict_budget, seed=seed)
    rep.checked += mapped.n_luts
    merged = merge_duplicate_lut_outputs(mapped, pairs)
    rep.info["sat_sweep"] = {
        "dup_lut_outputs": len(pairs),
        "luts": mapped.n_luts,
        "luts_after_sweep": merged.n_luts,
        "sat_queries": stats["queries"],
        "conflicts": stats["conflicts"],
    }
    for keep, dup, neg in pairs:
        k, d = mapped.luts[keep], mapped.luts[dup]
        rep.warn(PASS, "sat-sweep",
                 f"LUT {dup} (root {d.root}) duplicates LUT {keep} "
                 f"(root {k.root}){' complemented' if neg else ''} — "
                 f"SAT-proven; merging would drop "
                 f"{mapped.n_luts - merged.n_luts} LUT(s)")
    return rep
