"""Streaming windowed metrics: per-lane time series, not one snapshot.

``ServeMetrics.snapshot()`` answers "how did the whole run go";
nothing in the repo could answer "what is happening *right now*" — a
p99 that degraded in the last two seconds is invisible inside an
end-of-run histogram. This module keeps bounded **tumbling windows**
(fixed-width time buckets on a ring, old buckets evicted as time
advances) and derives **sliding-window** views by summing the most
recent buckets, the standard streaming-aggregation trade: O(1) memory
per window, O(windows) query cost, no per-event allocation beyond a
bounded latency reservoir.

Feeding is push-based: ``ServeMetrics.add_sink(WindowedMetrics(...))``
forwards every completion/shed/batch to the window aggregator with the
scheduler-clock timestamp, so FakeClock tests produce exact,
deterministic series. ``series()`` returns per-lane
``[{t_us, qps, p50_us, p99_us, slo_attainment, ...}]`` rows plus a
batch-occupancy track; ``sliding(span_us)`` merges the trailing span
into one record (what the SLO burn-rate monitor in ``repro.obs.slo``
is built on).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np

# per-bucket latency reservoir bound: enough for exact-ish tail
# percentiles at smoke-benchmark scale without per-event allocation
DEFAULT_BUCKET_SAMPLES = 512


class _Bucket:
    """One tumbling-window bucket of lane activity."""

    __slots__ = ("n_done", "n_ok", "n_miss", "n_shed", "rows",
                 "lat_sum_us", "samples", "_max_samples")

    def __init__(self, max_samples: int = DEFAULT_BUCKET_SAMPLES):
        self.n_done = 0         # completions landing in this bucket
        self.n_ok = 0           # completed within deadline (or no deadline)
        self.n_miss = 0         # completed past deadline
        self.n_shed = 0         # expired before dispatch
        self.rows = 0
        self.lat_sum_us = 0.0
        self.samples: List[float] = []
        self._max_samples = max_samples

    def add_done(self, latency_us: float, ok: bool, rows: int,
                 has_deadline: bool = True) -> None:
        self.n_done += 1
        self.rows += rows
        self.lat_sum_us += latency_us
        # only deadline-carrying traffic enters the attainment counters:
        # a best-effort completion is neither "within SLO" nor a miss
        if has_deadline:
            if ok:
                self.n_ok += 1
            else:
                self.n_miss += 1
        if len(self.samples) < self._max_samples:
            self.samples.append(latency_us)
        else:   # deterministic stride reservoir (matches LatencyHistogram)
            self.samples[self.n_done % self._max_samples] = latency_us

    def merge(self, other: "_Bucket") -> "_Bucket":
        self.n_done += other.n_done
        self.n_ok += other.n_ok
        self.n_miss += other.n_miss
        self.n_shed += other.n_shed
        self.rows += other.rows
        self.lat_sum_us += other.lat_sum_us
        room = self._max_samples - len(self.samples)
        if room > 0:
            self.samples.extend(other.samples[:room])
        return self

    def record(self, t_us: float, window_us: float) -> Dict[str, float]:
        s = np.asarray(self.samples) if self.samples else None
        slo_n = self.n_ok + self.n_miss + self.n_shed
        return {
            "t_us": t_us,
            "n": self.n_done,
            "shed": self.n_shed,
            "rows": self.rows,
            "qps": self.n_done / (window_us * 1e-6) if window_us else 0.0,
            "mean_us": (self.lat_sum_us / self.n_done
                        if self.n_done else 0.0),
            "p50_us": float(np.percentile(s, 50)) if s is not None else 0.0,
            "p99_us": float(np.percentile(s, 99)) if s is not None else 0.0,
            # attainment over deadline-carrying traffic incl. sheds; a
            # window with no such traffic reports None, never a fake 1.0
            "slo_attainment": (self.n_ok / slo_n if slo_n else None),
        }


class BucketRing:
    """Tumbling time buckets keyed by ``floor(ts / window_us)``.

    Holds at most ``n_windows`` live buckets; anything older than the
    retention horizon is evicted on write. Thread-safe — feeds arrive
    from scheduler and client threads.
    """

    _GUARDED_BY = {"_buckets": "_lock"}
    _LOCKED_METHODS = ("bucket",)

    def __init__(self, window_us: float, n_windows: int = 120,
                 max_samples: int = DEFAULT_BUCKET_SAMPLES):
        assert window_us > 0 and n_windows >= 1
        self.window_us = float(window_us)
        self.n_windows = int(n_windows)
        self._max_samples = max_samples
        self._buckets: Dict[int, _Bucket] = {}
        self._lock = threading.Lock()

    def _index(self, ts_us: float) -> int:
        return int(ts_us // self.window_us)

    def bucket(self, ts_us: float) -> _Bucket:
        """Get-or-create the bucket covering ``ts_us`` (caller must hold
        the lock); evicts buckets past the retention horizon."""
        idx = self._index(ts_us)
        b = self._buckets.get(idx)
        if b is None:
            b = self._buckets[idx] = _Bucket(self._max_samples)
            if len(self._buckets) > self.n_windows:
                floor = idx - self.n_windows + 1
                for k in [k for k in self._buckets if k < floor]:
                    del self._buckets[k]
        return b

    def add_done(self, ts_us: float, latency_us: float, ok: bool,
                 rows: int = 1, has_deadline: bool = True) -> None:
        with self._lock:
            self.bucket(ts_us).add_done(latency_us, ok, rows, has_deadline)

    def add_shed(self, ts_us: float) -> None:
        with self._lock:
            self.bucket(ts_us).n_shed += 1

    def merged(self, now_us: float, span_us: float) -> _Bucket:
        """One bucket summing everything in ``[now - span, now]``."""
        lo = self._index(now_us - span_us)
        hi = self._index(now_us)
        out = _Bucket(self._max_samples)
        with self._lock:
            for idx in range(lo, hi + 1):
                b = self._buckets.get(idx)
                if b is not None:
                    out.merge(b)
        return out

    def series(self, now_us: Optional[float] = None) -> List[Dict]:
        """All retained buckets as time-ordered records."""
        with self._lock:
            items = sorted(self._buckets.items())
        return [b.record(idx * self.window_us, self.window_us)
                for idx, b in items]


class WindowedMetrics:
    """Per-lane streaming window aggregation (a ``ServeMetrics`` sink).

    ``record_done``/``record_shed``/``record_batch`` match the sink
    protocol ``ServeMetrics`` forwards into; ``series()`` is the
    queryable product: per-lane tumbling-window time series of QPS,
    p50/p99 latency, SLO attainment and shed counts, plus a batch
    occupancy track. ``sliding(span_us)`` collapses the trailing span
    per lane — the view the burn-rate monitor consumes.
    """

    _GUARDED_BY = {"_lanes": "_lock", "_batches": "_lock"}
    # _last_ts is a monotonic high-water mark: a concurrent max() write
    # can only lose to a *newer* value, and sliding() treats it as an
    # advisory "now" — benign race, deliberately unguarded
    _LOCK_FREE = ("_last_ts",)

    def __init__(self, window_us: float = 1_000_000.0,
                 n_windows: int = 120,
                 max_samples: int = DEFAULT_BUCKET_SAMPLES):
        self.window_us = float(window_us)
        self.n_windows = int(n_windows)
        self._max_samples = max_samples
        self._lanes: Dict[int, BucketRing] = {}
        # batch track: (bucket idx -> [n, rows_sum, occ_sum, exec_sum])
        self._batches: Dict[int, List[float]] = {}
        self._last_ts = 0.0
        self._lock = threading.Lock()

    def _lane(self, lane: int) -> BucketRing:
        with self._lock:
            ring = self._lanes.get(lane)
            if ring is None:
                ring = self._lanes[lane] = BucketRing(
                    self.window_us, self.n_windows, self._max_samples)
            return ring

    # -- sink protocol -----------------------------------------------------
    def record_done(self, lane: int, latency_us: float, now_us: float,
                    ok: bool = True, rows: int = 1,
                    deadline_us: Optional[float] = None, **_kw) -> None:
        self._last_ts = max(self._last_ts, now_us)
        self._lane(lane).add_done(now_us, latency_us, ok, rows,
                                  has_deadline=deadline_us is not None)

    def record_shed(self, lane: int, now_us: float, **_kw) -> None:
        self._last_ts = max(self._last_ts, now_us)
        self._lane(lane).add_shed(now_us)

    def record_batch(self, rows: int, exec_us: float, now_us: float,
                     occupancy: float = 1.0, **_kw) -> None:
        self._last_ts = max(self._last_ts, now_us)
        idx = int(now_us // self.window_us)
        with self._lock:
            acc = self._batches.setdefault(idx, [0, 0.0, 0.0, 0.0])
            acc[0] += 1
            acc[1] += rows
            acc[2] += occupancy
            acc[3] += exec_us
            if len(self._batches) > self.n_windows:
                floor = idx - self.n_windows + 1
                for k in [k for k in self._batches if k < floor]:
                    del self._batches[k]

    # -- queries -----------------------------------------------------------
    def series(self) -> Dict:
        """Everything retained, as per-lane time-ordered window rows."""
        with self._lock:
            lanes = dict(self._lanes)
            batches = sorted(self._batches.items())
        return {
            "window_us": self.window_us,
            "lanes": {str(lane): ring.series()
                      for lane, ring in sorted(lanes.items())},
            "batches": [{
                "t_us": idx * self.window_us,
                "n_batches": int(n),
                "mean_rows": rows / n if n else 0.0,
                "mean_occupancy": occ / n if n else 0.0,
                "mean_exec_us": ex / n if n else 0.0,
            } for idx, (n, rows, occ, ex) in batches],
        }

    def sliding(self, span_us: float,
                now_us: Optional[float] = None) -> Dict[str, Dict]:
        """Trailing-``span_us`` merged record per lane (keys are lane
        ids as strings, matching ``ServeMetrics`` lane snapshots)."""
        now = self._last_ts if now_us is None else now_us
        with self._lock:
            lanes = dict(self._lanes)
        return {str(lane): ring.merged(now, span_us).record(
                    now - span_us, span_us)
                for lane, ring in sorted(lanes.items())}

    def publish(self, registry, name: str = "windows") -> None:
        """Expose the live series through a
        ``repro.obs.MetricsRegistry`` snapshot provider."""
        registry.register(name, self.series)
