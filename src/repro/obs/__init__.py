"""repro.obs — the measurement substrate for the serving stack.

NullaNet Tiny's whole pitch is latency, so latency has to be visible
*with structure*, not just as end-to-end histograms:

  trace      — thread-safe ring-buffer span tracer (injectable clock,
               near-zero overhead when disabled); every request carries
               submit → queue-wait → batch-formation (with flush
               reason) → pack → dispatch → device-exec → scatter spans;
  export     — Chrome trace-event JSON (opens in Perfetto / chrome://
               tracing) and structured JSONL event export;
  registry   — one counters/gauges/histograms registry that
               ``ServeMetrics``, ``ReplicaSet`` and
               ``BitplaneAggregator`` publish into, with a single
               ``snapshot()`` surface;
  kernelprof — per-level ``lut_eval`` device timing fitted into a
               measured ``(level_width, k, fanin) -> µs`` table, written
               as an artifact so ``least_slack`` dispatch and mapping
               search consume calibrated estimates instead of
               cold-start EWMA;
  analyze    — trace artifacts back into per-request phase breakdowns
               ("where did the time go"), reconciliation against the
               scheduler-stamped latency, and trace-vs-trace diffing
               (``python -m repro.obs.analyze --trace ...``);
  window     — streaming tumbling/sliding-window aggregation: per-lane
               QPS / p50 / p99 / SLO-attainment *time series* instead
               of one end-of-run snapshot;
  slo        — multi-window SLO burn-rate monitor with alert callbacks,
               the scheduler's optional degradation hook;
  online     — sampled real-traffic device timings blended back into
               the ``LatencyTable`` so flush margins track the live
               device;
  promexport — Prometheus text-exposition rendering of a registry
               snapshot plus a stdlib pull endpoint
               (``launch.serve --metrics-port``).

``benchmarks/loadgen.py --trace PATH`` and
``repro.launch.serve --trace PATH`` wire the tracer through the whole
request path; ``python -m repro.check --passes trace`` validates trace
well-formedness (monotonic spans, no orphans, valid flush reasons).
"""
from .trace import (FLUSH_REASONS, NULL_TRACER, NullTracer, SpanTracer,
                    TraceEvent)
from .export import (load_trace_events, to_chrome_trace, to_jsonl,
                     write_chrome_trace, write_jsonl)
from .registry import Counter, Gauge, MetricsRegistry
from .kernelprof import (EmptyLatencyTable, LatencyTable,
                         LatencyTableError, measure_level_grid,
                         profile_plan, build_latency_table)
from .analyze import TraceReport, analyze_events, analyze_trace
from .window import BucketRing, WindowedMetrics
from .slo import BurnAlert, BurnRateMonitor
from .online import OnlineProfiler
from .promexport import MetricsServer, to_prometheus_text

__all__ = [
    "FLUSH_REASONS", "NULL_TRACER", "NullTracer", "SpanTracer",
    "TraceEvent",
    "load_trace_events", "to_chrome_trace", "to_jsonl",
    "write_chrome_trace", "write_jsonl",
    "Counter", "Gauge", "MetricsRegistry",
    "EmptyLatencyTable", "LatencyTable", "LatencyTableError",
    "measure_level_grid", "profile_plan", "build_latency_table",
    "TraceReport", "analyze_events", "analyze_trace",
    "BucketRing", "WindowedMetrics",
    "BurnAlert", "BurnRateMonitor",
    "OnlineProfiler",
    "MetricsServer", "to_prometheus_text",
]
