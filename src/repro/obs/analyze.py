"""Trace analytics: per-request phase breakdowns from trace artifacts.

``repro.obs.trace`` records *events*; this module turns them back into
*requests* and answers "where did the microseconds go". The
reconstruction leans on two structural facts of the serving stack:

  * async request spans carry a tracer-allocated ``scope_id``, so a
    request's begin/instants/end pair up across threads by id;
  * the scheduler serializes batches on one dispatch thread and thread
    spans record at context *exit*, so each batch appears in buffer
    order as ``[e queue_wait]*n → X batch_form → (X aggregate_pack,
    X device_exec, X replica_dispatch) → X exec → [e request]*n →
    X scatter`` — a linear scan with a current-batch state machine
    rebinds every request to the batch that served it.

Per-request phase decomposition (all µs):

  ``queue_wait``  enqueue → batch formation (per-request, measured)
  ``batch_form``  payload concatenation for the batch it rode
  ``pack``        bitplane aggregation (quantize + scatter to lanes)
  ``device_exec`` netlist evaluation on the engine
  ``dispatch``    executor time not inside pack/device — replica pick,
                  failover, mesh placement (``exec − pack − device``)
  ``scatter``     result slicing back to futures (*after* the latency
                  stamp — reported, but outside the reconciliation sum)

The **reconciliation invariant** — checked here and by
``repro.check --passes trace`` — is that for every completed request
``queue_wait + batch_form + exec`` matches the ``latency_us`` the
scheduler stamped on the request end (the same number ``ServeMetrics``
aggregates) within tolerance: the trace is only trustworthy if its
phases add back up to the latency the serving stack reports.

Ring-buffer truncation is expected, not an error: orphaned ends (their
begins overwritten) still contribute their ``wait_us``/``latency_us``
args where present and are counted in ``truncated``; a zero-request or
shed-only trace produces a report, not a crash.

CLI::

    python -m repro.obs.analyze --trace serve_trace.json
    python -m repro.obs.analyze --trace new.json --diff old.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

from .trace import TraceEvent

# phases inside the reconciliation sum, in pipeline order
RECON_PHASES = ("queue_wait", "batch_form", "pack", "dispatch",
                "device_exec")
ALL_PHASES = RECON_PHASES + ("scatter", "unattributed")

# absolute slop floor (µs) under the relative tolerance: SystemClock
# traces pay a few clock reads between span edges, and the scheduler
# thread can be preempted for tens of µs between two stamps; FakeClock
# traces reconcile exactly
DEFAULT_TOL = 0.05
ABS_FLOOR_US = 50.0
# fraction of checked requests allowed over tolerance before the trace
# as a whole fails reconciliation: a single OS preemption landing
# between two clock reads inflates one request's gap past any floor,
# and that is scheduler noise, not a mis-attributed span (which shows
# up across *every* request in the affected batches)
STRAGGLER_FRAC = 0.005


@dataclasses.dataclass
class BatchRecord:
    """One dispatched batch reconstructed from thread spans."""

    idx: int
    flush_reason: str = ""
    rows: int = 0
    n_requests: int = 0
    form_us: float = 0.0
    pack_us: float = 0.0
    device_us: float = 0.0
    exec_us: float = 0.0
    scatter_us: float = 0.0
    kernel_us: float = 0.0          # lut_eval spans inside device_exec
    members: List[int] = dataclasses.field(default_factory=list)

    @property
    def dispatch_us(self) -> float:
        """Executor time not attributed to pack or device work."""
        return max(0.0, self.exec_us - self.pack_us - self.device_us)


@dataclasses.dataclass
class RequestRecord:
    """One request lifecycle reassembled from its async span."""

    sid: int
    lane: Optional[int] = None
    rows: int = 1
    deadline_us: Optional[float] = None
    t_begin_us: Optional[float] = None
    t_end_us: Optional[float] = None
    wait_us: Optional[float] = None
    flush_reason: Optional[str] = None
    outcome: Optional[str] = None
    latency_us: Optional[float] = None
    batch: Optional[BatchRecord] = None
    truncated: bool = False         # begin lost to the ring buffer

    def phases_us(self) -> Optional[Dict[str, float]]:
        """Per-phase attribution, or None when the request never rode a
        batch (shed/shutdown) or its timing is incomplete."""
        if self.batch is None or self.wait_us is None:
            return None
        b = self.batch
        out = {"queue_wait": self.wait_us, "batch_form": b.form_us,
               "pack": b.pack_us, "dispatch": b.dispatch_us,
               "device_exec": b.device_us, "scatter": b.scatter_us}
        if self.latency_us is not None:
            recon = self.wait_us + b.form_us + b.exec_us
            out["unattributed"] = self.latency_us - recon
        return out

    def recon_error(self) -> Optional[float]:
        """Relative reconciliation error |phase sum − latency| /
        latency, or None when either side is unknown."""
        if (self.batch is None or self.wait_us is None
                or self.latency_us is None or self.latency_us <= 0):
            return None
        recon = self.wait_us + self.batch.form_us + self.batch.exec_us
        gap = abs(recon - self.latency_us)
        if gap <= ABS_FLOOR_US:         # clock-read jitter, not skew
            return 0.0
        return gap / self.latency_us


class TraceReport:
    """Reconstruction product: requests, batches, and derived stats."""

    def __init__(self, requests: List[RequestRecord],
                 batches: List[BatchRecord], n_events: int,
                 counts: Dict[str, int], tol: float = DEFAULT_TOL):
        self.requests = requests
        self.batches = batches
        self.n_events = n_events
        self.counts = counts
        self.tol = tol

    # -- derived -----------------------------------------------------------
    def reconciliation(self) -> Dict:
        errs = [e for r in self.requests
                if r.outcome == "ok" and (e := r.recon_error()) is not None]
        out = {"tol": self.tol, "n_checked": len(errs),
               "mean_rel_err": float(np.mean(errs)) if errs else 0.0,
               "max_rel_err": float(np.max(errs)) if errs else 0.0,
               "n_over_tol": sum(1 for e in errs if e > self.tol),
               "n_allowed": int(STRAGGLER_FRAC * len(errs))}
        out["ok"] = out["n_over_tol"] <= out["n_allowed"]
        return out

    def phase_summary(self) -> Dict[str, Dict[str, float]]:
        """Request-weighted per-phase stats: every request in a batch
        experiences the batch's full phase time, so request-µs per
        phase is what a latency budget should be carved from."""
        cols: Dict[str, List[float]] = {p: [] for p in ALL_PHASES}
        for r in self.requests:
            ph = r.phases_us()
            if ph is None:
                continue
            for p in ALL_PHASES:
                if p in ph:
                    cols[p].append(ph[p])
        out: Dict[str, Dict[str, float]] = {}
        total = sum(sum(v) for p, v in cols.items()
                    if p != "scatter" and v)
        for p, v in cols.items():
            if not v:
                continue
            a = np.asarray(v)
            out[p] = {"total_us": float(a.sum()),
                      "mean_us": float(a.mean()),
                      "p50_us": float(np.percentile(a, 50)),
                      "p99_us": float(np.percentile(a, 99)),
                      "share": (float(a.sum()) / total
                                if total > 0 and p != "scatter" else 0.0)}
        return out

    def lane_summary(self) -> Dict[str, Dict]:
        lanes: Dict[int, List[RequestRecord]] = {}
        for r in self.requests:
            if r.lane is not None:
                lanes.setdefault(r.lane, []).append(r)
        out = {}
        for lane, rs in sorted(lanes.items()):
            lat = np.asarray([r.latency_us for r in rs
                              if r.latency_us is not None] or [0.0])
            n_shed = sum(1 for r in rs if r.outcome == "shed")
            out[str(lane)] = {
                "n": len(rs), "n_shed": n_shed,
                "p50_us": float(np.percentile(lat, 50)),
                "p99_us": float(np.percentile(lat, 99))}
        return out

    def to_dict(self) -> Dict:
        outcomes: Dict[str, int] = {}
        for r in self.requests:
            key = r.outcome or "unterminated"
            outcomes[key] = outcomes.get(key, 0) + 1
        reasons: Dict[str, int] = {}
        for b in self.batches:
            reasons[b.flush_reason] = reasons.get(b.flush_reason, 0) + 1
        kernel = sum(b.kernel_us for b in self.batches)
        return {
            "n_events": self.n_events,
            "n_requests": len(self.requests),
            "n_batches": len(self.batches),
            "n_truncated": sum(1 for r in self.requests if r.truncated),
            "counts": dict(self.counts),
            "outcomes": outcomes,
            "flush_reasons": reasons,
            "phases_us": self.phase_summary(),
            "kernel_us_total": kernel,
            "lanes": self.lane_summary(),
            "reconciliation": self.reconciliation(),
        }


def _arg(ev: TraceEvent, key: str):
    return (ev.args or {}).get(key)


def analyze_events(events: Sequence[TraceEvent],
                   tol: float = DEFAULT_TOL) -> TraceReport:
    """Rebuild requests/batches from events in buffer order."""
    reqs: Dict[int, RequestRecord] = {}
    batches: List[BatchRecord] = []
    pending: List[int] = []         # queue_wait-closed, awaiting batch_form
    current: Optional[BatchRecord] = None
    counts = {"rejects": 0, "failovers": 0, "orphan_ends": 0}

    def req(sid: int) -> RequestRecord:
        r = reqs.get(sid)
        if r is None:
            # end without begin: head of the lifecycle fell off the ring
            r = reqs[sid] = RequestRecord(sid=sid, truncated=True)
        return r

    for ev in events:
        if ev.ph == "b" and ev.name == "request":
            r = reqs.get(ev.scope_id)
            if r is None:
                r = reqs[ev.scope_id] = RequestRecord(sid=ev.scope_id)
            r.t_begin_us = ev.ts_us
            r.lane = _arg(ev, "lane")
            r.rows = _arg(ev, "rows") or 1
            r.deadline_us = _arg(ev, "deadline_us")
        elif ev.ph == "e" and ev.name == "queue_wait":
            if ev.scope_id not in reqs:
                counts["orphan_ends"] += 1
            r = req(ev.scope_id)
            r.flush_reason = _arg(ev, "flush_reason")
            w = _arg(ev, "wait_us")
            if w is not None:
                r.wait_us = float(w)
            elif r.t_begin_us is not None:
                r.wait_us = ev.ts_us - r.t_begin_us
            # drain-flushed requests do ride a batch (stop(drain=True));
            # only sheds never reach batch_form. Shutdown leftovers also
            # tag "drain" with no batch — membership is undone at their
            # request end below.
            if r.flush_reason != "shed":
                pending.append(ev.scope_id)
        elif ev.ph == "e" and ev.name == "request":
            if ev.scope_id not in reqs:
                counts["orphan_ends"] += 1
            r = req(ev.scope_id)
            r.t_end_us = ev.ts_us
            r.outcome = _arg(ev, "outcome")
            lat = _arg(ev, "latency_us")
            if lat is not None:
                r.latency_us = float(lat)
            elif r.t_begin_us is not None:
                r.latency_us = ev.ts_us - r.t_begin_us
            if r.outcome in ("shed", "shutdown"):
                r.batch = None      # never dispatched
                if ev.scope_id in pending:
                    pending.remove(ev.scope_id)
        elif ev.ph == "X":
            if ev.name == "batch_form":
                current = BatchRecord(
                    idx=len(batches),
                    flush_reason=_arg(ev, "flush_reason") or "",
                    rows=_arg(ev, "rows") or 0,
                    n_requests=_arg(ev, "n_requests") or 0,
                    form_us=ev.dur_us, members=pending)
                for sid in pending:
                    reqs[sid].batch = current
                pending = []
                batches.append(current)
            elif current is not None and ev.name == "aggregate_pack":
                current.pack_us += ev.dur_us
            elif current is not None and ev.name == "device_exec":
                current.device_us += ev.dur_us
            elif current is not None and ev.name == "exec" \
                    and ev.cat == "exec":
                current.exec_us += ev.dur_us
            elif current is not None and ev.name == "scatter":
                current.scatter_us += ev.dur_us
            elif current is not None and ev.cat == "kernel":
                current.kernel_us += ev.dur_us
        elif ev.ph == "i":
            if ev.name == "reject":
                counts["rejects"] += 1
            elif ev.name == "replica_failover":
                counts["failovers"] += 1

    return TraceReport(list(reqs.values()), batches, len(events),
                       counts, tol=tol)


def analyze_trace(path: str, tol: float = DEFAULT_TOL) -> TraceReport:
    """Load a Chrome-trace/JSONL artifact and analyze it."""
    from .export import load_trace_events
    return analyze_events(load_trace_events(path), tol=tol)


# ---------------------------------------------------------------------------
# Rendering + diff
# ---------------------------------------------------------------------------

def format_report(rep: TraceReport) -> str:
    d = rep.to_dict()
    lines = [
        f"trace: {d['n_events']} events, {d['n_requests']} requests, "
        f"{d['n_batches']} batches"
        + (f", {d['n_truncated']} truncated lifecycles"
           if d["n_truncated"] else ""),
        "outcomes: " + (", ".join(
            f"{k}={v}" for k, v in sorted(d["outcomes"].items())) or "none"),
        "flush reasons: " + (", ".join(
            f"{k}={v}" for k, v in sorted(d["flush_reasons"].items()))
            or "none"),
    ]
    if d["counts"]["rejects"] or d["counts"]["failovers"]:
        lines.append(f"admission rejects: {d['counts']['rejects']}, "
                     f"replica failovers: {d['counts']['failovers']}")
    ph = d["phases_us"]
    if ph:
        lines.append("")
        lines.append("where did the time go (request-weighted, µs):")
        lines.append(f"  {'phase':<14}{'share':>7}{'mean':>12}"
                     f"{'p50':>12}{'p99':>12}{'total':>14}")
        for p in ALL_PHASES:
            if p not in ph:
                continue
            s = ph[p]
            share = (f"{100 * s['share']:.1f}%"
                     if p not in ("scatter",) else "post")
            lines.append(
                f"  {p:<14}{share:>7}{s['mean_us']:>12.1f}"
                f"{s['p50_us']:>12.1f}{s['p99_us']:>12.1f}"
                f"{s['total_us']:>14.1f}")
    if d["lanes"]:
        lines.append("")
        lines.append("per-lane latency (µs):")
        for lane, s in d["lanes"].items():
            lines.append(f"  lane {lane}: n={s['n']} shed={s['n_shed']} "
                         f"p50={s['p50_us']:.1f} p99={s['p99_us']:.1f}")
    rec = d["reconciliation"]
    lines.append("")
    if rec["n_checked"]:
        lines.append(
            f"reconciliation: {rec['n_checked']} requests checked, "
            f"mean err {100 * rec['mean_rel_err']:.2f}%, max "
            f"{100 * rec['max_rel_err']:.2f}%, "
            f"{rec['n_over_tol']}/{rec['n_allowed']} straggler(s) "
            f"({'OK' if rec['ok'] else 'OVER TOLERANCE'} at "
            f"{100 * rec['tol']:.0f}%)")
    else:
        lines.append("reconciliation: no completed requests to check")
    return "\n".join(lines)


def diff_reports(new: TraceReport, old: TraceReport) -> Dict:
    """Phase-level regression attribution between two traces: which
    phase's mean moved, by how much, and in which direction."""
    a, b = new.phase_summary(), old.phase_summary()
    out: Dict = {"phases": {}, "n_requests": {
        "new": len(new.requests), "old": len(old.requests)}}
    for p in ALL_PHASES:
        if p not in a or p not in b:
            continue
        mn, mo = a[p]["mean_us"], b[p]["mean_us"]
        delta = mn - mo
        pct = (delta / mo * 100.0) if mo > 0 else (math.inf if delta > 0
                                                   else 0.0)
        out["phases"][p] = {
            "new_mean_us": mn, "old_mean_us": mo,
            "delta_us": delta, "delta_pct": pct,
            "direction": ("regressed" if delta > 0 else
                          "improved" if delta < 0 else "flat")}
    worst = max(out["phases"].items(),
                key=lambda kv: kv[1]["delta_us"], default=None)
    out["attribution"] = (worst[0] if worst and worst[1]["delta_us"] > 0
                          else None)
    return out


def format_diff(d: Dict) -> str:
    lines = [f"trace diff (new {d['n_requests']['new']} vs old "
             f"{d['n_requests']['old']} requests):",
             f"  {'phase':<14}{'old mean':>12}{'new mean':>12}"
             f"{'delta':>12}{'change':>10}"]
    for p in ALL_PHASES:
        if p not in d["phases"]:
            continue
        s = d["phases"][p]
        pct = ("+inf" if math.isinf(s["delta_pct"])
               else f"{s['delta_pct']:+.1f}%")
        lines.append(f"  {p:<14}{s['old_mean_us']:>12.1f}"
                     f"{s['new_mean_us']:>12.1f}{s['delta_us']:>+12.1f}"
                     f"{pct:>10}")
    if d["attribution"]:
        lines.append(f"largest regression: {d['attribution']}")
    else:
        lines.append("no phase regressed")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.analyze",
        description="Per-request phase breakdown from a serve trace "
                    "(Chrome-trace JSON or JSONL)")
    ap.add_argument("--trace", required=True,
                    help="trace artifact from --trace on launch.serve "
                         "or benchmarks/loadgen.py")
    ap.add_argument("--diff", default=None, metavar="OLD_TRACE",
                    help="also diff against an older trace for "
                         "regression attribution")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="reconciliation tolerance (default 0.05)")
    args = ap.parse_args(argv)

    rep = analyze_trace(args.trace, tol=args.tol)
    if args.diff:
        d = diff_reports(rep, analyze_trace(args.diff, tol=args.tol))
        print(json.dumps({"report": rep.to_dict(), "diff": d}, indent=2)
              if args.json else
              format_report(rep) + "\n\n" + format_diff(d))
    else:
        print(json.dumps(rep.to_dict(), indent=2) if args.json
              else format_report(rep))
    return 0 if rep.reconciliation()["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
