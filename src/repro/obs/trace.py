"""Thread-safe span tracer for the request lifecycle.

Two event families, mirroring the Chrome trace-event model so export is
a straight mapping:

  * **thread spans** (``ph="X"``) — work done start-to-finish on one
    thread: batch formation, aggregate pack, device exec, scatter.
    Nested calls on the same thread nest in Perfetto by time
    containment, so the aggregator's ``pack``/``device_exec`` spans
    render inside the scheduler's ``exec`` span with no extra plumbing.
  * **async spans** (``ph="b"/"n"/"e"``) — one per *request*, keyed by
    a tracer-allocated id threaded through ``ServeRequest``/
    ``ServeFuture``: begun retroactively at the request's enqueue
    timestamp when the scheduler first touches it (dispatch / shed /
    drain — the submit fast path records nothing but the id), ended at
    complete/shed/error. Async spans cross threads — enqueue time is
    stamped on the client thread, all recording happens scheduler-side
    — which is exactly what thread spans cannot express.

Storage is a lock-free ring: events are plain tuples appended to a
``deque(maxlen=capacity)`` and counted with ``itertools.count`` — both
single C calls, atomic under the GIL — so concurrent recorders never
serialize on a mutex and old events fall off the ring (``n_dropped``
counts them). ``TraceEvent`` objects are only materialized on the cold
``events()`` read path. The clock is injectable (``FakeClock`` in
tests); when the tracer is disabled — or the shared ``NULL_TRACER`` is
in use — every record call is a single attribute check, so the serving
hot path pays ~nothing for the instrumentation points it carries.
"""
from __future__ import annotations

import threading
from collections import deque
from itertools import count as _monotonic_count
from threading import get_ident
from typing import Dict, List, NamedTuple, Optional, Tuple

# batch flush reasons annotated on batch-formation events; the trace
# validation pass (repro.check --passes trace) rejects anything else
FLUSH_REASONS = ("size", "deadline", "max_wait", "drain", "shed")


class TraceEvent(NamedTuple):
    """One trace record (all times µs, from the tracer's clock).

    ``ph`` is the Chrome trace-event phase: ``X`` complete thread span
    (``dur_us`` set), ``b``/``n``/``e`` async begin/instant/end (keyed
    by ``scope_id``), ``i`` global instant.
    """

    ph: str
    name: str
    cat: str
    ts_us: float
    dur_us: float
    tid: int
    scope_id: Optional[int]
    args: Optional[Dict[str, object]]


class _Span:
    """Context manager recording one thread span on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, cat: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._now()
        return self

    def __exit__(self, *exc) -> None:
        # inlined tracer.complete(): X spans fire per batch phase on
        # the scheduler thread, so every frame saved is throughput
        tr = self._tracer
        next(tr._n)
        tr._buf.append(("X", self._name, self._cat, self._t0,
                        tr._now() - self._t0, get_ident(), None,
                        self._args))


class _NullSpan:
    """Shared no-op context manager for the disabled paths."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class SpanTracer:
    """Ring-buffer span recorder with an injectable clock.

    ``capacity`` bounds memory: the buffer holds the most recent
    ``capacity`` events and ``n_dropped`` counts overwrites. All
    recording methods are thread-safe; ids from ``new_id`` are unique
    per tracer and are what requests carry across threads.
    """

    def __init__(self, clock=None, capacity: int = 1 << 16,
                 enabled: bool = True):
        if clock is None:
            from repro.serve.clock import SystemClock
            clock = SystemClock()
        assert capacity >= 1
        self.clock = clock
        self.enabled = enabled
        self._cap = capacity
        # the hot path is lock-free: deque.append with a maxlen and
        # next() on an itertools.count are both single C calls, atomic
        # under the GIL, so 64 submitter threads recording concurrently
        # never serialize on a mutex. Events are stored as plain tuples
        # and only materialized into TraceEvent on the cold read path.
        self._buf: deque = deque(maxlen=capacity)
        self._n = _monotonic_count()    # total events ever recorded
        self._now = clock.now_us
        self._ids = _monotonic_count(1)
        self._lock = threading.Lock()   # clear only, never the hot path

    # -- ids / time --------------------------------------------------------
    def now_us(self) -> float:
        return self._now()

    def new_id(self) -> int:
        return next(self._ids)

    @property
    def n_recorded(self) -> int:
        # itertools.count exposes its next value through __reduce__;
        # reading it there peeks the total without consuming a tick
        return self._n.__reduce__()[1][0]

    @property
    def n_dropped(self) -> int:
        return max(0, self.n_recorded - self._cap)

    # -- recording ---------------------------------------------------------
    def complete(self, name: str, t0_us: float, t1_us: float,
                 cat: str = "sched", args: Optional[dict] = None) -> None:
        """A finished thread span with explicit endpoints (for spans
        whose start was stamped on another code path)."""
        if not self.enabled:
            return
        next(self._n)
        self._buf.append(("X", name, cat, t0_us, t1_us - t0_us,
                          get_ident(), None, args))

    def span(self, name: str, cat: str = "sched",
             args: Optional[dict] = None):
        """``with tracer.span("exec"): ...`` — times the block on the
        current thread."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "sched",
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        next(self._n)
        self._buf.append(("i", name, cat, self._now(), 0.0,
                          get_ident(), None, args))

    def abegin(self, name: str, scope_id: int, cat: str = "request",
               args: Optional[dict] = None,
               ts_us: Optional[float] = None) -> None:
        """Begin the async span ``scope_id`` (one per request)."""
        if not self.enabled:
            return
        next(self._n)
        self._buf.append(
            ("b", name, cat, self._now() if ts_us is None else ts_us,
             0.0, get_ident(), scope_id, args))

    def abegin_nested(self, outer: str, inner: str, scope_id: int,
                      ts_us: float, args: Optional[dict] = None) -> None:
        """Open an outer async span and an inner phase span at the same
        timestamp with one method dispatch — the submit-path fast path
        (``request`` + ``queue_wait``); ``args`` lands on the outer."""
        if not self.enabled:
            return
        next(self._n)
        next(self._n)
        tid = get_ident()
        self._buf.append(("b", outer, "request", ts_us, 0.0, tid,
                          scope_id, args))
        self._buf.append(("b", inner, "request", ts_us, 0.0, tid,
                          scope_id, None))

    def ainstant(self, name: str, scope_id: int, cat: str = "request",
                 args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        next(self._n)
        self._buf.append(("n", name, cat, self._now(), 0.0,
                          get_ident(), scope_id, args))

    def aend(self, name: str, scope_id: int, cat: str = "request",
             args: Optional[dict] = None,
             ts_us: Optional[float] = None) -> None:
        if not self.enabled:
            return
        next(self._n)
        self._buf.append(("e", name, cat,
                          self._now() if ts_us is None else ts_us, 0.0,
                          get_ident(), scope_id, args))

    # -- reading -----------------------------------------------------------
    def events(self) -> List[TraceEvent]:
        """Snapshot of the retained events in recording order."""
        # list(deque) is one atomic C call; the maxlen ring keeps
        # oldest-to-newest order by construction
        return [TraceEvent(*t) for t in list(self._buf)]

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._n = _monotonic_count()


class NullTracer:
    """Disabled tracer: same surface as ``SpanTracer``, every call a
    no-op. The scheduler default, so untraced serving carries only an
    ``if tracer.enabled`` per instrumentation point."""

    enabled = False
    clock = None

    def now_us(self) -> float:
        return 0.0

    def new_id(self) -> int:
        return 0

    @property
    def n_recorded(self) -> int:
        return 0

    @property
    def n_dropped(self) -> int:
        return 0

    def complete(self, name, t0_us, t1_us, cat="sched", args=None) -> None:
        pass

    def span(self, name, cat="sched", args=None):
        return _NULL_SPAN

    def instant(self, name, cat="sched", args=None) -> None:
        pass

    def abegin(self, name, scope_id, cat="request", args=None,
               ts_us=None) -> None:
        pass

    def abegin_nested(self, outer, inner, scope_id, ts_us,
                      args=None) -> None:
        pass

    def ainstant(self, name, scope_id, cat="request", args=None) -> None:
        pass

    def aend(self, name, scope_id, cat="request", args=None,
             ts_us=None) -> None:
        pass

    def events(self) -> List[TraceEvent]:
        return []

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()
