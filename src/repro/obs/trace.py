"""Thread-safe span tracer for the request lifecycle.

Two event families, mirroring the Chrome trace-event model so export is
a straight mapping:

  * **thread spans** (``ph="X"``) — work done start-to-finish on one
    thread: batch formation, aggregate pack, device exec, scatter.
    Nested calls on the same thread nest in Perfetto by time
    containment, so the aggregator's ``pack``/``device_exec`` spans
    render inside the scheduler's ``exec`` span with no extra plumbing.
  * **async spans** (``ph="b"/"n"/"e"``) — one per *request*, keyed by
    a tracer-allocated id threaded through ``ServeRequest``/
    ``ServeFuture``: begin at submit, instants for queue/batch
    milestones (the batch-formation instant carries the flush reason),
    end at complete/shed/error. Async spans cross threads — submit
    happens on the client thread, completion on the scheduler thread —
    which is exactly what thread spans cannot express.

Storage is a preallocated ring buffer: recording is one tuple build and
one slot write under a lock, old events are overwritten (``n_dropped``
counts them), and nothing allocates proportional to trace length until
``events()`` is called. The clock is injectable (``FakeClock`` in
tests); when the tracer is disabled — or the shared ``NULL_TRACER`` is
in use — every record call is a single attribute check, so the serving
hot path pays ~nothing for the instrumentation points it carries.
"""
from __future__ import annotations

import threading
from typing import Dict, List, NamedTuple, Optional, Tuple

# batch flush reasons annotated on batch-formation events; the trace
# validation pass (repro.check --passes trace) rejects anything else
FLUSH_REASONS = ("size", "deadline", "max_wait", "drain", "shed")


class TraceEvent(NamedTuple):
    """One trace record (all times µs, from the tracer's clock).

    ``ph`` is the Chrome trace-event phase: ``X`` complete thread span
    (``dur_us`` set), ``b``/``n``/``e`` async begin/instant/end (keyed
    by ``scope_id``), ``i`` global instant.
    """

    ph: str
    name: str
    cat: str
    ts_us: float
    dur_us: float
    tid: int
    scope_id: Optional[int]
    args: Optional[Dict[str, object]]


class _Span:
    """Context manager recording one thread span on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str, cat: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.now_us()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer.complete(self._name, self._t0, self._tracer.now_us(),
                              cat=self._cat, args=self._args)


class _NullSpan:
    """Shared no-op context manager for the disabled paths."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class SpanTracer:
    """Ring-buffer span recorder with an injectable clock.

    ``capacity`` bounds memory: the buffer holds the most recent
    ``capacity`` events and ``n_dropped`` counts overwrites. All
    recording methods are thread-safe; ids from ``new_id`` are unique
    per tracer and are what requests carry across threads.
    """

    def __init__(self, clock=None, capacity: int = 1 << 16,
                 enabled: bool = True):
        if clock is None:
            from repro.serve.clock import SystemClock
            clock = SystemClock()
        assert capacity >= 1
        self.clock = clock
        self.enabled = enabled
        self._cap = capacity
        self._buf: List[Optional[TraceEvent]] = [None] * capacity
        self._head = 0              # next write slot
        self._count = 0             # total events ever recorded
        self._next_id = 0
        self._lock = threading.Lock()

    # -- ids / time --------------------------------------------------------
    def now_us(self) -> float:
        return self.clock.now_us()

    def new_id(self) -> int:
        with self._lock:
            self._next_id += 1
            return self._next_id

    @property
    def n_recorded(self) -> int:
        return self._count

    @property
    def n_dropped(self) -> int:
        return max(0, self._count - self._cap)

    # -- recording ---------------------------------------------------------
    def _record(self, ev: TraceEvent) -> None:
        with self._lock:
            self._buf[self._head] = ev
            self._head = (self._head + 1) % self._cap
            self._count += 1

    def complete(self, name: str, t0_us: float, t1_us: float,
                 cat: str = "sched", args: Optional[dict] = None) -> None:
        """A finished thread span with explicit endpoints (for spans
        whose start was stamped on another code path)."""
        if not self.enabled:
            return
        self._record(TraceEvent("X", name, cat, t0_us, t1_us - t0_us,
                                threading.get_ident(), None, args))

    def span(self, name: str, cat: str = "sched",
             args: Optional[dict] = None):
        """``with tracer.span("exec"): ...`` — times the block on the
        current thread."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "sched",
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._record(TraceEvent("i", name, cat, self.now_us(), 0.0,
                                threading.get_ident(), None, args))

    def abegin(self, name: str, scope_id: int, cat: str = "request",
               args: Optional[dict] = None,
               ts_us: Optional[float] = None) -> None:
        """Begin the async span ``scope_id`` (one per request)."""
        if not self.enabled:
            return
        self._record(TraceEvent(
            "b", name, cat, self.now_us() if ts_us is None else ts_us,
            0.0, threading.get_ident(), scope_id, args))

    def ainstant(self, name: str, scope_id: int, cat: str = "request",
                 args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._record(TraceEvent("n", name, cat, self.now_us(), 0.0,
                                threading.get_ident(), scope_id, args))

    def aend(self, name: str, scope_id: int, cat: str = "request",
             args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._record(TraceEvent("e", name, cat, self.now_us(), 0.0,
                                threading.get_ident(), scope_id, args))

    # -- reading -----------------------------------------------------------
    def events(self) -> List[TraceEvent]:
        """Snapshot of the retained events in recording order."""
        with self._lock:
            if self._count <= self._cap:
                raw = self._buf[: self._head]
            else:
                raw = self._buf[self._head:] + self._buf[: self._head]
        return [e for e in raw if e is not None]

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self._cap
            self._head = 0
            self._count = 0


class NullTracer:
    """Disabled tracer: same surface as ``SpanTracer``, every call a
    no-op. The scheduler default, so untraced serving carries only an
    ``if tracer.enabled`` per instrumentation point."""

    enabled = False
    clock = None

    def now_us(self) -> float:
        return 0.0

    def new_id(self) -> int:
        return 0

    @property
    def n_recorded(self) -> int:
        return 0

    @property
    def n_dropped(self) -> int:
        return 0

    def complete(self, name, t0_us, t1_us, cat="sched", args=None) -> None:
        pass

    def span(self, name, cat="sched", args=None):
        return _NULL_SPAN

    def instant(self, name, cat="sched", args=None) -> None:
        pass

    def abegin(self, name, scope_id, cat="request", args=None,
               ts_us=None) -> None:
        pass

    def ainstant(self, name, scope_id, cat="request", args=None) -> None:
        pass

    def aend(self, name, scope_id, cat="request", args=None) -> None:
        pass

    def events(self) -> List[TraceEvent]:
        return []

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()
