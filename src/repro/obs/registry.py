"""Unified metrics registry: counters, gauges, histograms, providers.

Before this module every stats surface in the repo was its own island:
``ServeMetrics.snapshot()``, ``ReplicaSet.stats()``, the
``BitplaneAggregator`` occupancy counters. The registry gives them one
roof — components either allocate typed instruments (``counter`` /
``gauge`` / ``histogram``) or register a zero-argument *provider*
callable whose dict is evaluated lazily at ``snapshot()`` time (the
natural fit for objects that already maintain their own locked state).
One ``snapshot()`` call returns everything, which is what benchmark
JSON writers, the launcher's shutdown report, and trace ``otherData``
embed.

Instrument updates are lock-protected and cheap; ``snapshot()`` is the
only place provider callables run, so registering a provider adds zero
steady-state cost to the hot path.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

from repro.serve.metrics import LatencyHistogram


class Counter:
    """Monotonic counter (thread-safe)."""

    def __init__(self):
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v


class Gauge:
    """Last-value gauge; either set explicitly or backed by a callable
    evaluated at snapshot time."""

    def __init__(self, fn: Optional[Callable[[], float]] = None):
        self._v = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        self._v = float(v)

    @property
    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        return self._v


class MetricsRegistry:
    """Get-or-create instrument registry with one snapshot surface.

    Names are dotted paths by convention (``sched.completed``,
    ``replicas.0.ewma_us``); providers publish a whole nested dict
    under their name. Re-requesting an existing name returns the same
    instrument, so publishers never need to coordinate creation order.
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, LatencyHistogram] = {}
        self._providers: Dict[str, Callable[[], Dict]] = {}
        self._lock = threading.Lock()

    # -- instruments -------------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            if name not in self._counters:
                self._counters[name] = Counter()
            return self._counters[name]

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        with self._lock:
            if name not in self._gauges:
                self._gauges[name] = Gauge(fn)
            elif fn is not None:
                self._gauges[name]._fn = fn
            return self._gauges[name]

    def histogram(self, name: str,
                  max_samples: int = 200_000) -> LatencyHistogram:
        with self._lock:
            if name not in self._hists:
                self._hists[name] = LatencyHistogram(max_samples)
            return self._hists[name]

    def register(self, name: str, provider: Callable[[], Dict]) -> None:
        """Publish a component's own stats dict under ``name``; the
        callable runs at every ``snapshot()``."""
        with self._lock:
            self._providers[name] = provider

    # -- reporting ---------------------------------------------------------
    def snapshot(self) -> Dict:
        """Everything, in one dict:

        ``{"counters": {...}, "gauges": {...}, "histograms":
        {name: {n, mean_us, p50_us, p95_us, p99_us, buckets}},
        <provider name>: <provider dict>, ...}``
        """
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
            providers = dict(self._providers)
        out: Dict = {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {
                k: {"n": h.n, "mean_us": h.mean(),
                    "p50_us": h.percentile(50), "p95_us": h.percentile(95),
                    "p99_us": h.percentile(99), "buckets": h.buckets()}
                for k, h in sorted(hists.items())},
        }
        for name, fn in sorted(providers.items()):
            out[name] = fn()
        return out
