"""Prometheus text-exposition export + a stdlib pull endpoint.

``MetricsRegistry.snapshot()`` is a nested dict built for JSON
artifacts; a fleet monitor wants the flat
`name{label="..."} value` lines of the Prometheus text exposition
format (version 0.0.4) on a scrape port. This module provides both
halves with **zero new dependencies**:

  * ``to_prometheus_text(snapshot)`` — flatten a registry snapshot into
    exposition lines: counters → ``counter``, gauges → ``gauge``,
    histograms → mean/percentile gauges plus a cumulative
    ``_bucket{le=...}`` series, provider dicts → gauges with their
    nested path as the metric name and non-numeric leaves skipped;
  * ``MetricsServer(registry, port)`` — a ``ThreadingHTTPServer``
    serving ``/metrics`` (exposition text) and ``/metrics.json`` (the
    raw snapshot), started on a daemon thread.
    ``launch.serve --metrics-port`` wires it up.

Metric names are sanitized to ``[a-zA-Z0-9_:]`` with a ``repro_``
prefix; µs values keep their ``_us`` suffix rather than being rescaled
— honest units over convention.
"""
from __future__ import annotations

import json
import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Tuple

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LEADING_RE = re.compile(r"^[^a-zA-Z_:]+")


def _metric_name(*parts: str) -> str:
    flat = "_".join(str(p) for p in parts if p != "")
    name = _NAME_RE.sub("_", flat)
    name = _LEADING_RE.sub("", name) or "metric"
    return f"repro_{name}"


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(float(v))


def _fmt(v) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _flatten(prefix: Tuple[str, ...], obj,
             out: List[Tuple[str, float]]) -> None:
    """Provider dicts -> (dotted-path, value) leaves; non-numeric leaves
    (engine names, booleans-as-flags keep 0/1) are dropped."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(prefix + (str(k),), v, out)
    elif isinstance(obj, bool):
        out.append((_metric_name(*prefix), 1.0 if obj else 0.0))
    elif _is_num(obj):
        out.append((_metric_name(*prefix), float(obj)))


def to_prometheus_text(snapshot: Dict) -> str:
    """Registry snapshot dict -> Prometheus text exposition format."""
    lines: List[str] = []

    def emit(name: str, value, mtype: Optional[str] = None,
             labels: str = "") -> None:
        if mtype is not None:
            lines.append(f"# TYPE {name} {mtype}")
        lines.append(f"{name}{labels} {_fmt(value)}")

    for key, v in sorted((snapshot.get("counters") or {}).items()):
        emit(_metric_name(key, "total"), v, "counter")
    for key, v in sorted((snapshot.get("gauges") or {}).items()):
        if _is_num(v):
            emit(_metric_name(key), v, "gauge")
    for key, h in sorted((snapshot.get("histograms") or {}).items()):
        base = _metric_name(key)
        emit(f"{base}_count", h.get("n", 0), "gauge")
        for stat in ("mean_us", "p50_us", "p95_us", "p99_us"):
            if _is_num(h.get(stat)):
                emit(f"{base}_{stat}", h[stat], "gauge")
        buckets = h.get("buckets") or {}
        if buckets:
            # cumulative le-series from the registry's sparse log
            # buckets (edges are their lower bound, label keeps the
            # registry's own "<edge>us" spelling)
            lines.append(f"# TYPE {base}_bucket gauge")
            cum = 0
            for edge, n in buckets.items():
                cum += int(n)
                lines.append(f'{base}_bucket{{le="{edge}"}} {cum}')
    reserved = ("counters", "gauges", "histograms")
    flat: List[Tuple[str, float]] = []
    for key, sub in sorted(snapshot.items()):
        if key in reserved:
            continue
        _flatten((key,), sub, flat)
    for name, v in flat:
        emit(name, v, "gauge")
    return "\n".join(lines) + ("\n" if lines else "")


class MetricsServer:
    """Pull endpoint for one ``MetricsRegistry`` (stdlib http.server).

    ``GET /metrics`` returns the exposition text, ``GET /metrics.json``
    the raw snapshot. The server thread is a daemon; ``close()`` shuts
    it down deterministically (tests), process exit reaps it otherwise.
    """

    def __init__(self, registry, port: int = 0, host: str = "127.0.0.1"):
        self.registry = registry
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                           # noqa: N802
                try:
                    if self.path.startswith("/metrics.json"):
                        body = json.dumps(
                            server.registry.snapshot()).encode()
                        ctype = "application/json"
                    elif self.path.startswith("/metrics"):
                        body = to_prometheus_text(
                            server.registry.snapshot()).encode()
                        ctype = ("text/plain; version=0.0.4; "
                                 "charset=utf-8")
                    else:
                        self.send_error(404, "try /metrics")
                        return
                except Exception as e:      # scrape must not kill serving
                    self.send_error(500, type(e).__name__)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):   # scrapes are not log news
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name=f"metrics-server:{self.port}")
        self._thread.start()

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
