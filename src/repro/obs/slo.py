"""Multi-window SLO burn-rate monitoring with degradation alerts.

The per-lane SLO tables (PR 5) make individual requests deadline-aware;
nothing yet watches the *rate* at which a lane is spending its error
budget. This module implements the standard SRE multi-window burn-rate
rule:

  * a lane's **error budget** is ``1 - slo_target`` (target 0.99 →
    budget 1% of deadline-carrying requests may miss or be shed);
  * the **burn rate** over a window is ``miss_fraction / budget`` —
    burn 1.0 spends the budget exactly at the sustainable rate, burn
    10 spends a day of budget in ~2.4 hours;
  * an alert **fires** only when both a long and a short window exceed
    the threshold — the long window proves the problem is real (not one
    bad batch), the short window proves it is *still happening* (fast
    reset once the cause clears);
  * the alert **clears** with hysteresis when the short-window burn
    drops below ``clear_threshold`` — flapping between degraded and
    normal admission would shed in bursts, the worst of both modes.

``BurnRateMonitor`` is a ``ServeMetrics`` sink (same push protocol as
``WindowedMetrics``) built on the same tumbling ``BucketRing``; it is
scheduler-agnostic — ``check(now_us)`` evaluates the rule and invokes
registered alert callbacks. ``MicroBatchScheduler(slo_monitor=...)``
wires it as the degradation hook: while any lane's alert is active, the
scheduler sheds the *loosest* lane (largest SLO budget — the traffic
whose latency promise costs least to break) at admission with a typed
``RequestRejected(DEGRADED)``, freeing capacity for the lanes that are
burning.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Tuple

from .window import BucketRing


@dataclasses.dataclass(frozen=True)
class BurnAlert:
    """One alert transition (``kind`` = ``"fire"`` or ``"clear"``)."""

    kind: str
    lane: int
    burn_long: float
    burn_short: float
    threshold: float
    now_us: float

    def __str__(self) -> str:
        return (f"[slo] {self.kind}: lane {self.lane} burn "
                f"long={self.burn_long:.1f}x short={self.burn_short:.1f}x "
                f"(threshold {self.threshold:.1f}x) at t={self.now_us:.0f}us")


class BurnRateMonitor:
    """Per-lane multi-window burn-rate evaluation over pushed events.

    Parameters
    ----------
    slo_target:
        Attainment objective in (0, 1); the error budget is its
        complement.
    long_window_us / short_window_us:
        The two evaluation windows; both must exceed ``threshold``
        burn for an alert to fire.
    threshold:
        Burn-rate multiple that fires the alert.
    clear_threshold:
        Short-window burn below which an active alert clears
        (hysteresis; must be <= threshold).
    min_events:
        Minimum deadline-carrying events in the long window before the
        rule is evaluated — two misses out of three requests is noise,
        not a burn.
    """

    _GUARDED_BY = {"_lanes": "_lock", "_active": "_lock",
                   "_history": "_lock"}
    # callbacks are registered during wiring, before traffic flows, and
    # only appended — check() iterates a list that never shrinks, so
    # the list itself needs no lock (callbacks run outside it anyway)
    _LOCK_FREE = ("_callbacks",)

    def __init__(self, slo_target: float = 0.99,
                 long_window_us: float = 60_000_000.0,
                 short_window_us: float = 5_000_000.0,
                 threshold: float = 10.0,
                 clear_threshold: float = 1.0,
                 min_events: int = 20,
                 clock=None):
        if not 0.0 < slo_target < 1.0:
            raise ValueError(f"slo_target must be in (0, 1), "
                             f"got {slo_target}")
        if short_window_us >= long_window_us:
            raise ValueError("short window must be shorter than long "
                             f"({short_window_us} >= {long_window_us})")
        if clear_threshold > threshold:
            raise ValueError("clear_threshold above threshold would "
                             "re-fire immediately after every clear")
        self.slo_target = float(slo_target)
        self.budget = 1.0 - self.slo_target
        self.long_window_us = float(long_window_us)
        self.short_window_us = float(short_window_us)
        self.threshold = float(threshold)
        self.clear_threshold = float(clear_threshold)
        self.min_events = int(min_events)
        self.clock = clock
        # bucket the long window into short-window-sized cells so the
        # short view is exact and the long view is a cheap merge
        n = max(2, int(long_window_us // short_window_us) + 2)
        self._mk_ring = lambda: BucketRing(short_window_us, n_windows=n)
        self._lanes: Dict[int, BucketRing] = {}
        self._active: Dict[int, BurnAlert] = {}
        self._history: List[BurnAlert] = []
        self._callbacks: List[Callable[[BurnAlert], None]] = []
        self._lock = threading.Lock()

    # -- wiring ------------------------------------------------------------
    def on_alert(self, cb: Callable[[BurnAlert], None]) -> None:
        """Register a callback invoked on every fire/clear transition.

        Callbacks run inside ``check()`` on the calling thread (the
        scheduler may hold its lock there) — keep them fast and never
        call back into the scheduler from one."""
        self._callbacks.append(cb)

    def _lane(self, lane: int) -> BucketRing:
        with self._lock:
            ring = self._lanes.get(lane)
            if ring is None:
                ring = self._lanes[lane] = self._mk_ring()
            return ring

    # -- sink protocol (pushed by ServeMetrics) ----------------------------
    def record_done(self, lane: int, latency_us: float, now_us: float,
                    ok: bool = True, deadline_us: Optional[float] = None,
                    **_kw) -> None:
        # deadline-free traffic has no budget to burn: skip it so one
        # best-effort lane cannot dilute a burning SLO lane's rate
        if deadline_us is None:
            return
        self._lane(lane).add_done(now_us, latency_us, ok)

    def record_shed(self, lane: int, now_us: float, **_kw) -> None:
        self._lane(lane).add_shed(now_us)

    # -- evaluation --------------------------------------------------------
    def burn_rate(self, lane: int, window_us: float,
                  now_us: float) -> Tuple[float, int]:
        """(burn multiple, deadline-carrying events) over the trailing
        window; burn is 0 when the window carried no such traffic."""
        b = self._lane(lane).merged(now_us, window_us)
        n = b.n_ok + b.n_miss + b.n_shed
        if n == 0:
            return 0.0, 0
        return ((b.n_miss + b.n_shed) / n) / self.budget, n

    def check(self, now_us: Optional[float] = None) -> List[BurnAlert]:
        """Evaluate the multi-window rule for every lane seen so far;
        returns the alert *transitions* (fires and clears) this call
        produced, after invoking the registered callbacks on each."""
        if now_us is None:
            if self.clock is None:
                raise ValueError("check() needs now_us (no clock bound)")
            now_us = self.clock.now_us()
        with self._lock:
            lanes = list(self._lanes)
        out: List[BurnAlert] = []
        for lane in lanes:
            burn_long, n_long = self.burn_rate(lane, self.long_window_us,
                                               now_us)
            burn_short, _ = self.burn_rate(lane, self.short_window_us,
                                           now_us)
            with self._lock:
                active = lane in self._active
                if (not active and n_long >= self.min_events
                        and burn_long > self.threshold
                        and burn_short > self.threshold):
                    alert = BurnAlert("fire", lane, burn_long, burn_short,
                                      self.threshold, now_us)
                    self._active[lane] = alert
                elif active and burn_short < self.clear_threshold:
                    alert = BurnAlert("clear", lane, burn_long, burn_short,
                                      self.threshold, now_us)
                    del self._active[lane]
                else:
                    continue
                self._history.append(alert)
            out.append(alert)
            for cb in self._callbacks:
                cb(alert)
        return out

    def alerting_lanes(self) -> List[int]:
        """Lanes with an active (fired, not yet cleared) alert."""
        with self._lock:
            return sorted(self._active)

    def history(self) -> List[BurnAlert]:
        with self._lock:
            return list(self._history)

    # -- reporting ---------------------------------------------------------
    def stats(self, now_us: Optional[float] = None) -> Dict:
        now = now_us
        if now is None and self.clock is not None:
            now = self.clock.now_us()
        with self._lock:
            lanes = list(self._lanes)
            active = sorted(self._active)
            n_fired = sum(1 for a in self._history if a.kind == "fire")
        out: Dict = {"slo_target": self.slo_target,
                     "threshold": self.threshold,
                     "alerting_lanes": active, "alerts_fired": n_fired,
                     "lanes": {}}
        if now is not None:
            for lane in lanes:
                bl, nl = self.burn_rate(lane, self.long_window_us, now)
                bs, ns = self.burn_rate(lane, self.short_window_us, now)
                out["lanes"][str(lane)] = {
                    "burn_long": round(bl, 3), "burn_short": round(bs, 3),
                    "events_long": nl, "events_short": ns,
                    "alerting": lane in active}
        return out

    def publish(self, registry, name: str = "slo_burn") -> None:
        """Expose burn state through a ``repro.obs.MetricsRegistry``
        snapshot provider."""
        registry.register(name, self.stats)
