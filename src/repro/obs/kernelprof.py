"""Per-level ``lut_eval`` profiling -> a measured device-latency table.

The mapper optimizes structural LUT count/depth, and the scheduler's
flush margin + ``least_slack`` dispatch run on a cold-start EWMA of
whole-batch execution time. Neither knows what a netlist *level*
actually costs on the device. This module measures it two ways:

  * ``measure_level_grid`` — synthetic single-level plans swept over
    ``(level_width, fanin)`` at fixed ``k``: random leaves into a
    wire plane sized like a real netlist's, timed through the same
    jitted ``lut_eval_pallas`` entry the serving path uses. The grid is
    netlist-independent, so it can be measured once per device and
    reused (the nnabla-nas layer-wise offline-estimation shape).
  * ``profile_plan`` — the real ``DevicePlan``'s levels, timed by
    running level prefixes 1..n and differencing: level i's row is the
    *incremental* device cost of adding it, which captures gather
    locality the synthetic grid cannot.
  * ``profile_tile_plan`` — the streamed kernel's walk over a
    ``TilePlan``, timed by tile prefixes the same way: each row is one
    tile's incremental cost, which is the granularity the streamed
    engine actually schedules (and what tile-size autotuning trades).

``build_latency_table`` fits both into a ``LatencyTable`` whose
``estimate_level_us``/``estimate_plan_us`` interpolate (linear in
width, nearest in fanin) and whose ``save`` artifact is what
``least_slack`` dispatch (``ReplicaSet(exec_seed_us=...)``), the
scheduler's flush margin (``SchedConfig.exec_estimate_us``) and future
hardware-aware mapping search consume.

Interpret-mode timings on CPU are **not** TPU microseconds — the
artifact records backend + interpret flags so consumers can refuse to
mix calibrations from different devices.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

DEFAULT_WIDTHS = (4, 16, 64)
DEFAULT_FANINS = (2, 4, 6)


class LatencyTableError(ValueError):
    """Typed error for latency-table estimation failures."""


class EmptyLatencyTable(LatencyTableError):
    """Estimation was asked of a table holding no measurements."""


def _time_us(fn, *args, iters: int = 3) -> float:
    """Wall µs per call, first (compile) call excluded."""
    import jax

    from repro.serve.clock import SystemClock
    clk = SystemClock()
    jax.block_until_ready(fn(*args))
    t0 = clk.now_us()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (clk.now_us() - t0) / iters


def time_single_level(width: int, fanin: int, k: int = 6,
                      w_words: int = 128, iters: int = 3,
                      interpret: Optional[bool] = None,
                      seed: int = 0) -> float:
    """Device µs for one synthetic level of ``width`` LUTs with
    ``fanin`` live leaves each, through the jitted kernel."""
    import jax.numpy as jnp

    from repro.kernels.lut_eval import default_interpret
    from repro.kernels.lut_eval.lut_eval import lut_eval_pallas

    if interpret is None:
        interpret = default_interpret()
    rng = np.random.default_rng(seed)
    # wire plane shaped like a real netlist's: as many PI rows as LUTs
    n_pis = max(int(width), fanin, 1)
    leaf = np.zeros((width, k), np.int32)
    leaf[:, :fanin] = rng.integers(1, n_pis + 1, (width, fanin))
    tt = (rng.integers(0, 2, (width, 1 << k)).astype(np.uint32)
          * np.uint32(0xFFFFFFFF))
    ow = (np.arange(width, dtype=np.int32) + n_pis + 1)
    n_wires = 1 + n_pis + width
    words = rng.integers(0, 1 << 31, (n_pis, w_words), dtype=np.int64)
    args = (jnp.asarray(words.astype(np.int32)), jnp.asarray(leaf),
            jnp.asarray(tt.view(np.int32)), jnp.asarray(ow))

    def fn(w, l, t, o):
        return lut_eval_pallas(w, l, t, o, n_pis=n_pis, n_slots=width,
                               n_wires=n_wires, k=k,
                               block_w=min(128, w_words),
                               interpret=interpret)

    return _time_us(fn, *args, iters=iters)


def measure_level_grid(widths: Sequence[int] = DEFAULT_WIDTHS,
                       fanins: Sequence[int] = DEFAULT_FANINS,
                       k: int = 6, w_words: int = 128, iters: int = 3,
                       interpret: Optional[bool] = None,
                       seed: int = 0) -> List[Dict]:
    """Synthetic ``(level_width, fanin)`` sweep -> measurement rows."""
    rows = []
    for width in widths:
        for fanin in fanins:
            if fanin > k:
                continue
            us = time_single_level(width, fanin, k=k, w_words=w_words,
                                   iters=iters, interpret=interpret,
                                   seed=seed)
            rows.append({"source": "grid", "level_width": int(width),
                         "k": int(k), "fanin": int(fanin),
                         "device_us": float(us), "w_words": int(w_words)})
    return rows


def plan_level_fanins(dplan) -> List[float]:
    """Mean live (non-const-leaf) fanin per level of a ``DevicePlan``.

    Padded no-op slots (all leaves const, INIT masks all-zero) are
    excluded from the mean; a level that is pure padding reports 0.
    """
    out = []
    for lvl in range(dplan.n_levels):
        live = dplan.tt_bits[lvl].any(axis=1)        # real (non-pad) slots
        if not live.any():
            out.append(0.0)
            continue
        fan = (dplan.leaf_idx[lvl][live] != 0).sum(axis=1)
        out.append(float(fan.mean()))
    return out


def profile_plan(dplan, w_words: int = 128, iters: int = 3,
                 interpret: Optional[bool] = None,
                 seed: int = 0) -> List[Dict]:
    """Measured incremental device µs per level of a real plan.

    Times the kernel on level prefixes 1..n_levels and differences
    consecutive timings; clamps at >= 0 (timer noise can invert
    neighbouring prefixes on near-empty levels).
    """
    import jax.numpy as jnp

    from repro.kernels.lut_eval import default_interpret
    from repro.kernels.lut_eval.lut_eval import lut_eval_pallas

    if interpret is None:
        interpret = default_interpret()
    rng = np.random.default_rng(seed)
    lw, k = dplan.level_width, dplan.k
    words = rng.integers(0, 1 << 31, (max(dplan.n_pis, 1), w_words),
                         dtype=np.int64)
    jwords = jnp.asarray(words.astype(np.int32))
    leaf = jnp.asarray(dplan.leaf_idx.reshape(-1, k).astype(np.int32))
    tt = jnp.asarray(np.ascontiguousarray(
        dplan.tt_bits.reshape(-1, 1 << k)).view(np.int32))
    ow = jnp.asarray(dplan.out_wires.reshape(-1).astype(np.int32))
    fanins = plan_level_fanins(dplan)

    prefix_us = []
    for lvl in range(dplan.n_levels):
        n_slots = (lvl + 1) * lw

        def fn(w, l, t, o, n_slots=n_slots):
            return lut_eval_pallas(w, l[:n_slots], t[:n_slots],
                                   o[:n_slots], n_pis=dplan.n_pis,
                                   n_slots=n_slots, n_wires=dplan.n_wires,
                                   k=k, block_w=min(128, w_words),
                                   interpret=interpret)

        prefix_us.append(_time_us(fn, jwords, leaf, tt, ow, iters=iters))
    rows = []
    for lvl, us in enumerate(prefix_us):
        inc = us - (prefix_us[lvl - 1] if lvl else 0.0)
        rows.append({"source": "plan", "level": lvl,
                     "level_width": int(lw), "k": int(k),
                     "fanin": round(fanins[lvl], 2),
                     "device_us": float(max(inc, 0.0)),
                     "prefix_us": float(us), "w_words": int(w_words)})
    return rows


def tile_plan_fanins(tplan) -> List[float]:
    """Mean live (non-const-leaf) fanin per tile of a ``TilePlan``;
    pad slots (all-zero INIT) are excluded, pure-pad tiles report 0."""
    out = []
    for t in range(tplan.n_tiles):
        live = tplan.tt_tiles[t].any(axis=1)
        if not live.any():
            out.append(0.0)
            continue
        fan = (tplan.leaf_tiles[t][live] != 0).sum(axis=1)
        out.append(float(fan.mean()))
    return out


def profile_tile_plan(tplan, w_words: int = 128, iters: int = 3,
                      interpret: Optional[bool] = None,
                      gather: Optional[str] = None,
                      seed: int = 0) -> List[Dict]:
    """Measured incremental device µs per *tile* of a streamed plan.

    Times the streamed kernel on tile prefixes 1..n_tiles and
    differences consecutive timings (clamped >= 0), so each row is what
    one double-buffered tile step costs end to end — DMA overlap
    included, which per-level timing through the monolithic kernel
    cannot see.
    """
    import jax.numpy as jnp

    from repro.kernels.lut_eval.lut_eval import (default_gather,
                                                 lut_eval_streamed_pallas)
    from repro.kernels.spec import default_interpret

    if interpret is None:
        interpret = default_interpret()
    if gather is None:
        gather = default_gather()
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 1 << 31, (max(tplan.n_pis, 1), w_words),
                         dtype=np.int64)
    jwords = jnp.asarray(words.astype(np.int32))
    tt = jnp.asarray(np.ascontiguousarray(tplan.tt_tiles).view(np.int32))
    leaf = jnp.asarray(tplan.leaf_tiles)
    loc = jnp.asarray(tplan.leaf_loc)
    grows = jnp.asarray(tplan.gather_rows)
    ob = jnp.asarray(tplan.out_base)
    fanins = tile_plan_fanins(tplan)

    prefix_us = []
    for n in range(1, tplan.n_tiles + 1):
        def fn(w, n=n):
            return lut_eval_streamed_pallas(
                w, tt[:n], leaf[:n], loc[:n], grows[:n], ob[:n],
                n_pis=tplan.n_pis, n_tiles=n, tile_rows=tplan.tile_rows,
                gather_cap=tplan.gather_cap, n_rows=tplan.n_rows,
                k=tplan.k, block_w=min(128, w_words), gather=gather,
                interpret=interpret)

        prefix_us.append(_time_us(fn, jwords, iters=iters))
    rows = []
    for t, us in enumerate(prefix_us):
        inc = us - (prefix_us[t - 1] if t else 0.0)
        rows.append({"source": "tile", "level": int(tplan.level_of_tile[t]),
                     "tile": t, "level_width": int(tplan.tile_rows),
                     "k": int(tplan.k), "fanin": round(fanins[t], 2),
                     "device_us": float(max(inc, 0.0)),
                     "prefix_us": float(us), "w_words": int(w_words)})
    return rows


@dataclasses.dataclass
class LatencyTable:
    """Measured ``(level_width, k, fanin) -> device µs`` lookup.

    Estimation is nearest-fanin (which clamps out-of-sweep fanins to
    the nearest calibrated one), then linear interpolation in
    ``level_width``. Queries **outside the calibrated width sweep are
    clamped, never slope-extrapolated**: below the grid the smallest
    measurement applies (``np.interp``'s edge clamp); above it the
    largest measurement scales proportionally per LUT
    (``us[-1] * width / ws[-1]``) — per-level work is linear in width
    for a fixed word tile, and a two-point slope can go negative or
    explode on a noisy sweep, which once fed the flush margin a
    nonsense estimate.

    ``scale`` is an online correction factor: calibration happens on an
    idle device, serving happens on a busy one, and
    ``repro.obs.online.OnlineProfiler`` blends the live measured/
    predicted ratio into it so scheduler flush margins track the
    machine as it actually is.
    """

    rows: List[Dict]
    meta: Dict = dataclasses.field(default_factory=dict)
    scale: float = 1.0              # online measured/predicted blend

    SCALE_MIN = 0.1
    SCALE_MAX = 10.0

    def _grid_rows(self, k: int) -> List[Dict]:
        if not self.rows:
            raise EmptyLatencyTable(
                "latency table holds no measurements — run "
                "build_latency_table (or load a saved artifact) before "
                "estimating")
        rows = [r for r in self.rows
                if r["k"] == k and r["source"] == "grid"]
        return rows or [r for r in self.rows if r["k"] == k]

    def estimate_level_us(self, level_width: int, fanin: float,
                          k: int = 6) -> float:
        rows = self._grid_rows(k)
        if not rows:
            raise LatencyTableError(
                f"no measurements for k={k} "
                f"(calibrated: {sorted({r['k'] for r in self.rows})})")
        if not np.isfinite(level_width) or not np.isfinite(fanin):
            raise LatencyTableError(
                f"non-finite query (level_width={level_width}, "
                f"fanin={fanin})")
        level_width = max(float(level_width), 0.0)
        fans = sorted({r["fanin"] for r in rows})
        near_fan = min(fans, key=lambda f: abs(f - fanin))
        pts = sorted((r["level_width"], r["device_us"]) for r in rows
                     if r["fanin"] == near_fan)
        ws = [p[0] for p in pts]
        us = [p[1] for p in pts]
        if level_width > ws[-1]:        # past grid: per-LUT scaling of
            est = us[-1] * level_width / max(ws[-1], 1)     # the last point
        elif len(pts) == 1:
            est = us[0] * level_width / max(ws[0], 1)
        else:                           # in-grid interp; below-grid clamps
            est = float(np.interp(level_width, ws, us))     # to us[0]
        return max(est, 0.0) * self.scale

    def estimate_plan_us(self, dplan) -> float:
        """Calibrated whole-netlist estimate: sum of per-level
        estimates at each level's width and mean live fanin."""
        total = 0.0
        for fanin in plan_level_fanins(dplan):
            total += self.estimate_level_us(dplan.level_width, fanin,
                                            k=dplan.k)
        return total

    def blend_scale(self, factor: float, alpha: float = 0.2) -> float:
        """EWMA-blend a live measured/predicted ratio into ``scale``.

        ``factor`` outside ``[SCALE_MIN, SCALE_MAX]`` is clamped before
        blending (one absurd sample — a GC pause mid-measurement — must
        not poison every later estimate); non-finite factors are
        ignored. Returns the updated scale."""
        if not np.isfinite(factor) or factor <= 0:
            return self.scale
        factor = min(max(float(factor), self.SCALE_MIN), self.SCALE_MAX)
        self.scale = min(max((1.0 - alpha) * self.scale + alpha * factor,
                             self.SCALE_MIN), self.SCALE_MAX)
        return self.scale

    # -- artifact ----------------------------------------------------------
    def to_dict(self) -> Dict:
        return {"kind": "lut_level_latency_table", "meta": self.meta,
                "scale": self.scale, "rows": self.rows}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "LatencyTable":
        with open(path) as f:
            doc = json.load(f)
        if doc.get("kind") != "lut_level_latency_table":
            raise ValueError(f"{path} is not a lut-level latency table")
        return cls(rows=doc["rows"], meta=doc.get("meta", {}),
                   scale=float(doc.get("scale", 1.0)))


def build_latency_table(dplan=None, widths: Sequence[int] = DEFAULT_WIDTHS,
                        fanins: Sequence[int] = DEFAULT_FANINS, k: int = 6,
                        w_words: int = 128, iters: int = 3,
                        interpret: Optional[bool] = None,
                        seed: int = 0) -> LatencyTable:
    """Grid sweep (+ real-plan per-level rows when ``dplan`` given) ->
    a saveable ``LatencyTable`` stamped with the measurement context."""
    import jax

    from repro.kernels.lut_eval import default_interpret

    if interpret is None:
        interpret = default_interpret()
    if dplan is not None:
        k = dplan.k
    rows = measure_level_grid(widths, fanins, k=k, w_words=w_words,
                              iters=iters, interpret=interpret, seed=seed)
    if dplan is not None:
        rows += profile_plan(dplan, w_words=w_words, iters=iters,
                             interpret=interpret, seed=seed)
        if getattr(dplan, "tiles", None) is not None:
            rows += profile_tile_plan(dplan.tiles, w_words=w_words,
                                      iters=iters, interpret=interpret,
                                      seed=seed)
    meta = {"backend": jax.default_backend(), "interpret": bool(interpret),
            "device": str(jax.devices()[0]), "w_words": int(w_words),
            "iters": int(iters), "k": int(k)}
    return LatencyTable(rows=rows, meta=meta)
