"""Trace export: Chrome trace-event JSON (Perfetto-loadable) + JSONL.

The Chrome JSON Object Format (the ``{"traceEvents": [...]}`` shape)
opens directly in https://ui.perfetto.dev or chrome://tracing. Thread
spans map to complete events (``ph="X"``), per-request lifecycles map
to async events (``ph="b"/"n"/"e"``, keyed by the request's trace id)
so each request renders as its own track with submit → queue →
batch-formed → complete milestones, overlapping freely with other
requests. ``otherData`` carries the metrics-registry snapshot when one
is supplied, so a trace file is a self-contained incident report.

JSONL export writes one structured event per line — the grep/pandas
surface for scripted analysis where a timeline viewer is overkill.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Union

from .trace import TraceEvent

_EVENT_SOURCE = Union["SpanTracer", Iterable[TraceEvent]]  # noqa: F821


def _as_events(src) -> List[TraceEvent]:
    if hasattr(src, "events"):
        return list(src.events())
    return list(src)


def to_chrome_trace(src, pid: int = 1,
                    process_name: str = "repro.serve",
                    other_data: Optional[Dict] = None) -> Dict:
    """Events -> Chrome JSON Object Format dict.

    Times are emitted in µs directly (the Chrome format's native unit),
    so FakeClock timestamps round-trip exactly."""
    out: List[Dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    tids = {}
    for ev in _as_events(src):
        tid = tids.setdefault(ev.tid, len(tids) + 1)   # compact tids
        rec: Dict = {"name": ev.name, "cat": ev.cat, "ph": ev.ph,
                     "ts": ev.ts_us, "pid": pid, "tid": tid}
        if ev.ph == "X":
            rec["dur"] = ev.dur_us
        if ev.scope_id is not None:
            rec["id"] = str(ev.scope_id)
        if ev.args:
            rec["args"] = dict(ev.args)
        out.append(rec)
    doc: Dict = {"traceEvents": out, "displayTimeUnit": "ns"}
    if other_data is not None:
        doc["otherData"] = other_data
    return doc


def write_chrome_trace(path: str, src, pid: int = 1,
                       process_name: str = "repro.serve",
                       other_data: Optional[Dict] = None) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(src, pid=pid, process_name=process_name,
                                  other_data=other_data), f)
    return path


def to_jsonl(src) -> str:
    lines = []
    for ev in _as_events(src):
        lines.append(json.dumps({
            "ph": ev.ph, "name": ev.name, "cat": ev.cat,
            "ts_us": ev.ts_us, "dur_us": ev.dur_us, "tid": ev.tid,
            "id": ev.scope_id, "args": ev.args or {}}))
    return "\n".join(lines) + ("\n" if lines else "")


def write_jsonl(path: str, src) -> str:
    with open(path, "w") as f:
        f.write(to_jsonl(src))
    return path


def load_trace_events(path: str) -> List[TraceEvent]:
    """Read either export format back into ``TraceEvent`` records
    (metadata events are dropped) — the input side of the trace
    validation pass."""
    with open(path) as f:
        text = f.read()
    events: List[TraceEvent] = []
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None
    if isinstance(doc, dict) and "traceEvents" in doc:   # Chrome format
        for rec in doc["traceEvents"]:
            if rec.get("ph") == "M":
                continue
            sid = rec.get("id")
            events.append(TraceEvent(
                rec.get("ph", "?"), rec.get("name", "?"),
                rec.get("cat", "?"), float(rec.get("ts", 0.0)),
                float(rec.get("dur", 0.0)), int(rec.get("tid", 0)),
                None if sid is None else int(sid),
                rec.get("args")))
        return events
    for line in text.splitlines():                        # JSONL
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        events.append(TraceEvent(
            rec["ph"], rec["name"], rec["cat"], float(rec["ts_us"]),
            float(rec.get("dur_us", 0.0)), int(rec.get("tid", 0)),
            rec.get("id"), rec.get("args")))
    return events
