"""Online continuous profiling: live device timings recalibrate serving.

``kernelprof`` calibrates the ``LatencyTable`` offline, on an idle
device; the scheduler's flush margin and ``least_slack`` EWMAs are then
seeded once and drift on their own. But a serving device is not an idle
device — thermals, co-tenants, interpret-vs-compiled mode, and batch
shape all move per-batch microseconds. ``OnlineProfiler`` closes the
loop with *sampled real traffic*:

  * the ``BitplaneAggregator`` times its ``device_exec`` section and
    reports ``(measured_us, rows)`` through ``on_device_us`` (a plain
    callback — the aggregator stays scheduler- and profiler-agnostic);
  * every ``sample_every``-th observation, the profiler blends the
    measured/predicted ratio into ``LatencyTable.scale``
    (EWMA, clamped — one GC pause must not poison the margin);
  * the rescaled whole-plan estimate is pushed to
    ``MicroBatchScheduler.update_exec_estimate`` (flush margin) and
    ``ReplicaSet.reseed_exec_estimate`` (least-slack dispatch), so both
    track the live device instead of the calibration-day one.

The push happens on the executor thread *after* the batch completes —
the scheduler is not holding its condition lock while its executor
runs, so ``update_exec_estimate`` can take it without self-deadlock.
This is the serving half of the ROADMAP's hardware-aware-estimator
item: the same blended table the mapping search will consume.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

from .kernelprof import LatencyTable


class OnlineProfiler:
    """Blend sampled real-traffic device timings into a LatencyTable.

    Parameters
    ----------
    table:
        The calibrated ``LatencyTable`` to keep honest (its ``scale``
        field is the blend target).
    predicted_us:
        Whole-plan predicted device µs at the table's *current* scale
        (typically ``table.estimate_plan_us(dplan)``); the profiler
        normalizes out the scale so repeated blending converges on the
        true measured/calibrated ratio instead of compounding.
    sample_every:
        Blend every Nth observation (1 = every batch). Off-sample
        observations cost one counter increment.
    alpha:
        EWMA weight of each sampled ratio.
    min_rows:
        Ignore observations from batches smaller than this — a 1-row
        flush's per-call overhead is not the per-row device rate the
        table models.
    """

    _GUARDED_BY = {"_sched": "_lock", "_replicas": "_lock",
                   "n_observed": "_lock", "n_sampled": "_lock",
                   "last_measured_us": "_lock"}

    def __init__(self, table: LatencyTable, predicted_us: float,
                 sample_every: int = 16, alpha: float = 0.2,
                 min_rows: int = 1):
        if predicted_us <= 0:
            raise ValueError(f"predicted_us must be > 0, "
                             f"got {predicted_us}")
        self.table = table
        # prediction at scale 1.0: the stable denominator of the ratio
        self._base_us = predicted_us / table.scale
        self.sample_every = max(int(sample_every), 1)
        self.alpha = float(alpha)
        self.min_rows = int(min_rows)
        self._sched = None
        self._replicas = []
        self.n_observed = 0
        self.n_sampled = 0
        self.last_measured_us: Optional[float] = None
        self._lock = threading.Lock()

    # -- wiring ------------------------------------------------------------
    def attach(self, scheduler=None, replicas=None) -> "OnlineProfiler":
        """Register consumers to push rescaled estimates into.

        Takes the lock: attach() may race an in-flight observe() on the
        executor thread when consumers are wired after traffic starts.
        """
        with self._lock:
            if scheduler is not None:
                self._sched = scheduler
            if replicas is not None:
                self._replicas.append(replicas)
        return self

    @property
    def estimate_us(self) -> float:
        """Whole-plan estimate at the current blended scale."""
        return self._base_us * self.table.scale

    # -- the aggregator callback -------------------------------------------
    def observe(self, measured_us: float, rows: int = 0) -> None:
        """One real-traffic device timing (``on_device_us`` target)."""
        with self._lock:
            self.n_observed += 1
            if (measured_us <= 0 or (rows and rows < self.min_rows)
                    or self.n_observed % self.sample_every):
                return
            self.n_sampled += 1
            self.last_measured_us = float(measured_us)
            self.table.blend_scale(measured_us / self._base_us,
                                   alpha=self.alpha)
            est = self.estimate_us
            sched, replicas = self._sched, list(self._replicas)
        # push outside our lock: consumers take their own locks and
        # nothing here may run under the scheduler's condition
        if sched is not None:
            sched.update_exec_estimate(est)
        for rs in replicas:
            rs.reseed_exec_estimate(est)

    # -- reporting ---------------------------------------------------------
    def stats(self) -> Dict:
        with self._lock:
            return {"n_observed": self.n_observed,
                    "n_sampled": self.n_sampled,
                    "sample_every": self.sample_every,
                    "scale": self.table.scale,
                    "base_us": self._base_us,
                    "estimate_us": self.estimate_us,
                    "last_measured_us": self.last_measured_us}

    def publish(self, registry, name: str = "online_profile") -> None:
        """Expose blend state through a ``repro.obs.MetricsRegistry``
        snapshot provider."""
        registry.register(name, self.stats)
