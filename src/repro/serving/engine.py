"""Batched serving engine.

Two request kinds, matching the paper's deployment story:
  * LogicEngine — ultra-low-latency classification through the compiled
    fixed-function logic network (the paper's product); requests are
    micro-batched with a latency deadline, executed via the Pallas
    lut_layer path (oracle path selectable);
  * LMEngine    — autoregressive decode with a shared KV cache pool:
    continuous batching over slots (admit on free slot, retire on EOS /
    max tokens). On-pod deployment shards slots over ("pod","data") and
    heads over "model" exactly like the dry-run's decode cells.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.logic_infer import LogicNetwork
from repro.models import lm


# ---------------------------------------------------------------------------
# Logic-network serving (the paper's inference product)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LogicEngine:
    """Micro-batching frontend over a compiled LogicNetwork.

    ``backend`` selects the inference representation:
      * ``"gather"``   — per-neuron truth-table gathers (pure jnp oracle);
      * ``"pallas"``   — same tables through the lut_layer Pallas kernel;
      * ``"bitplane"`` — the ``repro.synth`` mapped 6-LUT netlist run as
        packed bitplane ops (32 samples per uint32 lane) — no per-neuron
        gathers at all. Argmax outputs are identical across backends.

    For the bitplane backend, ``engine`` names a netlist executor in
    the ``repro.synth.executors`` registry: ``"numpy"`` folds levels on
    the host; ``"pallas"`` runs the whole levelized netlist through the
    monolithic ``kernels.lut_eval`` device pipeline;
    ``"pallas-streamed"`` through the streamed/tiled kernel (pack →
    levels → complement → argmax in one jit either way). Custom engines
    registered via ``executors.register`` work here unchanged.
    """

    net: LogicNetwork
    n_classes: int
    max_batch: int = 256
    max_wait_ms: float = 0.2
    use_pallas: bool = False            # legacy alias for backend="pallas"
    backend: str = "gather"
    engine: str = "numpy"               # bitplane netlist executor
    synth_effort: int = 1

    def __post_init__(self):
        if self.use_pallas and self.backend == "gather":
            self.backend = "pallas"
        if self.backend == "bitplane":
            from repro.serve.aggregate import BitplaneAggregator
            from repro.synth import compile_logic_network
            self.bitnet = compile_logic_network(
                self.net, effort=self.synth_effort, engine=self.engine)
            # padded aggregator: one quantizer shape for every flush size
            self._fn = BitplaneAggregator(self.bitnet, self.n_classes,
                                          pad_rows=self.max_batch)
            return
        if self.backend not in ("gather", "pallas"):
            raise ValueError(f"unknown LogicEngine backend {self.backend!r}")
        use_pallas = self.backend == "pallas"
        self._fn = jax.jit(
            lambda x: jnp.argmax(
                self.net(x, use_pallas=use_pallas)
                [..., : self.n_classes], axis=-1))
        # warm the jit cache at the serving batch size
        self._fn(jnp.zeros((self.max_batch, self.net.n_inputs), jnp.float32))

    def exec_batch(self, x: np.ndarray) -> np.ndarray:
        """One evaluation: (B <= max_batch, F) -> (B,) int32 argmax.

        The jit backends pad to the warmed ``max_batch`` shape; the
        bitplane backend packs exactly the rows it is given.
        """
        x = np.asarray(x)
        n = x.shape[0]
        assert n <= self.max_batch, (n, self.max_batch)
        if self.backend == "bitplane":
            return np.asarray(self._fn(x))
        pad = self.max_batch - n
        if pad:
            x = np.concatenate([x, np.zeros((pad, x.shape[1]), x.dtype)])
        return np.asarray(self._fn(jnp.asarray(x)))[:n]

    def classify(self, x: np.ndarray) -> np.ndarray:
        """Synchronous batched classification."""
        n = x.shape[0]
        out = np.empty((n,), np.int32)
        for i in range(0, n, self.max_batch):
            xb = x[i: i + self.max_batch]
            out[i: i + xb.shape[0]] = self.exec_batch(xb)
        return out

    def scheduler_executor(self) -> Callable[[np.ndarray], np.ndarray]:
        """Executor callable for ``repro.serve`` schedulers.

        The bitplane backend aggregates the batch's requests into uint32
        lanes and evaluates the mapped netlist once per pack
        (``repro.serve.aggregate``); the jit backends run one padded
        evaluation. All three return identical argmaxes. The executor
        advertises ``n_features`` so the scheduler rejects wrong-width
        payloads at admission (typed ``BAD_SHAPE``) instead of letting
        one malformed request poison a whole batch.
        """
        if self.backend == "bitplane":
            return self._fn             # BitplaneAggregator: has n_features

        def ex(x: np.ndarray) -> np.ndarray:
            return self.exec_batch(x)

        ex.n_features = self.net.n_inputs
        return ex

    def serve_queue(self, requests: List[np.ndarray], clock=None,
                    deadline_us: Optional[float] = None,
                    lane_slo_us: Optional[Tuple[float, ...]] = None,
                    tracer=None
                    ) -> Tuple[List[np.ndarray], Dict[str, float]]:
        """Micro-batched serving of a request list; returns per-request
        results + latency stats (p50/p95/p99/mean, µs).

        Thin compatibility wrapper over ``repro.serve``'s micro-batch
        scheduler: all requests are admitted up front and drained, so
        the reported latencies are true enqueue→complete times — a
        request stuck behind earlier batches shows its head-of-line
        wait, which the old per-call timing loop hid.

        ``deadline_us`` gives every request that latency budget (µs from
        enqueue); ``lane_slo_us`` installs the per-lane SLO table
        instead. With either set, requests past their budget at flush
        time are shed with a typed ``RequestRejected(DEADLINE_EXCEEDED)``
        (a ``None`` in the results list) and the stats gain
        ``deadline_miss_rate`` / ``shed``.
        """
        from repro.serve import (MicroBatchScheduler, RequestRejected,
                                 SchedConfig)

        cfg = SchedConfig(max_batch=self.max_batch,
                          max_wait_us=self.max_wait_ms * 1e3,
                          max_queue=max(2 * len(requests), 1),
                          n_priorities=1, lane_slo_us=lane_slo_us)
        sched = MicroBatchScheduler(self.scheduler_executor(), cfg,
                                    clock=clock, tracer=tracer)
        futs: List[Any] = []
        for r in requests:
            r = np.asarray(r)
            if r.ndim > 1 and r.shape[0] > self.max_batch:
                futs.append([sched.submit(r[i: i + self.max_batch],
                                          deadline_us=deadline_us)
                             for i in range(0, r.shape[0], self.max_batch)])
            else:
                futs.append(sched.submit(r, deadline_us=deadline_us))
        sched.drain()

        def _res(f):
            try:
                return np.asarray(f.result())
            except RequestRejected:
                return None                 # shed past its deadline

        results = []
        for f in futs:
            if isinstance(f, list):
                parts = [_res(p) for p in f]
                results.append(None if any(p is None for p in parts)
                               else np.concatenate(parts))
            else:
                results.append(_res(f))
        snap = sched.metrics.snapshot()
        stats = {k: snap[k] for k in
                 ("p50_us", "p95_us", "p99_us", "mean_us", "qps",
                  "mean_batch_occupancy", "n_batches",
                  "deadline_miss_rate", "shed")}
        return results, stats


# ---------------------------------------------------------------------------
# LM serving (continuous batching decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LMRequest:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1            # -1 = never
    out_tokens: Optional[List[int]] = None


_LM_CACHE_LEAVES = ("k", "v", "positions", "ssm", "conv", "enc_out")


class LMEngine:
    """Continuous-batching decode over a fixed slot pool.

    Slots admit requests as they free up; one jitted decode_step advances
    every active slot each tick (inactive slots carry a pad token, their
    outputs are discarded) — the standard TPU serving shape where the
    decode batch is static and occupancy varies.

    Admission sits behind the ``repro.serve`` bounded priority queue:
    ``submit`` enqueues with a priority lane and raises a typed
    ``RequestRejected`` when ``max_pending`` is hit (backpressure),
    and freed slots always admit the highest-priority waiter first.
    """

    def __init__(self, cfg: ArchConfig, params, n_slots: int = 4,
                 max_seq: int = 512, max_pending: Optional[int] = None,
                 n_priorities: int = 2, clock=None):
        from repro.serve.clock import SystemClock
        from repro.serve.sched import BoundedPriorityQueue

        self.cfg = cfg
        self.params = params
        self.clock = clock or SystemClock()
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = lm.init_cache(cfg, n_slots, max_seq)
        self.positions = np.zeros((n_slots,), np.int32)
        self.active: List[Optional[LMRequest]] = [None] * n_slots
        self.last_tok = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
        self._prefill_cache = {}
        self._splice = jax.jit(self._splice_slot, donate_argnums=(0,))
        self.admission = BoundedPriorityQueue(
            max_pending if max_pending is not None else (1 << 30),
            n_priorities)

    def submit(self, req: LMRequest, priority: int = 0,
               deadline_us: Optional[float] = None):
        """Admit into the priority queue (typed reject when full).

        ``deadline_us`` is a queueing budget (µs from enqueue): a
        request still waiting for a decode slot past its budget is shed
        with a typed ``RequestRejected(DEADLINE_EXCEEDED)`` on its
        future instead of being admitted late.

        Returns the request's ``ServeFuture``: resolved with the
        finished ``LMRequest`` by ``run``, with enqueue→complete
        latency on ``fut.latency_us``.
        """
        import math

        from repro.serve.sched import ServeFuture, ServeRequest

        fut = ServeFuture()
        fut.t_enqueue_us = self.clock.now_us()
        self.admission.push(ServeRequest(
            x=req, rows=1, priority=priority,
            t_enqueue_us=fut.t_enqueue_us, future=fut,
            deadline_us=(fut.t_enqueue_us + deadline_us
                         if deadline_us is not None else math.inf)))
        return fut

    @staticmethod
    def _splice_slot(cache, single, slot):
        """Write ONE admitted slot into the pooled cache.

        Runs jitted with the pool donated, so every leaf updates in
        place — O(layers × window) writes for the admitted slot only,
        where the old two-step ``.at[...].set`` path materialised two
        full-pool copies per leaf (O(layers × slots) device traffic per
        admission).
        """
        out = {}
        for key, pool in cache.items():
            s = single[key]
            if key in ("k", "v"):            # (L, B, W, KV, dh)
                w = min(s.shape[2], pool.shape[2])
                row = jnp.zeros(pool.shape[:1] + pool.shape[2:], pool.dtype)
                row = row.at[:, :w].set(s[:, 0, :w])
                out[key] = pool.at[:, slot].set(row)
            elif key == "positions":          # (B, W)
                w = min(s.shape[1], pool.shape[1])
                row = jnp.full(pool.shape[1:], -1, pool.dtype)
                row = row.at[:w].set(s[0, :w])
                out[key] = pool.at[slot].set(row)
            elif key in ("ssm", "conv"):      # (L, B, ...)
                out[key] = pool.at[:, slot].set(s[:, 0])
            else:                             # enc_out (B, F, D)
                out[key] = pool.at[slot].set(s[0])
        return out

    def _admit(self, req: LMRequest, slot: int):
        # per-request prefill at its prompt length (compile cache per len)
        s = len(req.prompt)
        toks = jnp.asarray(req.prompt[None, :])
        if s not in self._prefill_cache:
            self._prefill_cache[s] = jax.jit(
                lambda p, t: lm.prefill(self.cfg, p, tokens=t,
                                        max_seq=self.max_seq))
        logits, cache1 = self._prefill_cache[s](self.params, toks)

        for key in cache1:
            if key not in _LM_CACHE_LEAVES:
                raise KeyError(f"unknown cache leaf {key}")
        # splice only the admitted slot (ring slot layouts agree because
        # prompt_len <= pool window here)
        self.cache = self._splice(self.cache, cache1,
                                  jnp.asarray(slot, jnp.int32))
        req.out_tokens = []
        self.active[slot] = req
        self.positions[slot] = s
        self.last_tok[slot, 0] = int(jnp.argmax(logits[0]))
        req.out_tokens.append(int(self.last_tok[slot, 0]))

    def run(self, requests: Sequence[LMRequest] = ()) -> List[LMRequest]:
        """Decode until the admission queue and all slots are empty.

        ``requests`` (back-compat) are submitted at priority 0 before
        the loop; callers using ``submit`` directly can pass nothing.
        """
        for r in requests:
            self.submit(r)
        from repro.serve.sched import RejectReason, RequestRejected

        done: List[LMRequest] = []
        sreqs: List[Optional[Any]] = [None] * self.n_slots
        while len(self.admission) or any(a is not None for a in self.active):
            # shed waiters whose queueing budget expired before a slot
            # freed up — a typed reject beats a silently late admission
            now_us = self.clock.now_us()
            for expired in self.admission.shed_expired(now_us):
                expired.future.t_done_us = now_us
                expired.future.set_exception(RequestRejected(
                    RejectReason.DEADLINE_EXCEEDED,
                    f"expired {now_us - expired.deadline_us:.0f} µs before "
                    f"a decode slot freed"))
            # admit, highest priority lane first
            for i in range(self.n_slots):
                if self.active[i] is None and len(self.admission):
                    (sreq,) = self.admission.pop_batch(1)
                    sreqs[i] = sreq
                    self._admit(sreq.x, i)
            # decode tick
            logits, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self.last_tok), jnp.asarray(self.positions))
            nxt = np.asarray(jnp.argmax(logits, -1))
            for i in range(self.n_slots):
                req = self.active[i]
                if req is None:
                    continue
                tok = int(nxt[i])
                req.out_tokens.append(tok)
                self.positions[i] += 1
                self.last_tok[i, 0] = tok
                if (tok == req.eos_id
                        or len(req.out_tokens) >= req.max_new_tokens
                        or self.positions[i] >= self.max_seq - 1):
                    done.append(req)
                    self.active[i] = None
                    if sreqs[i] is not None:
                        sreqs[i].future.t_done_us = self.clock.now_us()
                        sreqs[i].future.set_result(req)
                        sreqs[i] = None
        return done
