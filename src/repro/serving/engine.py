"""Batched serving engine.

Two request kinds, matching the paper's deployment story:
  * LogicEngine — ultra-low-latency classification through the compiled
    fixed-function logic network (the paper's product); requests are
    micro-batched with a latency deadline, executed via the Pallas
    lut_layer path (oracle path selectable);
  * LMEngine    — autoregressive decode with a shared KV cache pool:
    continuous batching over slots (admit on free slot, retire on EOS /
    max tokens). On-pod deployment shards slots over ("pod","data") and
    heads over "model" exactly like the dry-run's decode cells.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.logic_infer import LogicNetwork
from repro.models import lm


# ---------------------------------------------------------------------------
# Logic-network serving (the paper's inference product)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LogicEngine:
    """Micro-batching frontend over a compiled LogicNetwork.

    ``backend`` selects the inference representation:
      * ``"gather"``   — per-neuron truth-table gathers (pure jnp oracle);
      * ``"pallas"``   — same tables through the lut_layer Pallas kernel;
      * ``"bitplane"`` — the ``repro.synth`` mapped 6-LUT netlist run as
        packed bitplane ops (32 samples per uint32 lane) — no per-neuron
        gathers at all. Argmax outputs are identical across backends.
    """

    net: LogicNetwork
    n_classes: int
    max_batch: int = 256
    max_wait_ms: float = 0.2
    use_pallas: bool = False            # legacy alias for backend="pallas"
    backend: str = "gather"
    synth_effort: int = 1

    def __post_init__(self):
        if self.use_pallas and self.backend == "gather":
            self.backend = "pallas"
        if self.backend == "bitplane":
            from repro.synth import compile_logic_network
            self.bitnet = compile_logic_network(
                self.net, effort=self.synth_effort)
            self._fn = lambda x: self.bitnet.classify(x, self.n_classes)
            return
        if self.backend not in ("gather", "pallas"):
            raise ValueError(f"unknown LogicEngine backend {self.backend!r}")
        use_pallas = self.backend == "pallas"
        self._fn = jax.jit(
            lambda x: jnp.argmax(
                self.net(x, use_pallas=use_pallas)
                [..., : self.n_classes], axis=-1))
        # warm the jit cache at the serving batch size
        self._fn(jnp.zeros((self.max_batch, self.net.n_inputs), jnp.float32))

    def classify(self, x: np.ndarray) -> np.ndarray:
        """Synchronous batched classification."""
        n = x.shape[0]
        out = np.empty((n,), np.int32)
        for i in range(0, n, self.max_batch):
            xb = x[i: i + self.max_batch]
            pad = self.max_batch - xb.shape[0]
            if pad:
                xb = np.concatenate([xb, np.zeros((pad, x.shape[1]),
                                                  x.dtype)])
            res = np.asarray(self._fn(jnp.asarray(xb)))
            out[i: i + self.max_batch - pad] = res[: self.max_batch - pad]
        return out

    def serve_queue(self, requests: List[np.ndarray]
                    ) -> Tuple[List[np.ndarray], Dict[str, float]]:
        """Micro-batched serving of a request list; returns per-request
        results + latency stats (p50/p95/mean, µs)."""
        lat = []
        results = []
        for r in requests:
            t0 = time.perf_counter()
            results.append(self.classify(r))
            lat.append((time.perf_counter() - t0) * 1e6)
        lat_np = np.asarray(lat)
        stats = {"p50_us": float(np.percentile(lat_np, 50)),
                 "p95_us": float(np.percentile(lat_np, 95)),
                 "mean_us": float(lat_np.mean())}
        return results, stats


# ---------------------------------------------------------------------------
# LM serving (continuous batching decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LMRequest:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1            # -1 = never
    out_tokens: Optional[List[int]] = None


class LMEngine:
    """Continuous-batching decode over a fixed slot pool.

    Slots admit requests as they free up; one jitted decode_step advances
    every active slot each tick (inactive slots carry a pad token, their
    outputs are discarded) — the standard TPU serving shape where the
    decode batch is static and occupancy varies.
    """

    def __init__(self, cfg: ArchConfig, params, n_slots: int = 4,
                 max_seq: int = 512):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.cache = lm.init_cache(cfg, n_slots, max_seq)
        self.positions = np.zeros((n_slots,), np.int32)
        self.active: List[Optional[LMRequest]] = [None] * n_slots
        self.last_tok = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(cfg, p, c, t, pos))
        self._prefill_cache = {}

    def _admit(self, req: LMRequest, slot: int):
        # per-request prefill at its prompt length (compile cache per len)
        s = len(req.prompt)
        toks = jnp.asarray(req.prompt[None, :])
        if s not in self._prefill_cache:
            self._prefill_cache[s] = jax.jit(
                lambda p, t: lm.prefill(self.cfg, p, tokens=t,
                                        max_seq=self.max_seq))
        logits, cache1 = self._prefill_cache[s](self.params, toks)

        # splice slot state into the pooled cache (key-aware; ring slot
        # layouts agree because prompt_len <= pool window here)
        new_cache = dict(self.cache)
        for key, single in cache1.items():
            pool = self.cache[key]
            if key in ("k", "v"):            # (L, B, W, KV, dh)
                w = min(single.shape[2], pool.shape[2])
                reset = pool.at[:, slot].set(0)
                new_cache[key] = reset.at[:, slot, :w].set(single[:, 0, :w])
            elif key == "positions":          # (B, W)
                w = min(single.shape[1], pool.shape[1])
                reset = pool.at[slot].set(-1)
                new_cache[key] = reset.at[slot, :w].set(single[0, :w])
            elif key in ("ssm", "conv"):      # (L, B, ...)
                new_cache[key] = pool.at[:, slot].set(single[:, 0])
            elif key == "enc_out":            # (B, F, D)
                new_cache[key] = pool.at[slot].set(single[0])
            else:
                raise KeyError(f"unknown cache leaf {key}")
        self.cache = new_cache
        req.out_tokens = []
        self.active[slot] = req
        self.positions[slot] = s
        self.last_tok[slot, 0] = int(jnp.argmax(logits[0]))
        req.out_tokens.append(int(self.last_tok[slot, 0]))

    def run(self, requests: List[LMRequest]) -> List[LMRequest]:
        pending = list(requests)
        done: List[LMRequest] = []
        while pending or any(a is not None for a in self.active):
            # admit
            for i in range(self.n_slots):
                if self.active[i] is None and pending:
                    self._admit(pending.pop(0), i)
            # decode tick
            logits, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(self.last_tok), jnp.asarray(self.positions))
            nxt = np.asarray(jnp.argmax(logits, -1))
            for i in range(self.n_slots):
                req = self.active[i]
                if req is None:
                    continue
                tok = int(nxt[i])
                req.out_tokens.append(tok)
                self.positions[i] += 1
                self.last_tok[i, 0] = tok
                if (tok == req.eos_id
                        or len(req.out_tokens) >= req.max_new_tokens
                        or self.positions[i] >= self.max_seq - 1):
                    done.append(req)
                    self.active[i] = None
        return done
