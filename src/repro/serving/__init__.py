"""Serving substrate: batched request engine for logic networks + LMs.

Both engines sit behind the ``repro.serve`` micro-batching scheduler:
``LogicEngine.serve_queue`` wraps it for request batching, and
``LMEngine`` admission uses its bounded priority queue.
"""
