"""Serving substrate: batched request engine for logic networks + LMs."""
