"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Full-size archs expect a real pod (the mesh asserts device count);
``--smoke`` trains the reduced config on local devices — the same code
path the examples and integration tests use. ``--model-par``>1 exercises
tensor parallelism on local (or forced-host) devices.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import lm_batch, sharded_batch
from repro.dist import shardings as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.loop import Trainer, init_state, make_train_step
from repro.train.optim import AdamW
from repro.train.schedules import warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "sign"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 (or 2x16x16 with --multi-pod) mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, smoke=args.smoke)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh(model_par=args.model_par)

    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps),
                weight_decay=0.01, grad_clip=1.0)
    step_fn = make_train_step(cfg, opt, compress=args.compress,
                              grad_accum=args.grad_accum)

    with sh.use_mesh(mesh):
        state = init_state(cfg, opt, jax.random.PRNGKey(args.seed),
                           compress=args.compress)
        p_sh = sh.params_shardings(mesh, state.params)
        state = state._replace(
            params=jax.device_put(state.params, p_sh),
            opt=state.opt._replace(
                mu=jax.device_put(state.opt.mu, p_sh),
                nu=jax.device_put(state.opt.nu, p_sh)))
        jitted = jax.jit(step_fn, donate_argnums=0)

        def batch_iter():
            step = 0
            while True:
                toks, labels = lm_batch(cfg, args.batch, args.seq,
                                        args.seed, step)
                batch = {"tokens": jnp.asarray(toks),
                         "labels": jnp.asarray(labels)}
                if cfg.is_encdec:
                    batch["enc_embeds"] = jnp.zeros(
                        (args.batch, args.seq // cfg.frontend_frames_div,
                         cfg.d_model), jnp.bfloat16)
                step += 1
                yield batch

        trainer = Trainer(jitted, state, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
        last = trainer.run(batch_iter(), args.steps,
                           log_every=args.log_every)
        print(f"done: final {last}")
        return last


if __name__ == "__main__":
    main()
