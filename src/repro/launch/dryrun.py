import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# ^ MUST precede any other import: jax locks the device count on first
#   init, and the dry-run needs 512 placeholder host devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
propagation succeeds, the compiled memory footprint fits a v5e, and the
HLO collective schedule is extractable for the roofline analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out benchmarks/results/dryrun
"""

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_arch, input_specs  # noqa: E402
from repro.configs.base import ArchConfig, ShapeConfig  # noqa: E402
from repro.dist import shardings as sh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.train.loop import TrainState, make_train_step  # noqa: E402
from repro.train.optim import AdamW  # noqa: E402

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in an HLO dump.

    Output bytes are the standard proxy: all-reduce/permute outputs equal
    inputs; all-gather outputs are the gathered (wire-crossing) size;
    reduce-scatter wire bytes are its *input*, approximated by output *
    shard-count upstream (we report both raw sums and a per-op table).
    """
    sums = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (\w[\w\-]*)\(", stripped)
        if not m:
            continue
        shapes_str, opname = m.groups()
        base = opname.rstrip("0123456789.").rstrip("-start").rstrip(".")
        hit = None
        for c in _COLLECTIVES:
            if opname.startswith(c):
                hit = c
                break
        if hit is None:
            continue
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        sums[hit] += nbytes
        counts[hit] += 1
    return {"bytes": sums, "counts": counts,
            "total_bytes": sum(sums.values())}


def _sds(tree):
    """Pytree -> ShapeDtypeStruct pytree."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (fn, args_shapes, in_shardings, out_shardings, donate)."""
    specs = input_specs(cfg, shape)
    batch_shardings = sh.batch_shardings(mesh, specs)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        mixed = sh.OPTS["bf16_params"]
        opt = AdamW(lr=1e-4, mixed_precision=mixed)
        step_fn = make_train_step(cfg, opt)
        params_shapes = jax.eval_shape(
            lambda k: opt.cast_params(lm.init_params(cfg, k)),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        p_sh = sh.params_shardings(mesh, params_shapes)
        state_shapes = TrainState(params_shapes, opt_shapes, None)
        state_sh = TrainState(
            p_sh,
            type(opt_shapes)(repl, p_sh, p_sh,
                             p_sh if mixed else None),
            None)
        metric_sh = {"loss": repl, "grad_norm": repl, "step": repl}

        def fn(state, batch):
            return step_fn(state, batch)

        return (fn, (state_shapes, specs), (state_sh, batch_shardings),
                (state_sh, metric_sh), (0,))

    params_shapes = jax.eval_shape(
        lambda k: lm.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_sh = sh.params_shardings(mesh, params_shapes)

    if shape.kind == "prefill":
        def fn(params, batch):
            return lm.prefill(cfg, params, **batch)

        cache_shapes = jax.eval_shape(
            lambda p, b: fn(p, b), params_shapes, specs)[1]
        cache_sh = sh.cache_pspec(mesh, cache_shapes)
        logits_sh = NamedSharding(
            mesh, P(sh._dp_for(mesh, shape.global_batch), "model"))
        return (fn, (params_shapes, specs), (p_sh, batch_shardings),
                (logits_sh, cache_sh), ())

    # decode
    B, S = shape.global_batch, shape.seq_len
    enc_frames = (S // cfg.frontend_frames_div) if cfg.is_encdec else 0
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, S, enc_frames))
    cache_sh = sh.cache_pspec(mesh, cache_shapes)

    def fn(params, cache, batch):
        return lm.decode_step(cfg, params, cache, batch["tokens"],
                              batch["positions"])

    logits_sh = NamedSharding(
        mesh, P(sh._dp_for(mesh, shape.global_batch), "model"))
    return (fn, (params_shapes, cache_shapes, specs),
            (p_sh, cache_sh, batch_shardings),
            (logits_sh, cache_sh), (1,))


def _cell_costs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Dict:
    """Lower+compile one config; return flops/bytes/collectives."""
    with sh.use_mesh(mesh):
        fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def extrapolate_scan_costs(cfg: ArchConfig, shape: ShapeConfig, mesh
                           ) -> Dict:
    """XLA's cost_analysis counts a while(scan-over-layers) body ONCE.

    Recover true totals by the 2-point fit: lower the same step with 1
    and 2 layers; body = f(2) - f(1), outside = f(1) - body, total =
    outside + L * body. Applied to FLOPs, bytes and collective bytes.
    """
    import dataclasses as dc

    from repro.models import scan_utils as SU
    L = cfg.n_layers
    kw1 = {"n_layers": 1}
    kw2 = {"n_layers": 2}
    if cfg.is_encdec:
        kw1["n_enc_layers"] = 1
        kw2["n_enc_layers"] = 2
    with SU.unrolled():  # expose true per-iteration costs to cost_analysis
        c1 = _cell_costs(dc.replace(cfg, **kw1), shape, mesh)
        c2 = _cell_costs(dc.replace(cfg, **kw2), shape, mesh)

    def fit(a, b):
        body = max(b - a, 0.0)
        outside = max(a - body, 0.0)
        return outside + L * body

    coll_fit = {}
    for key in c1["coll"]["bytes"]:
        coll_fit[key] = fit(c1["coll"]["bytes"][key],
                            c2["coll"]["bytes"][key])
    return {
        "flops_per_device": fit(c1["flops"], c2["flops"]),
        "bytes_accessed_per_device": fit(c1["bytes"], c2["bytes"]),
        "collective_bytes": coll_fit,
        "collective_total_bytes": sum(coll_fit.values()),
        "fit_points": {"L1": c1, "L2": c2},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: Optional[str] = None) -> Dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full-attention arch at 500k context "
                          "(see DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with sh.use_mesh(mesh):
        fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    n_dev = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1.0)),
        "collectives": coll,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                result[k] = int(v)
    # true per-step totals (scan bodies re-multiplied by trip count)
    result["extrapolated"] = extrapolate_scan_costs(cfg, shape, mesh)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opts", default="",
                    help="comma list of sharding-strategy knobs: "
                         "seq_parallel,serve_tp_only,moe_ep "
                         "(EXPERIMENTS.md §Perf)")
    args = ap.parse_args()
    if args.opts:
        sh.set_opts(**{k: True for k in args.opts.split(",") if k})

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e)}
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "ok":
                    print(f"  ok: flops/dev={res['flops_per_device']:.3e} "
                          f"coll={res['collectives']['total_bytes']:.3e}B "
                          f"compile={res['compile_s']}s", flush=True)
                elif res["status"] == "skipped":
                    print(f"  skipped: {res['reason']}")
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("all requested dry-run cells passed")


if __name__ == "__main__":
    main()
