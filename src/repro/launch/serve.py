"""Serving launcher: logic-network classification or LM decode.

  # paper's product: compiled fixed-function logic serving
  PYTHONPATH=src python -m repro.launch.serve --mode logic --jsc jsc-s

  # continuous-batching LM decode on a smoke config
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch glm4-9b \
      --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch


def serve_logic(jsc_name: str, train_steps: int, n_requests: int,
                use_pallas: bool, backend: str = "gather"):
    from repro.configs.jsc import JSC
    from repro.data.jsc import train_test
    from repro.models.mlp import to_logic
    from repro.serving.engine import LogicEngine
    from repro.train.jsc_trainer import train_jsc

    cfg = JSC[jsc_name]
    print(f"[serve] training {jsc_name} with QAT+FCP ({train_steps} steps)")
    res = train_jsc(cfg, steps=train_steps)
    print(f"  test acc: {res.test_acc:.4f}")
    print("[serve] compiling to fixed-function logic ...")
    net = to_logic(cfg, res.params, res.masks, res.bn_state)
    if backend == "bitplane":
        print("[serve] synthesizing mapped 6-LUT netlist (repro.synth) ...")
    eng = LogicEngine(net, cfg.n_classes, use_pallas=use_pallas,
                      backend=backend)
    if backend == "bitplane":
        print(f"  mapped: {eng.bitnet.mapped.n_luts} LUTs, "
              f"depth {eng.bitnet.mapped.depth}")
    (_, _), (xte, yte) = train_test()
    reqs = [xte[i * 64: (i + 1) * 64] for i in range(n_requests)]
    results, stats = eng.serve_queue(reqs)
    acc = float(np.mean(np.concatenate(results)
                        == yte[: sum(len(r) for r in reqs)]))
    print(f"[serve] {n_requests} requests: acc={acc:.4f} "
          f"p50={stats['p50_us']:.1f}us p95={stats['p95_us']:.1f}us")
    return stats


def serve_lm(arch: str, smoke: bool, n_requests: int, max_new: int):
    from repro.models import lm
    from repro.serving.engine import LMEngine, LMRequest

    cfg = get_arch(arch, smoke=smoke)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = LMEngine(cfg, params, n_slots=4, max_seq=256)
    rng = np.random.default_rng(0)
    reqs = [LMRequest(prompt=rng.integers(0, cfg.vocab_size, 32,
                                          dtype=np.int32),
                      max_new_tokens=max_new) for _ in range(n_requests)]
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s)")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["logic", "lm"], default="logic")
    ap.add_argument("--jsc", default="jsc-s")
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--backend", choices=["gather", "pallas", "bitplane"],
                    default="gather",
                    help="logic inference path (bitplane = mapped netlist)")
    args = ap.parse_args(argv)
    if args.mode == "logic":
        serve_logic(args.jsc, args.train_steps, args.requests, args.pallas,
                    backend=args.backend)
    else:
        serve_lm(args.arch, args.smoke, args.requests, args.max_new)


if __name__ == "__main__":
    main()
