"""Serving launcher: logic-network classification or LM decode.

  # paper's product: compiled fixed-function logic serving
  PYTHONPATH=src python -m repro.launch.serve --mode logic --jsc jsc-s

  # async micro-batching scheduler with 2 replicas under open-loop load,
  # mapped netlist executed on-device via the kernels/lut_eval kernel
  PYTHONPATH=src python -m repro.launch.serve --mode logic --sched \
      --replicas 2 --loadgen open --qps 20000 --backend bitplane \
      --engine pallas

  # continuous-batching LM decode on a smoke config
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch glm4-9b \
      --smoke --requests 8
"""
from __future__ import annotations

import argparse
import os
import sys

import jax
import numpy as np

from repro.configs import get_arch

# benchmarks/ lives at the repo root, one level above src/
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def serve_logic(jsc_name: str, train_steps: int, n_requests: int,
                use_pallas: bool, backend: str = "gather",
                engine: str = "numpy", sched: bool = False,
                replicas: int = 1, qps: float = None, loadgen: str = None,
                slo_us: tuple = None, check: bool = False,
                trace: str = None, metrics_port: int = None):
    from repro.configs.jsc import JSC
    from repro.data.jsc import train_test
    from repro.models.mlp import to_logic
    from repro.serving.engine import LogicEngine
    from repro.train.jsc_trainer import train_jsc

    cfg = JSC[jsc_name]
    print(f"[serve] training {jsc_name} with QAT+FCP ({train_steps} steps)")
    res = train_jsc(cfg, steps=train_steps)
    print(f"  test acc: {res.test_acc:.4f}")
    print("[serve] compiling to fixed-function logic ...")
    net = to_logic(cfg, res.params, res.masks, res.bn_state)
    if backend == "bitplane":
        print(f"[serve] synthesizing mapped 6-LUT netlist (repro.synth, "
              f"engine={engine}) ...")
    eng = LogicEngine(net, cfg.n_classes, use_pallas=use_pallas,
                      backend=backend, engine=engine)
    if backend == "bitplane":
        print(f"  mapped: {eng.bitnet.mapped.n_luts} LUTs, "
              f"depth {eng.bitnet.mapped.depth}")
    if check:
        # preflight: refuse to serve a netlist that fails lint, plan
        # validation, or the valid-code equivalence spot-check
        from repro.check import preflight
        if backend != "bitplane":
            print("[serve] --check: nothing to verify for backend "
                  f"{backend!r} (mapped-netlist checks need --backend "
                  f"bitplane)")
        else:
            rep = preflight(eng.bitnet)
            print(rep.format())
            if not rep.ok:
                raise SystemExit(2)
    (_, _), (xte, yte) = train_test()

    # pull-based metrics endpoint (Prometheus text exposition on
    # /metrics, raw snapshot on /metrics.json), alive for the duration
    # of the serving run; daemon thread, so an exception path cannot
    # wedge process exit
    mserver = None
    registry = None
    if metrics_port is not None:
        from repro.obs import MetricsRegistry, MetricsServer
        registry = MetricsRegistry()
        mserver = MetricsServer(registry, port=metrics_port)
        print(f"[serve] metrics endpoint: {mserver.url}")

    if loadgen:                         # full benchmark harness
        if _REPO_ROOT not in sys.path:
            sys.path.insert(0, _REPO_ROOT)
        from benchmarks import loadgen as lg
        out = lg.run(fast=True, backends=(backend,), n_requests=n_requests,
                     qps=qps, loadgen=loadgen, n_replicas=replicas,
                     steps=train_steps, engine=engine, slo_us=slo_us,
                     trace=trace, registry=registry)
        rec = out["backends"][backend]
        mode = "open_loop" if "open_loop" in rec else "closed_loop"
        print(f"[serve] {mode}: {rec[mode]['qps']:.0f} qps "
              f"p95={rec[mode]['p95_us']:.1f}us "
              f"occ={rec[mode]['mean_batch_occupancy']:.2f}")
        if "slo_lanes" in rec:
            for lane, lr in rec["slo_lanes"]["lanes"].items():
                print(f"[serve] slo lane {lane} "
                      f"({rec['slo_lanes']['slo_us'][int(lane)]:.0f}us): "
                      f"attainment={lr['slo_attainment']:.3f} "
                      f"miss_rate={lr['deadline_miss_rate']:.3f} "
                      f"shed={lr['shed']} p99={lr['p99_us']:.0f}us")
        if mserver is not None:
            mserver.close()
        return rec

    tracer = None
    if trace:
        from repro.obs import SpanTracer
        tracer = SpanTracer()

    if sched:                           # scheduler + replica dispatch
        from repro.serve import (MicroBatchScheduler, RequestRejected,
                                 SchedConfig, build_logic_replicas)
        executor = eng.scheduler_executor()
        if replicas > 1:                # independent data-parallel engines
            executor = build_logic_replicas(
                net, cfg.n_classes, n_replicas=replicas, backend=backend,
                max_batch=eng.max_batch,
                policy="least_slack" if slo_us else "least_loaded",
                engine=engine)
        s = MicroBatchScheduler(
            executor, SchedConfig(max_batch=eng.max_batch,
                                  max_queue=4 * n_requests * 64,
                                  n_priorities=max(2, len(slo_us or ())),
                                  lane_slo_us=slo_us),
            tracer=tracer)
        if registry is not None:        # live pull endpoint content
            from repro.obs import WindowedMetrics
            s.metrics.publish(registry, "serve")
            if hasattr(executor, "publish"):
                executor.publish(registry)
            wm = WindowedMetrics()
            s.metrics.add_sink(wm)
            wm.publish(registry, "windows")
        s.start()
        futs = [s.submit(xte[i % xte.shape[0]])
                for i in range(n_requests * 64)]
        s.stop(drain=True)
        got = np.full((len(futs),), -1, np.int32)
        for i, f in enumerate(futs):
            try:
                got[i] = int(f.result(timeout=30))
            except RequestRejected:
                pass                    # shed past its lane SLO
        served = got >= 0
        acc = float(np.mean(
            got[served] == yte[np.arange(len(got)) % yte.shape[0]][served]
        )) if served.any() else 0.0
        snap = s.metrics.snapshot()
        if tracer is not None:
            _export_trace(trace, tracer, s, executor)
        print(f"[serve] sched x{replicas}: {len(futs)} requests "
              f"acc={acc:.4f} p50={snap['p50_us']:.1f}us "
              f"p95={snap['p95_us']:.1f}us qps={snap['qps']:.0f} "
              f"occ={snap['mean_batch_occupancy']:.2f} "
              f"shed={snap['shed']} "
              f"miss_rate={snap['deadline_miss_rate']:.3f}")
        if mserver is not None:
            mserver.close()
        return snap

    reqs = [xte[i * 64: (i + 1) * 64] for i in range(n_requests)]
    results, stats = eng.serve_queue(reqs, tracer=tracer)
    if tracer is not None:
        from repro.obs import write_chrome_trace
        write_chrome_trace(trace, tracer)
        print(f"[serve] trace: {tracer.n_recorded} events -> {trace}")
    acc = float(np.mean(np.concatenate(results)
                        == yte[: sum(len(r) for r in reqs)]))
    print(f"[serve] {n_requests} requests: acc={acc:.4f} "
          f"p50={stats['p50_us']:.1f}us p95={stats['p95_us']:.1f}us")
    if mserver is not None:
        mserver.close()
    return stats


def _export_trace(path: str, tracer, sched, executor) -> None:
    """Write the Chrome trace with a full metrics-registry snapshot as
    ``otherData`` (scheduler metrics + replica/aggregator stats)."""
    from repro.obs import MetricsRegistry, write_chrome_trace

    reg = MetricsRegistry()
    sched.metrics.publish(reg, "serve")
    if hasattr(executor, "publish"):
        executor.publish(reg)
    write_chrome_trace(path, tracer, other_data=reg.snapshot())
    print(f"[serve] trace: {tracer.n_recorded} events "
          f"({tracer.n_dropped} dropped) -> {path}")


def serve_lm(arch: str, smoke: bool, n_requests: int, max_new: int):
    from repro.models import lm
    from repro.serve.clock import SystemClock
    from repro.serving.engine import LMEngine, LMRequest

    clock = SystemClock()
    cfg = get_arch(arch, smoke=smoke)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = LMEngine(cfg, params, n_slots=4, max_seq=256, clock=clock)
    rng = np.random.default_rng(0)
    reqs = [LMRequest(prompt=rng.integers(0, cfg.vocab_size, 32,
                                          dtype=np.int32),
                      max_new_tokens=max_new) for _ in range(n_requests)]
    t0_us = clock.now_us()
    done = eng.run(reqs)
    dt = (clock.now_us() - t0_us) * 1e-6
    tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s)")
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["logic", "lm"], default="logic")
    ap.add_argument("--jsc", default="jsc-s")
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--backend", choices=["gather", "pallas", "bitplane"],
                    default="gather",
                    help="logic inference path (bitplane = mapped netlist)")
    from repro.synth.executors import names as engine_names
    ap.add_argument("--engine", choices=list(engine_names()),
                    default="numpy",
                    help="bitplane netlist executor from the "
                         "repro.synth.executors registry (host fold, "
                         "monolithic kernels/lut_eval, or the streamed/"
                         "tiled pallas-streamed pipeline)")
    ap.add_argument("--sched", action="store_true",
                    help="serve through the repro.serve micro-batch "
                         "scheduler instead of the blocking loop")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind the "
                         "scheduler (least-loaded dispatch)")
    ap.add_argument("--qps", type=float, default=None,
                    help="offered open-loop arrival rate for --loadgen")
    ap.add_argument("--loadgen", choices=["open", "closed", "both"],
                    default=None,
                    help="drive the scheduler with the benchmarks/"
                         "loadgen.py harness and report p50/p95/p99+QPS")
    ap.add_argument("--slo-us", default=None,
                    help="comma list of per-lane SLO deadline budgets in "
                         "µs (lane 0 first, e.g. '100,1000'); requests "
                         "past their lane budget are shed with a typed "
                         "DEADLINE_EXCEEDED reject")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the request lifecycle with repro.obs and "
                         "write a Chrome trace-event JSON (open in "
                         "ui.perfetto.dev) with the metrics-registry "
                         "snapshot embedded as otherData")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve a pull-based metrics endpoint on this "
                         "port for the duration of the run: Prometheus "
                         "text exposition on /metrics, raw registry "
                         "snapshot on /metrics.json (0 = ephemeral port, "
                         "printed at startup)")
    ap.add_argument("--check", action="store_true",
                    help="repro.check preflight before serving (bitplane "
                         "backend): netlist lint, DevicePlan validation, "
                         "mapped<->plan miter, valid-code equivalence; "
                         "exit 2 on any error")
    args = ap.parse_args(argv)
    slo_us = (tuple(float(v) for v in args.slo_us.split(","))
              if args.slo_us else None)
    if args.mode == "logic":
        serve_logic(args.jsc, args.train_steps, args.requests, args.pallas,
                    backend=args.backend, engine=args.engine,
                    sched=args.sched, replicas=args.replicas, qps=args.qps,
                    loadgen=args.loadgen, slo_us=slo_us, check=args.check,
                    trace=args.trace, metrics_port=args.metrics_port)
    else:
        serve_lm(args.arch, args.smoke, args.requests, args.max_new)


if __name__ == "__main__":
    main()
