"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state). Single pod: (data=16, model=16) = 256 chips
(TPU v5e pod slice); multi-pod: (pod=2, data=16, model=16) = 512 chips,
where the ``pod`` axis extends data parallelism across the DCN/ICI
boundary.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run launcher "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older jax.make_mesh without devices kwarg
        from jax.sharding import Mesh
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model_par: int = 1):
    """Tiny mesh over the real local devices (tests / examples)."""
    import jax
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices())
    n = len(devs)
    dp = n // model_par
    return Mesh(devs[: dp * model_par].reshape(dp, model_par),
                ("data", "model"))
