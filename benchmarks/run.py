"""Benchmark driver — one function per paper table/figure + repo extras.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = host wall
time where measured; hardware-model metrics land in the derived column)
and, per section, writes a machine-readable ``BENCH_<section>.json`` at
the repo root so the perf trajectory is tracked across PRs.

  table1   — paper Table I: JSC-S/M/L accuracy + measured (repro.synth)
             and modeled LUT/FF/fmax vs the LogicNets baseline
  latency  — logic path vs dense float vs XNOR, µs/call
  ablation — activation-selection + FCP-schedule ablations
  kernels  — Pallas kernel microbenchmarks vs oracles
  serve    — repro.serve scheduler loadgen vs legacy sequential serving
  roofline — dry-run derived roofline table (if results exist)
"""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_ROWS: dict = {}


def _emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
    section = name.split("/", 1)[0]
    _ROWS.setdefault(section, []).append(
        {"name": name, "us_per_call": round(us, 1), "derived": derived})


def _write_bench_json(all_results: dict) -> None:
    """One BENCH_<section>.json per section at the repo root: the CSV rows
    plus that section's full result object (derived metrics), stamped
    with a run-provenance ``meta`` block (ignored by the regression
    differ, which reads only ``rows``/``results``)."""
    from benchmarks.meta import bench_meta

    meta = bench_meta()
    for section, rows in _ROWS.items():
        path = os.path.join(REPO_ROOT, f"BENCH_{section}.json")
        with open(path, "w") as f:
            json.dump({"section": section, "meta": meta, "rows": rows,
                       "results": all_results.get(section)},
                      f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,latency,ablation,kernels,"
                         "serve,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="fewer training steps (CI mode)")
    ap.add_argument("--history", action="store_true",
                    help="append this run's BENCH_*.json metrics to the "
                         "benchmarks/results/history.jsonl trajectory "
                         "ledger (idempotent per git sha + timestamp)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    os.makedirs(RESULTS_DIR, exist_ok=True)
    all_results = {}

    def want(x):
        return only is None or x in only

    print("name,us_per_call,derived")

    if want("table1"):
        from benchmarks import table1_jsc
        t0 = time.time()
        res = table1_jsc.run(steps=300 if args.fast else 1200)
        all_results["table1"] = res
        for k, r in res.items():
            _emit(f"table1/{k}", (time.time() - t0) * 1e6 / 3,
                  f"acc={r['accuracy']:.4f};luts={r['nullanet']['luts']};"
                  f"luts_backend={r['nullanet']['backend']};"
                  f"luts_model={r['nullanet_model']['luts']};"
                  f"depth={r['nullanet']['depth']};"
                  f"synth_equiv={r['synth']['equivalent']};"
                  f"lut_red={r['lut_reduction_x']}x;"
                  f"fmax={r['nullanet']['fmax_mhz']}MHz;"
                  f"lat_red={r['latency_reduction_x']}x")

    if want("latency"):
        from benchmarks import latency
        res = latency.run(steps=200 if args.fast else 600)
        all_results["latency"] = res
        _emit("latency/logic", res["logic_us"],
              f"dense={res['dense_float_us']:.0f}us;"
              f"speedup={res['logic_vs_dense_x']}x")

    if want("ablation"):
        from benchmarks import ablations
        res = ablations.run()
        all_results["ablation"] = res
        _emit("ablation/act", 0.0, json.dumps(res["activation_selection"]))
        _emit("ablation/fcp", 0.0, json.dumps(res["fcp_schedule"]))

    if want("kernels"):
        from benchmarks import kernels_bench
        res = kernels_bench.run()
        all_results["kernels"] = res
        for k, v in res.items():
            _emit(f"kernels/{k}", v, "interpret-mode")

    if want("serve"):
        from benchmarks import loadgen
        res = loadgen.run(fast=args.fast, write_json=False)
        all_results["serve"] = res
        base = res["baseline_sequential"]
        _emit("serve/sequential", base["p95_us"],
              f"qps={base['qps']:.0f};p50={base['p50_us']:.0f}us;"
              f"service_p95={base['service_p95_us']:.0f}us")
        for b, rec in res["backends"].items():
            for mode, r in rec.items():
                if not isinstance(r, dict):  # per-backend metadata (engine)
                    continue
                _emit(f"serve/{b}/{mode}", r["p95_us"],
                      f"qps={r['qps']:.0f};p50={r['p50_us']:.0f}us;"
                      f"p99={r['p99_us']:.0f}us;"
                      f"occ={r['mean_batch_occupancy']:.2f};"
                      f"identical={r['identical_to_classify']}"
                      + (f";speedup={r['throughput_x_sequential']}x"
                         if "throughput_x_sequential" in r else ""))

    if want("roofline"):
        from benchmarks import roofline
        rows = roofline.run()
        all_results["roofline"] = rows
        for r in rows:
            if r["mesh"] == "single":
                _emit(f"roofline/{r['arch']}/{r['shape']}",
                      max(r['t_compute_s'], r['t_memory_s'],
                          r['t_collective_s']) * 1e6,
                      f"dom={r['dominant']};"
                      f"roofline={100*r['roofline_fraction']:.1f}%")

    with open(os.path.join(RESULTS_DIR, "bench_results.json"), "w") as f:
        json.dump(all_results, f, indent=1, default=str)
    _write_bench_json(all_results)
    if args.history:
        from benchmarks import history
        for section in _ROWS:
            path = os.path.join(REPO_ROOT, f"BENCH_{section}.json")
            entry = history.append_file(path)
            if entry is not None:
                print(f"[bench] history: {section} -> "
                      f"{len(entry['metrics'])} metric(s) appended")


if __name__ == "__main__":
    main()
