"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle, µs/call.

Interpret-mode timings on CPU are NOT TPU performance; the derived
column reports the work size (elements or MACs) so roofline reasoning
stays attached to each number.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def _t(fn, *args, iters=20) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> Dict:
    rng = np.random.default_rng(0)
    out = {}

    # lut_layer: JSC-M-ish layer
    from repro.kernels.lut_layer import lut_layer, lut_layer_ref
    B, n_in, N, K, L = 256, 64, 64, 4, 4
    codes = jnp.asarray(rng.integers(0, L, (B, n_in)), jnp.int32)
    idx = jnp.asarray(rng.integers(0, n_in, (N, K)), jnp.int32)
    tables = jnp.asarray(rng.integers(0, L, (N, L ** K)), jnp.int32)
    f_ref = jax.jit(lambda c: lut_layer_ref(c, idx, tables, L))
    f_pal = jax.jit(lambda c: lut_layer(c, idx, tables, L))
    out["lut_layer_ref_us"] = _t(f_ref, codes)
    out["lut_layer_pallas_us"] = _t(f_pal, codes)

    # aig_sim: bit-parallel simulation of a random-logic AIG, 8k samples
    from repro.kernels.aig_sim import aig_sim, aig_sim_ref
    from repro.synth import AIG
    from repro.synth.from_sop import table_to_aig
    n_vars = 8
    aig = AIG(n_vars)
    aig.outputs = [
        table_to_aig(aig, rng.random(1 << n_vars) < 0.5, None,
                     [2 * (i + 1) for i in range(n_vars)])
        for _ in range(4)]
    f0, f1 = aig.fanin_arrays()
    words = jnp.asarray(rng.integers(0, 1 << 31, (n_vars, 256)), jnp.int32)
    f0j, f1j = jnp.asarray(f0), jnp.asarray(f1)
    out["aig_sim_ref_us"] = _t(
        jax.jit(lambda w: aig_sim_ref(w, f0j, f1j, n_vars)), words)
    out["aig_sim_pallas_us"] = _t(
        lambda w: aig_sim(np.asarray(w).view(np.uint32), f0, f1, n_vars),
        words, iters=3)

    # lut_eval: whole mapped-netlist execution, 8k samples — the bitplane
    # Shannon fold (numpy host / jnp scan oracle / Pallas kernel) vs the
    # per-sample table-gather path on the same netlist
    from repro.kernels.lut_eval import lut_eval, lut_eval_gather_ref, lut_eval_ref
    from repro.synth import compile_device_plan, synthesize
    from repro.synth.executor import _compile_plan, execute_packed
    from repro.synth.simulate import unpack_bits
    n_vars = 10
    aig2 = AIG(n_vars)
    aig2.outputs = [
        table_to_aig(aig2, rng.random(1 << n_vars) < 0.5, None,
                     [2 * (i + 1) for i in range(n_vars)])
        for _ in range(4)]
    mapped = synthesize(aig2)
    plan = _compile_plan(mapped)
    dp = compile_device_plan(mapped, plan)
    lwords = rng.integers(0, 1 << 32, (n_vars, 256), dtype=np.uint32)
    out["lut_eval_numpy_us"] = _t(
        lambda w: execute_packed(mapped, w, plan=plan), lwords)
    flat_leaf = jnp.asarray(dp.leaf_idx.reshape(-1, dp.k), jnp.int32)
    flat_tt = jnp.asarray(np.ascontiguousarray(
        dp.tt_bits.reshape(-1, 1 << dp.k)).view(np.int32))
    flat_ow = jnp.asarray(dp.out_wires.reshape(-1), jnp.int32)
    out["lut_eval_ref_us"] = _t(
        jax.jit(lambda w: lut_eval_ref(w, flat_leaf, flat_tt, flat_ow,
                                       dp.n_pis, dp.n_wires)),
        jnp.asarray(lwords.view(np.int32)))
    out["lut_eval_pallas_us"] = _t(
        lambda w: lut_eval(w, dp.leaf_idx, dp.tt_bits, dp.out_wires,
                           n_pis=dp.n_pis, n_wires=dp.n_wires),
        lwords, iters=3)
    # streamed/tiled kernel: same netlist through the TilePlan route
    # (HBM-resident wire plane, double-buffered per-tile plan tensors)
    from repro.kernels.lut_eval import lut_eval_streamed
    from repro.synth import compile_tile_plan
    tp = compile_tile_plan(plan, dp.n_pis, dp.k)
    out["lut_eval_streamed_us"] = _t(
        lambda w: lut_eval_streamed(w, tp), lwords, iters=5)
    # dimensionless cross-kernel ratios (direction-aware CI gates; the
    # *_us rows drift with host load, the ratios should not)
    out["lut_eval_streamed_vs_pallas_x"] = (
        out["lut_eval_streamed_us"] / out["lut_eval_pallas_us"])
    out["aig_sim_pallas_vs_ref_x"] = (
        out["aig_sim_pallas_us"] / out["aig_sim_ref_us"])
    lbits = jnp.asarray(unpack_bits(lwords, 256 * 32), jnp.int32)
    tt01 = jnp.asarray((dp.tt_bits & 1).astype(np.int32))
    li, ow = jnp.asarray(dp.leaf_idx), jnp.asarray(dp.out_wires)
    out["lut_eval_gather_us"] = _t(
        jax.jit(lambda b: lut_eval_gather_ref(b, li, tt01, ow,
                                              dp.n_pis, dp.n_wires)), lbits)

    # xnor: 256x4096 @ 4096x256
    from repro.kernels.xnor_popcount import (pack_bipolar, xnor_matmul,
                                             xnor_matmul_ref)
    x = jnp.asarray(rng.choice([-1.0, 1.0], (256, 4096)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (256, 4096)), jnp.float32)
    out["xnor_ref_us"] = _t(jax.jit(xnor_matmul_ref), x, w)
    out["xnor_pallas_us"] = _t(jax.jit(xnor_matmul), x, w)

    # fanin_matmul: FCP layer 256 x (4096 -> 1024, K=8)
    from repro.kernels.fanin_matmul import fanin_matmul, fanin_matmul_ref
    xb = jnp.asarray(rng.normal(size=(256, 4096)), jnp.float32)
    idxb = jnp.asarray(rng.integers(0, 4096, (1024, 8)), jnp.int32)
    wb = jnp.asarray(rng.normal(size=(1024, 8)), jnp.float32)
    bias = jnp.zeros((1024,), jnp.float32)
    out["fanin_ref_us"] = _t(jax.jit(fanin_matmul_ref), xb, idxb, wb, bias)
    out["fanin_pallas_us"] = _t(jax.jit(fanin_matmul), xb, idxb, wb, bias)
    # dense equivalent cost at same shapes (what FCP saves)
    wd = jnp.asarray(rng.normal(size=(1024, 4096)), jnp.float32)
    out["fanin_dense_us"] = _t(jax.jit(lambda x: x @ wd.T), xb)

    # flash attention: 1k context, 4 heads
    from repro.kernels.flash_attention import flash_attention
    from repro.models.layers import full_attention
    q = jnp.asarray(rng.normal(size=(1, 1024, 4, 64)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.float32)
    out["flash_ref_us"] = _t(jax.jit(
        lambda q, k, v: full_attention(q, k, v, causal=True)), q, kk, vv)
    out["flash_pallas_us"] = _t(jax.jit(
        lambda q, k, v: flash_attention(q, k, v)), q, kk, vv, iters=3)

    for k, v in out.items():
        print(f"[kernels] {k}: {v:.1f}")
    return out


if __name__ == "__main__":
    run()
