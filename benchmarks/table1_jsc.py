"""Table I reproduction: NullaNet Tiny vs LogicNets on JSC-S/M/L.

Per architecture:
  * train with the paper's flow (QAT w/ per-layer activation selection +
    gradual FCP), compile to logic, espresso+DC minimize, map to 6-LUTs;
  * report BOTH LUT/depth numbers: the *measured* structural mapping
    from ``repro.synth`` (AIG -> rewrite -> FlowMap-style 6-LUT cover)
    and the analytic cost model it replaces (kept as a comparison
    column), plus a random-simulation equivalence check of the mapped
    whole-network netlist against the truth-table oracle;
  * the LogicNets baseline maps the SAME trained truth tables without
    two-level minimization (raw LUT-RAM cascades), matching how LogicNets
    realises neurons;
  * report accuracy, LUTs, FFs, fmax and the NullaNet/LogicNets ratios —
    the paper's claim structure (Dec. x / Inc. x columns).

Synthetic-data caveat (DESIGN.md §7): absolute accuracy differs from the
paper; the reproduced quantities are the ratios and orderings.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

from repro.configs.jsc import JSC
from repro.core.logic_infer import hardware_report
from repro.core.lutmap import structural_report
from repro.data.jsc import train_test
from repro.models.mlp import to_logic
from repro.train.jsc_trainer import train_jsc


def _synth_equivalence(net, n_samples: int = 4096, seed: int = 0) -> Dict:
    """Compile the whole network through repro.synth and check the mapped
    netlist against the truth-table oracle on random *reachable* inputs
    (bit-exact decoded outputs, packed-bitplane execution)."""
    import jax.numpy as jnp
    from repro.synth import compile_logic_network

    t0 = time.time()
    bit = compile_logic_network(net, effort=1)
    t_compile = time.time() - t0
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0.0, 2.0, (n_samples, net.n_inputs)),
                    jnp.float32)
    ref = np.asarray(net(x))
    t0 = time.time()
    got = bit(x)
    t_exec = time.time() - t0
    # SAT sweep: measured (proven) duplicate-LUT savings on the mapped net
    from repro.check.sat import (find_duplicate_lut_outputs,
                                 merge_duplicate_lut_outputs)
    t0 = time.time()
    pairs, _ = find_duplicate_lut_outputs(bit.mapped, seed=seed)
    swept = merge_duplicate_lut_outputs(bit.mapped, pairs)
    t_sweep = time.time() - t0
    return {
        "equivalent": bool(np.array_equal(got, ref)),
        "luts": bit.mapped.n_luts,
        "depth": bit.mapped.depth,
        "n_samples": n_samples,
        "compile_seconds": round(t_compile, 1),
        "exec_us_per_call": round(t_exec * 1e6, 1),
        "sat_sweep": {
            "dup_lut_outputs": len(pairs),
            "luts_after_sweep": swept.n_luts,
            "sweep_seconds": round(t_sweep, 1),
        },
    }


def _logicnets_cfg(cfg):
    """LogicNets-style realisation of the same topology.

    LogicNets (like its published JSC configs) spends MORE bits per
    neuron input to reach comparable accuracy without NullaNet Tiny's
    QAT machinery, and maps each neuron's raw truth table (no two-level
    minimization, no don't-cares). We model it as the same topology at
    +1 bit everywhere — which indeed trains to slightly HIGHER accuracy
    (paper Table I: LogicNets is 1.5-1.9 pts BELOW NullaNet instead;
    our synthetic task flips the small accuracy delta, see
    EXPERIMENTS.md) — and charge the LUT-RAM cascade for its
    fanin x bits-wide tables.
    """
    import dataclasses
    return dataclasses.replace(
        cfg, in_bits=cfg.in_bits + 1,
        act_bits=tuple(b + 1 for b in cfg.act_bits))


def run_one(name: str, steps: int = 1200, seed: int = 0) -> Dict:
    cfg = JSC[name]
    data = train_test(20000, 5000, seed)
    res = train_jsc(cfg, steps=steps, seed=seed, data=data)
    net = to_logic(cfg, res.params, res.masks, res.bn_state)

    t0 = time.time()
    mini, _ = hardware_report(net, minimize_logic=True)
    t_min = time.time() - t0

    # measured structural mapping (repro.synth) alongside the model
    t0 = time.time()
    meas, _, meas_backend = structural_report(net)
    t_synth = time.time() - t0
    equiv = _synth_equivalence(net)

    # LogicNets-style: +1-bit network, raw-table mapping
    ln_cfg = _logicnets_cfg(cfg)
    ln_res = train_jsc(ln_cfg, steps=steps, seed=seed, data=data)
    ln_net = to_logic(ln_cfg, ln_res.params, ln_res.masks, ln_res.bn_state)
    base, _ = hardware_report(ln_net, minimize_logic=False)

    n_stages = cfg.n_layers + 1  # per-layer pipeline + output reg
    lat_nn = n_stages * 1e3 / meas.fmax_mhz
    lat_ln = n_stages * 1e3 / base.fmax_mhz
    return {
        "arch": name,
        "accuracy": res.test_acc,
        "float_accuracy": res.float_test_acc,
        "logicnets_accuracy": ln_res.test_acc,
        "nullanet": {"luts": meas.luts, "depth": meas.depth,
                     "ffs": meas.ffs,
                     "fmax_mhz": round(meas.fmax_mhz, 1),
                     "latency_ns": round(lat_nn, 2),
                     "backend": meas_backend},
        "nullanet_model": {"luts": mini.luts, "depth": mini.depth,
                           "ffs": mini.ffs,
                           "fmax_mhz": round(mini.fmax_mhz, 1)},
        "synth": equiv,
        "logicnets_baseline": {"luts": base.luts, "ffs": base.ffs,
                               "fmax_mhz": round(base.fmax_mhz, 1),
                               "latency_ns": round(lat_ln, 2)},
        "lut_reduction_x": round(base.luts / max(meas.luts, 1), 2),
        "fmax_increase_x": round(meas.fmax_mhz / base.fmax_mhz, 2),
        "latency_reduction_x": round(lat_ln / max(lat_nn, 1e-9), 2),
        "minimize_seconds": round(t_min, 1),
        "synth_seconds": round(t_synth, 1),
    }


def run(steps: int = 1200) -> Dict:
    out = {}
    for name in ("jsc-s", "jsc-m", "jsc-l"):
        out[name] = run_one(name, steps=steps)
        r = out[name]
        print(f"[table1] {name}: acc={r['accuracy']:.4f} "
              f"(LN {r['logicnets_accuracy']:.4f}, "
              f"float {r['float_accuracy']:.4f}) "
              f"LUTs {r['nullanet']['luts']} "
              f"(model {r['nullanet_model']['luts']}) "
              f"vs {r['logicnets_baseline']['luts']} "
              f"({r['lut_reduction_x']}x) "
              f"depth {r['nullanet']['depth']} "
              f"fmax {r['nullanet']['fmax_mhz']}MHz "
              f"({r['fmax_increase_x']}x) "
              f"lat ({r['latency_reduction_x']}x) "
              f"equiv={r['synth']['equivalent']}", flush=True)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
