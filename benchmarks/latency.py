"""Inference latency: compiled-logic path vs dense float vs XNOR path.

The paper's headline is ultra-low latency. On the FPGA that is the LUT
pipeline (modelled in table1_jsc); here we ALSO measure the TPU-analogue
execution paths in µs/call on this host (CPU; indicative, not TPU
timings) — logic-gather vs dense-bf16 MLP vs packed XNOR matmul at the
same topology.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.jsc import JSC
from repro.data.jsc import train_test
from repro.models.mlp import to_logic
from repro.train.jsc_trainer import train_jsc


def _time_call(fn, *args, iters: int = 50) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(steps: int = 600, batch: int = 256) -> Dict:
    cfg = JSC["jsc-s"]
    data = train_test(10000, 2000)
    res = train_jsc(cfg, steps=steps, data=data)
    net = to_logic(cfg, res.params, res.masks, res.bn_state)
    x = jnp.asarray(data[1][0][:batch])

    logic_fn = jax.jit(lambda x: net(x))
    pallas_fn = jax.jit(lambda x: net(x, use_pallas=True))

    # dense float reference at the same topology
    ws = [(jnp.asarray(np.random.randn(o, i), jnp.float32))
          for i, o in zip((cfg.n_inputs,) + cfg.features, cfg.features)]

    @jax.jit
    def dense_fn(x):
        h = x
        for w in ws:
            h = jax.nn.relu(h @ w.T)
        return h

    # packed XNOR path (binary-QAT inference primitive)
    from repro.kernels.xnor_popcount import xnor_matmul
    wq = jnp.sign(ws[0])

    @jax.jit
    def xnor_fn(x):
        return xnor_matmul(jnp.sign(x), wq)

    out = {
        "logic_us": _time_call(logic_fn, x),
        "logic_pallas_us": _time_call(pallas_fn, x),
        "dense_float_us": _time_call(dense_fn, x),
        "xnor_us": _time_call(xnor_fn, x),
        "batch": batch,
    }
    out["logic_vs_dense_x"] = round(out["dense_float_us"]
                                    / out["logic_us"], 2)
    for k, v in out.items():
        if k.endswith("_us"):
            print(f"[latency] {k}: {v:.1f}")
    return out


if __name__ == "__main__":
    run()
