"""Load-generator harness for the ``repro.serve`` scheduler.

Drives the micro-batching scheduler end-to-end on JSC-S across the
``LogicEngine`` backends (``bitplane-pallas`` = mapped netlist on the
``kernels/lut_eval`` device executor) and writes ``BENCH_serve.json``
at the repo root:

  * open-loop   — seeded Poisson arrivals at an offered QPS, submitted
    in real time into a thread-driven scheduler (the arrival process
    does not wait for completions — the honest overload model);
  * closed-loop — a fixed concurrency of submit→wait workers (peak
    sustainable throughput at bounded in-flight);
  * slo-lanes   — a two-lane open loop at moderate load (tight SLO on
    lane 0, loose on lane 1, budgets from ``--slo-us`` or scaled from
    the measured service time): per-lane deadline-miss rate / SLO
    attainment / shed counts, with expired requests shed via typed
    ``DEADLINE_EXCEEDED`` rejects instead of served late;
  * baseline    — the *legacy* sequential ``serve_queue`` semantics
    (one blocking padded evaluation per request), replayed against the
    same arrival trace with a busy-server queueing model so its
    latencies are true enqueue→complete times, head-of-line wait
    included — the number the old stats loop hid.

  PYTHONPATH=src:. python benchmarks/loadgen.py --fast \
      --backends gather --requests 1000
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BACKENDS = ("gather", "pallas", "bitplane", "bitplane-pallas",
            "bitplane-streamed")


def parse_backend(spec: str, engine: str = "numpy"):
    """Backend spec -> (LogicEngine backend, bitplane engine).

    ``"bitplane-<engine>"`` pins the bitplane backend to that executor
    from the ``repro.synth.executors`` registry regardless of
    ``--engine`` (``bitplane-streamed`` is shorthand for the
    ``pallas-streamed`` engine); plain ``"bitplane"`` uses ``engine``
    (default numpy host fold)."""
    if spec == "bitplane-streamed":
        return "bitplane", "pallas-streamed"
    if spec.startswith("bitplane-"):
        return "bitplane", spec[len("bitplane-"):]
    if spec == "bitplane":
        return "bitplane", engine
    return spec, "numpy"


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

def poisson_arrivals_us(n: int, qps: float, seed: int = 0) -> np.ndarray:
    """Cumulative open-loop arrival offsets (µs) at offered rate qps."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1e6 / qps, n)
    gaps[0] = 0.0
    return np.cumsum(gaps)


def _pace_until(target_us: float, t0: float) -> None:
    """Sleep until target_us past t0. Sleep-only on purpose: a spin
    wait would hold the GIL against the scheduler thread's (numpy)
    executor and serialize the very batches being measured."""
    while True:
        rem = target_us - (time.perf_counter() * 1e6 - t0)
        if rem <= 0:
            return
        time.sleep(rem * 1e-6)


# ---------------------------------------------------------------------------
# Legacy sequential baseline (busy-server replay)
# ---------------------------------------------------------------------------

def measure_sequential_us(engine, xs: np.ndarray) -> np.ndarray:
    """Real per-call wall times of the pre-scheduler serving model: one
    blocking padded evaluation per request (what the seed's
    ``serve_queue`` loop executed and the only latency it reported)."""
    n = xs.shape[0]
    call_us = np.empty(n)
    for i in range(n):
        t0 = time.perf_counter()
        engine.exec_batch(xs[i: i + 1])
        call_us[i] = (time.perf_counter() - t0) * 1e6
    return call_us


def _lat_stats(lat: np.ndarray, span_us: float) -> Dict[str, float]:
    return {
        "completed": int(lat.shape[0]),
        "p50_us": float(np.percentile(lat, 50)),
        "p95_us": float(np.percentile(lat, 95)),
        "p99_us": float(np.percentile(lat, 99)),
        "mean_us": float(lat.mean()),
        "qps": lat.shape[0] / (span_us * 1e-6) if span_us > 0 else 0.0,
    }


def replay_busy_server(arrivals_us: np.ndarray,
                       call_us: np.ndarray) -> Dict[str, float]:
    """True enqueue→complete latency of a sequential server under an
    arrival trace: start = max(arrival, previous finish). This is the
    queueing the legacy per-call timing loop hid — under load the
    head-of-line wait, not the evaluation, dominates."""
    n = arrivals_us.shape[0]
    lat = np.empty(n)
    end_prev = arrivals_us[0]
    for i in range(n):
        end_prev = max(arrivals_us[i], end_prev) + call_us[i]
        lat[i] = end_prev - arrivals_us[i]
    return _lat_stats(lat, end_prev - arrivals_us[0])


# ---------------------------------------------------------------------------
# Scheduler-driven load generators
# ---------------------------------------------------------------------------

def _wire_online(sched, executor, sinks, profiler) -> None:
    """Attach streaming sinks (windowed metrics / burn monitors) and the
    online profiler to a freshly built scheduler."""
    for s in (sinks or []):
        sched.metrics.add_sink(s)
    if profiler is not None:
        profiler.attach(scheduler=sched)
        if hasattr(executor, "reseed_exec_estimate"):   # ReplicaSet
            profiler.attach(replicas=executor)


def run_open_loop(executor, xs: np.ndarray, qps: float, seed: int = 0,
                  max_batch: int = 256, max_wait_us: float = 200.0,
                  tracer=None, exec_estimate_us: Optional[float] = None,
                  sinks: Optional[Sequence] = None, profiler=None):
    """Real-time Poisson open loop into a threaded scheduler."""
    from repro.serve import MicroBatchScheduler, RequestRejected, SchedConfig

    n = xs.shape[0]
    cfg = SchedConfig(max_batch=max_batch, max_wait_us=max_wait_us,
                      max_queue=2 * n, exec_estimate_us=exec_estimate_us)
    sched = MicroBatchScheduler(executor, cfg, tracer=tracer)
    _wire_online(sched, executor, sinks, profiler)
    sched.start()
    arrivals = poisson_arrivals_us(n, qps, seed)
    futs: List = [None] * n
    t0 = time.perf_counter() * 1e6
    for i in range(n):
        _pace_until(arrivals[i], t0)
        try:
            futs[i] = sched.submit(xs[i])
        except RequestRejected:
            pass
    sched.stop(drain=True)
    results = np.array([-1 if f is None else int(f.result(timeout=30))
                        for f in futs], np.int32)
    return results, sched.metrics.snapshot()


def run_slo_lanes(executor, xs: np.ndarray, qps: float,
                  slo_us: Sequence[float], seed: int = 0,
                  max_batch: int = 256, max_wait_us: float = 200.0,
                  tight_every: int = 4, tracer=None,
                  exec_estimate_us: Optional[float] = None,
                  sinks: Optional[Sequence] = None, profiler=None):
    """Two-lane SLO open loop: every ``tight_every``-th request rides
    lane 0 (tight SLO), the rest lane 1 (loose SLO). Deadlines default
    from the per-lane table; expired requests are shed with a typed
    ``DEADLINE_EXCEEDED`` reject rather than served late. Returns
    (results with -1 for shed/rejected, lane assignment, snapshot)."""
    from repro.serve import MicroBatchScheduler, RequestRejected, SchedConfig

    n = xs.shape[0]
    cfg = SchedConfig(max_batch=max_batch, max_wait_us=max_wait_us,
                      max_queue=2 * n, n_priorities=max(2, len(slo_us)),
                      lane_slo_us=tuple(slo_us),
                      exec_estimate_us=exec_estimate_us)
    sched = MicroBatchScheduler(executor, cfg, tracer=tracer)
    _wire_online(sched, executor, sinks, profiler)
    sched.start()
    arrivals = poisson_arrivals_us(n, qps, seed)
    lanes = np.where(np.arange(n) % tight_every == 0, 0,
                     min(1, len(slo_us) - 1)).astype(np.int32)
    futs: List = [None] * n
    t0 = time.perf_counter() * 1e6
    for i in range(n):
        _pace_until(arrivals[i], t0)
        try:
            futs[i] = sched.submit(xs[i], priority=int(lanes[i]))
        except RequestRejected:
            pass
    sched.stop(drain=True)
    results = np.full((n,), -1, np.int32)
    for i, f in enumerate(futs):
        if f is None:
            continue
        try:
            results[i] = int(f.result(timeout=30))
        except RequestRejected:
            pass                        # shed past its lane deadline
    return results, lanes, sched.metrics.snapshot()


def run_closed_loop(executor, xs: np.ndarray, concurrency: int = 32,
                    max_batch: int = 256, max_wait_us: float = 200.0,
                    tracer=None, exec_estimate_us: Optional[float] = None,
                    sinks: Optional[Sequence] = None, profiler=None):
    """Fixed in-flight submit→wait workers (peak throughput probe)."""
    from repro.serve import MicroBatchScheduler, SchedConfig

    n = xs.shape[0]
    cfg = SchedConfig(max_batch=max_batch, max_wait_us=max_wait_us,
                      max_queue=2 * n, exec_estimate_us=exec_estimate_us)
    sched = MicroBatchScheduler(executor, cfg, tracer=tracer)
    _wire_online(sched, executor, sinks, profiler)
    sched.start()
    results = np.full((n,), -1, np.int32)
    it = iter(range(n))
    lock = threading.Lock()

    def worker():
        while True:
            with lock:
                i = next(it, None)
            if i is None:
                return
            results[i] = int(sched.submit(xs[i]).result(timeout=30))

    threads = [threading.Thread(target=worker)
               for _ in range(min(concurrency, n))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    sched.stop(drain=True)
    return results, sched.metrics.snapshot()


def measure_tracer_overhead(executor, xs: np.ndarray,
                            max_batch: int = 256,
                            trials: int = 13,
                            concurrency: int = 8) -> Dict:
    """Honest tracer cost: the *same* closed-loop section with the
    scheduler's ``NULL_TRACER`` default vs a live ``SpanTracer``, and
    the throughput delta reported as a direction-aware overhead
    percentage (negative deltas are timer noise and clamp to 0).

    A single A/B pair at smoke scale is dominated by thread-scheduling
    jitter (the section is tens of ms of GIL-contended work), so the
    two arms are interleaved ``trials`` times (null, traced, null,
    traced, ...). ``overhead_pct`` is the *median* of the per-pair
    deltas — the honest headline for "what did tracing cost this run".
    Because the jitter is one-sided (preemption only ever slows an arm
    down), the median still swings with the machine's regime; the
    *systematic* per-event cost is bounded by the quietest pairs, same
    reasoning as ``timeit``'s min-of-repeats. ``overhead_pct_lb`` is
    therefore the second-smallest pair delta — second, not first, so a
    single lucky pair can't hide a real regression — and is what CI
    gates on. The full per-pair spread is reported alongside so a
    noisy measurement is visible as such. The untraced arm runs first
    in every pair so warm-cache advantage, if any, goes *against* the
    tracer rather than flattering it.

    The probe runs at modest ``concurrency`` (not the loadgen
    sections' 32+): it measures per-event recording cost, not
    contention behavior, and on a small host 32 GIL-contended
    submitters make individual sections swing 3x on thread-scheduling
    luck alone — the fewer the runnable threads, the tighter the
    pairs."""
    from repro.obs import SpanTracer

    tr = SpanTracer(capacity=1 << 16)
    pair_pct: List[float] = []
    last_null = last_traced = None
    run_closed_loop(executor, xs, concurrency=concurrency,
                    max_batch=max_batch)                    # warm-up
    for _ in range(max(1, trials)):
        _, last_null = run_closed_loop(executor, xs,
                                       concurrency=concurrency,
                                       max_batch=max_batch)
        _, last_traced = run_closed_loop(executor, xs,
                                         concurrency=concurrency,
                                         max_batch=max_batch, tracer=tr)
        qn, qt = last_null["qps"], last_traced["qps"]
        pair_pct.append(max(0.0, (1.0 - qt / qn) * 100.0)
                        if qn > 0 else 0.0)
    overhead = float(np.median(pair_pct))
    ranked = sorted(pair_pct)
    lower_bound = ranked[1] if len(ranked) >= 2 else ranked[0]
    return {"qps_untraced": round(last_null["qps"], 1),
            "qps_traced": round(last_traced["qps"], 1),
            "mean_us_untraced": round(last_null["mean_us"], 1),
            "mean_us_traced": round(last_traced["mean_us"], 1),
            "overhead_pct": round(overhead, 2),
            "overhead_pct_lb": round(lower_bound, 2),
            "overhead_pct_pairs": [round(p, 2) for p in pair_pct],
            "trials": max(1, trials),
            "concurrency": concurrency,
            "trace_events": tr.n_recorded}


# ---------------------------------------------------------------------------
# End-to-end JSC-S benchmark
# ---------------------------------------------------------------------------

def _snap_row(snap: Dict) -> Dict[str, float]:
    keys = ("completed", "rejected", "shed", "deadline_miss_rate",
            "p50_us", "p95_us", "p99_us", "mean_us", "qps", "n_batches",
            "mean_batch_rows", "mean_batch_occupancy", "max_queue_depth")
    return {k: (round(snap[k], 3) if isinstance(snap[k], float)
                else snap[k]) for k in keys}


def _lane_row(lane_snap: Dict, slo: float) -> Dict[str, float]:
    keys = ("completed", "completed_with_deadline", "missed", "shed",
            "deadline_miss_rate", "slo_attainment", "p50_us", "p95_us",
            "p99_us", "slack_p50_us", "mean_slack_us")
    row = {k: (round(lane_snap[k], 3) if isinstance(lane_snap[k], float)
               else lane_snap[k]) for k in keys}
    row["slo_us"] = slo
    row["p99_under_slo"] = bool(lane_snap["p99_us"] <= slo)
    return row


def run(fast: bool = False, backends: Sequence[str] = BACKENDS,
        n_requests: Optional[int] = None, qps: Optional[float] = None,
        loadgen: str = "both", n_replicas: int = 1, steps: Optional[int] = None,
        seed: int = 0, write_json: bool = True,
        engine: str = "numpy",
        slo_us: Optional[Sequence[float]] = None,
        trace: Optional[str] = None, registry=None) -> Dict:
    """Train JSC-S once, then loadgen every backend through the
    scheduler; returns (and optionally writes) the BENCH_serve record.

    ``trace=PATH`` records the full request lifecycle with
    ``repro.obs`` and writes a Perfetto-loadable Chrome trace there
    (metrics-registry snapshot embedded as ``otherData``), plus a
    measured per-level ``lut_eval`` latency table next to it
    (``<PATH stem>.lut_table.json``) whose whole-netlist estimate seeds
    the scheduler's flush margin and replica dispatch for the
    bitplane-pallas backend.

    ``registry`` lets a caller (``launch.serve --metrics-port``) hand
    in the ``MetricsRegistry`` behind a live pull endpoint: every
    scheduler/aggregator/window built here publishes into it, so the
    endpoint shows the run as it happens instead of an empty registry
    while loadgen owns the schedulers. Without it one is created
    internally when tracing (for the trace's ``otherData`` snapshot)."""
    from repro.configs.jsc import JSC_S
    from repro.data.jsc import train_test
    from repro.models.mlp import to_logic
    from repro.serve import build_logic_replicas
    from repro.serving.engine import LogicEngine
    from repro.train.jsc_trainer import train_jsc

    n_requests = n_requests or (1000 if fast else 4000)
    steps = steps or (150 if fast else 400)
    max_batch = 256

    data = train_test(3000, 800, seed=1)
    res = train_jsc(JSC_S, steps=steps, batch=128, data=data)
    net = to_logic(JSC_S, res.params, res.masks, res.bn_state)
    (xte, _) = data[1]
    xs = np.ascontiguousarray(
        xte[np.arange(n_requests) % xte.shape[0]], np.float32)

    resolved = {b: parse_backend(b, engine) for b in backends}
    engines = {b: LogicEngine(net, JSC_S.n_classes, max_batch=max_batch,
                              backend=be, engine=en)
               for b, (be, en) in resolved.items()}
    direct = {b: engines[b].classify(xs) for b in backends}

    # observability: one tracer + registry across every loadgen phase,
    # and a calibrated per-level lut_eval latency table for any backend
    # running the device pipeline
    tracer = None
    lut_table = None
    exec_est_us: Dict[str, float] = {}
    if trace:
        from repro.obs import MetricsRegistry, SpanTracer, build_latency_table
        from repro.synth.executor import compile_device_plan

        tracer = SpanTracer(capacity=1 << 18)
        if registry is None:
            registry = MetricsRegistry()
        for b, (be, en) in resolved.items():
            if be != "bitplane" or en not in ("pallas", "pallas-streamed"):
                continue
            bn = engines[b].bitnet
            dplan = compile_device_plan(bn.mapped, bn._plan)
            if lut_table is None:
                lut_table = build_latency_table(dplan,
                                                iters=2 if fast else 3)
            exec_est_us[b] = lut_table.estimate_plan_us(dplan)
            print(f"[loadgen] {b}: calibrated netlist estimate "
                  f"{exec_est_us[b]:.1f}us/batch "
                  f"({dplan.n_levels} levels)")
        if lut_table is None:           # no device backend: grid only
            lut_table = build_latency_table(iters=2 if fast else 3)

    # legacy sequential reference (gather = the seed's default backend)
    base_eng = engines.get("gather") or next(iter(engines.values()))
    call_us = measure_sequential_us(base_eng, xs)
    capacity_qps = n_requests / (call_us.sum() * 1e-6)
    offered = qps or 8 * capacity_qps
    arrivals = poisson_arrivals_us(n_requests, offered, seed)
    base = replay_busy_server(arrivals, call_us)
    base["service_p95_us"] = float(np.percentile(call_us, 95))
    base["service_mean_us"] = float(call_us.mean())
    base["capacity_qps"] = capacity_qps

    # SLO lanes: tight/loose deadline budgets scaled from the measured
    # service time so attainment is meaningful on any machine, driven at
    # moderate load (below the scheduler's capacity) — the regime where
    # the tight lane's p99 should sit under its SLO and sheds stay rare
    service_mean = float(call_us.mean())
    if slo_us is None:
        tight = max(5_000.0, 25.0 * service_mean)
        slo_us = (tight, 10.0 * tight)
    slo_us = tuple(float(v) for v in slo_us)
    slo_qps = 1.5 * capacity_qps

    out: Dict = {"n_requests": n_requests, "offered_qps": round(offered, 1),
                 "train_steps": steps, "seed": seed,
                 "slo_us": list(slo_us),
                 "slo_offered_qps": round(slo_qps, 1),
                 "baseline_sequential": base, "backends": {}}
    for b in backends:
        be, en = resolved[b]
        est = exec_est_us.get(b)
        executor = engines[b].scheduler_executor()
        sinks = None
        profiler = None
        if registry is not None:
            # streaming per-lane windows for this backend's sections,
            # published into the registry (lands in trace otherData
            # and/or the caller's live /metrics endpoint)
            from repro.obs import OnlineProfiler, WindowedMetrics
            wm = WindowedMetrics(window_us=250_000.0)
            wm.publish(registry, f"{b}.windows")
            sinks = [wm]
            if est is not None and est > 0:
                # close the calibration loop: sampled real-traffic
                # device timings blend into the LatencyTable and
                # re-seed the flush margin + least_slack EWMAs live
                profiler = OnlineProfiler(lut_table, predicted_us=est,
                                          sample_every=4)
                profiler.publish(registry, f"{b}.online_profile")
                agg = getattr(engines[b], "_fn", None)
                if agg is not None and hasattr(agg, "on_device_us"):
                    agg.on_device_us = profiler.observe
        if n_replicas > 1:              # independent data-parallel engines
            # least_slack so the slo_lanes section measures the same
            # deadline-aware dispatch the launch --sched path runs;
            # with no deadlines it degenerates to exec-time-weighted
            # least-loaded, so open/closed numbers stay comparable
            executor = build_logic_replicas(
                net, JSC_S.n_classes, n_replicas=n_replicas, backend=be,
                max_batch=max_batch, policy="least_slack", engine=en,
                exec_seed_us=est)
        rec: Dict = {"engine": en} if be == "bitplane" else {}
        if loadgen in ("open", "both"):
            got, snap = run_open_loop(executor, xs, offered, seed=seed,
                                      max_batch=max_batch, tracer=tracer,
                                      exec_estimate_us=est, sinks=sinks,
                                      profiler=profiler)
            if registry is not None:
                registry.register(f"{b}.open_loop",
                                  lambda snap=snap: snap)
            rec["open_loop"] = _snap_row(snap)
            rec["open_loop"]["identical_to_classify"] = bool(
                np.array_equal(got, direct[b]))
            rec["open_loop"]["throughput_x_sequential"] = round(
                snap["qps"] / base["qps"], 2) if base["qps"] else 0.0
            # per-lane SLO attainment under moderate two-lane load
            got, lanes, snap = run_slo_lanes(executor, xs, slo_qps, slo_us,
                                             seed=seed, max_batch=max_batch,
                                             tracer=tracer,
                                             exec_estimate_us=est,
                                             sinks=sinks, profiler=profiler)
            if registry is not None:
                registry.register(f"{b}.slo_lanes",
                                  lambda snap=snap: snap)
            served = got >= 0
            rec["slo_lanes"] = {
                "offered_qps": round(slo_qps, 1),
                "slo_us": list(slo_us),
                "completed": snap["completed"],
                "shed": snap["shed"],
                "deadline_miss_rate": round(snap["deadline_miss_rate"], 4),
                "qps": round(snap["qps"], 3),
                "identical_on_served": bool(np.array_equal(
                    got[served], direct[b][served])),
                "lanes": {lane: _lane_row(ls, slo_us[int(lane)])
                          for lane, ls in snap["lanes"].items()},
            }
        if loadgen in ("closed", "both"):
            got, snap = run_closed_loop(executor, xs, max_batch=max_batch,
                                        tracer=tracer,
                                        exec_estimate_us=est, sinks=sinks,
                                        profiler=profiler)
            if registry is not None:
                registry.register(f"{b}.closed_loop",
                                  lambda snap=snap: snap)
            rec["closed_loop"] = _snap_row(snap)
            rec["closed_loop"]["identical_to_classify"] = bool(
                np.array_equal(got, direct[b]))
        if registry is not None:
            if hasattr(executor, "publish"):    # ReplicaSet dispatch stats
                executor.publish(registry, f"{b}.replicas")
            fn = getattr(engines[b], "_fn", None)
            if hasattr(fn, "publish"):          # aggregator occupancy
                fn.publish(registry, f"{b}.aggregate")
        if profiler is not None:
            st = profiler.stats()
            rec["online_profile"] = {
                "n_observed": st["n_observed"],
                "n_sampled": st["n_sampled"],
                "scale": round(st["scale"], 4),
                "estimate_us": round(st["estimate_us"], 2)}
            print(f"[loadgen] {b}: online profile blended scale "
                  f"{st['scale']:.3f} over {st['n_sampled']} samples "
                  f"(estimate {st['estimate_us']:.1f}us/batch)")
        out["backends"][b] = rec
    out["argmax_identical_across_backends"] = bool(all(
        np.array_equal(direct[b], direct[backends[0]]) for b in backends))

    # honest tracer cost (S-task): same closed-loop section, untraced
    # vs traced, direction-aware row the regression gate watches
    oh_exec = engines[backends[0]].scheduler_executor()
    out["tracer_overhead"] = measure_tracer_overhead(
        oh_exec, xs[: min(n_requests, 1000)], max_batch=max_batch)
    print(f"[loadgen] tracer overhead: "
          f"{out['tracer_overhead']['overhead_pct']:.2f}% median, "
          f"{out['tracer_overhead']['overhead_pct_lb']:.2f}% lower bound "
          f"({out['tracer_overhead']['qps_untraced']:.0f} -> "
          f"{out['tracer_overhead']['qps_traced']:.0f} qps)")

    if trace:
        from repro.obs import write_chrome_trace
        table_path = os.path.splitext(trace)[0] + ".lut_table.json"
        lut_table.save(table_path)
        write_chrome_trace(trace, tracer, other_data=registry.snapshot())
        out["trace"] = {
            "path": trace, "events": tracer.n_recorded,
            "dropped": tracer.n_dropped, "lut_table": table_path,
            "exec_estimate_us": {k: round(v, 2)
                                 for k, v in exec_est_us.items()},
        }
        print(f"[loadgen] trace: {tracer.n_recorded} events "
              f"({tracer.n_dropped} dropped) -> {trace}")
        print(f"[loadgen] lut latency table -> {table_path}")

    if write_json:
        from benchmarks.meta import bench_meta
        path = os.path.join(REPO_ROOT, "BENCH_serve.json")
        with open(path, "w") as f:
            json.dump({"section": "serve", "meta": bench_meta(seed=seed),
                       "results": out}, f, indent=1)
        print(f"[loadgen] wrote {path}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--qps", type=float, default=None,
                    help="offered open-loop rate (default: 8x sequential)")
    ap.add_argument("--backends", default=",".join(BACKENDS))
    ap.add_argument("--loadgen", choices=["open", "closed", "both"],
                    default="both")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    from repro.synth.executors import names as engine_names
    ap.add_argument("--engine", choices=list(engine_names()),
                    default="numpy",
                    help="bitplane netlist executor from the "
                         "repro.synth.executors registry (host fold, "
                         "monolithic device kernel, or pallas-streamed)")
    ap.add_argument("--slo-us", default=None,
                    help="comma list of per-lane SLO deadline budgets in µs "
                         "(tight lane first, e.g. '5000,50000'; default: "
                         "scaled from the measured service time)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the request lifecycle with repro.obs: "
                         "writes a Chrome trace-event JSON (open in "
                         "ui.perfetto.dev) with the metrics-registry "
                         "snapshot as otherData, plus a measured per-level "
                         "lut_eval latency table (<stem>.lut_table.json)")
    args = ap.parse_args(argv)
    slo_us = (tuple(float(v) for v in args.slo_us.split(","))
              if args.slo_us else None)
    out = run(fast=args.fast, backends=tuple(args.backends.split(",")),
              n_requests=args.requests, qps=args.qps, loadgen=args.loadgen,
              n_replicas=args.replicas, steps=args.steps, seed=args.seed,
              engine=args.engine, slo_us=slo_us, trace=args.trace)
    base = out["baseline_sequential"]
    print(f"[loadgen] sequential baseline: {base['qps']:.0f} qps "
          f"p95={base['p95_us']:.0f}us")
    for b, rec in out["backends"].items():
        for mode, r in rec.items():
            if not isinstance(r, dict):     # per-backend metadata (engine)
                continue
            if mode == "slo_lanes":
                for lane, lr in r["lanes"].items():
                    print(f"[loadgen] {b}/slo lane {lane} "
                          f"(slo={lr['slo_us']:.0f}us): "
                          f"attainment={lr['slo_attainment']:.3f} "
                          f"miss_rate={lr['deadline_miss_rate']:.3f} "
                          f"shed={lr['shed']} p99={lr['p99_us']:.0f}us "
                          f"p99_under_slo={lr['p99_under_slo']}")
                continue
            print(f"[loadgen] {b}/{mode}: {r['qps']:.0f} qps "
                  f"p50={r['p50_us']:.0f}us p95={r['p95_us']:.0f}us "
                  f"p99={r['p99_us']:.0f}us occ={r['mean_batch_occupancy']:.2f} "
                  f"identical={r['identical_to_classify']}")


if __name__ == "__main__":
    main()
