"""CI benchmark-regression gate.

Diffs freshly written ``BENCH_<section>.json`` files against committed
baselines and fails when any shared metric regresses past the
tolerance. Baselines default to the versions at git ``HEAD`` — in CI
that is the checked-out commit, i.e. the files *before* the smoke
benchmark steps overwrote them, so no copy step is needed.

Direction-aware comparison:
  * lower-is-better (µs latencies): fail when
    ``fresh > baseline * (1 + tolerance)``;
  * higher-is-better (qps, speedup ratios): fail when
    ``fresh < baseline / (1 + tolerance)``.

Two measures keep the gate honest across machines (a CI runner is not
the dev box that committed the baseline):

  * **Load-amplified metrics are excluded.** Open-loop queueing
    latencies explode non-linearly with the offered-rate/capacity
    ratio, which is machine-relative — a no-op commit on a slower
    runner can show 30x p95. Open-loop records contribute only their
    throughput metrics (qps and the machine-normalized
    ``throughput_x_sequential``); closed-loop and sequential-baseline
    latencies, which scale ~linearly with machine speed, stay in.
  * **Median drift normalization.** Per file, the median ratio across
    shared metrics estimates the uniform machine-speed factor; each
    metric is judged on its residual from that median (clamped to
    ``--max-drift`` so a genuine across-the-board regression bigger
    than the clamp still fails). ``--no-normalize`` compares raw
    ratios.

Metrics present on only one side are reported but never fail the gate
(smoke runs cover a subset of the full benchmark matrix, and new
kernels add rows the old baseline lacks). Sub-floor latencies
(``--min-us``) are skipped: timer noise dominates there.

  python benchmarks/check_regression.py                  # HEAD baselines
  python benchmarks/check_regression.py --tolerance 0.5  # looser gate
  python benchmarks/check_regression.py --baseline-dir /tmp/base
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from statistics import median
from typing import Dict, Optional, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_FILES = ("BENCH_kernels.json", "BENCH_serve.json")

LOWER, HIGHER = "lower", "higher"        # which direction is better

_LAT_KEYS = (("p50_us", LOWER), ("p95_us", LOWER), ("p99_us", LOWER),
             ("mean_us", LOWER))
_THROUGHPUT_KEYS = (("qps", HIGHER), ("throughput_x_sequential", HIGHER))
# deadline-miss-rate is lower-is-better; SLO attainment is its
# complement. Both are load-normalized fractions, so unlike open-loop
# queueing latencies they are comparable across machine speeds.
_SLO_KEYS = (("deadline_miss_rate", LOWER), ("slo_attainment", HIGHER))


class BaselineError(Exception):
    """A baseline exists but cannot be used (unparsable, or git itself
    is unavailable). Distinct from a *missing* baseline, which is a
    normal skip (new benchmark file); this one needs a human and fails
    the run with an actionable message instead of a traceback."""


def load_baseline(name: str, baseline_dir: Optional[str]) -> Optional[dict]:
    """Baseline JSON from a directory, or from the committed tree at
    git HEAD when no directory is given.

    Returns None when no baseline exists (legitimately skippable);
    raises ``BaselineError`` when one exists but is unusable."""
    if baseline_dir:
        path = os.path.join(baseline_dir, name)
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                return json.load(f)
        except json.JSONDecodeError as e:
            raise BaselineError(
                f"baseline {path} is not valid JSON ({e}). Re-generate "
                f"it (python benchmarks/run.py --fast) or remove it "
                f"from --baseline-dir to skip this file.")
    try:
        proc = subprocess.run(["git", "show", f"HEAD:{name}"],
                              cwd=REPO_ROOT, capture_output=True)
    except FileNotFoundError:
        raise BaselineError(
            "git is not available, so baselines at HEAD cannot be read. "
            "Pass --baseline-dir pointing at a directory of committed "
            "BENCH_*.json files instead.")
    if proc.returncode != 0:
        # not in the committed tree: a brand-new benchmark file
        return None
    try:
        return json.loads(proc.stdout)
    except json.JSONDecodeError as e:
        raise BaselineError(
            f"baseline {name} at git HEAD is not valid JSON ({e}). The "
            f"committed file is corrupt — re-run the benchmark "
            f"(python benchmarks/run.py --fast) and commit a valid "
            f"{name}, or pass --baseline-dir with a good copy.")


def extract_metrics(doc: dict) -> Dict[str, Tuple[float, str]]:
    """Flatten a BENCH json into {metric_name: (value, direction)}.

    Works on both writers: ``benchmarks/run.py`` (rows + results) and
    ``benchmarks/loadgen.py`` (results only) — serve metrics always come
    from ``results`` so the two formats share keys. Only
    ``rows``/``results`` are read: the top-level ``meta`` provenance
    block (git sha, timestamp, device) is deliberately never diffed —
    it changes every run by design."""
    out: Dict[str, Tuple[float, str]] = {}
    section = doc.get("section", "?")
    res = doc.get("results") or {}
    if section == "serve":
        base = res.get("baseline_sequential") or {}
        keys = _LAT_KEYS + _THROUGHPUT_KEYS + (
            ("service_p95_us", LOWER), ("service_mean_us", LOWER))
        for key, direction in keys:
            if key in base:
                out[f"serve/sequential/{key}"] = (float(base[key]), direction)
        # tracer overhead: the honest cost of observability, measured by
        # loadgen running the same closed-loop section with a live
        # SpanTracer vs NULL_TRACER. Lower is better; the throughputs
        # themselves are machine-relative and excluded. The gate tracks
        # the min-of-pairs lower bound, not the median — the median
        # swings with one-sided scheduler jitter (0-15% on a loaded
        # box) while the lower bound isolates the systematic cost.
        to = res.get("tracer_overhead") or {}
        if "overhead_pct_lb" in to:
            out["serve/tracer/overhead_pct_lb"] = (
                float(to["overhead_pct_lb"]), LOWER)
        elif "overhead_pct" in to:
            out["serve/tracer/overhead_pct"] = (
                float(to["overhead_pct"]), LOWER)
        for b, rec in (res.get("backends") or {}).items():
            for mode, r in rec.items():
                if not isinstance(r, dict):
                    continue
                # open-loop latencies (incl. the slo_lanes open loop)
                # are queueing at a machine-relative offered rate —
                # load-amplified, not comparable across machines (see
                # module docstring)
                keys = (_SLO_KEYS + _THROUGHPUT_KEYS
                        if mode in ("open_loop", "slo_lanes")
                        else _LAT_KEYS + _THROUGHPUT_KEYS + _SLO_KEYS)
                for key, direction in keys:
                    if key in r:
                        out[f"serve/{b}/{mode}/{key}"] = (
                            float(r[key]), direction)
                for lane, lrec in (r.get("lanes") or {}).items():
                    if not isinstance(lrec, dict):
                        continue
                    for key, direction in _SLO_KEYS:
                        if key in lrec:
                            out[f"serve/{b}/{mode}/lane{lane}/{key}"] = (
                                float(lrec[key]), direction)
    elif isinstance(res, dict) and res:
        for k, v in res.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[f"{section}/{k}"] = (float(v), LOWER)
    else:                                   # generic fallback: CSV rows
        for row in doc.get("rows") or []:
            out[row["name"]] = (float(row["us_per_call"]), LOWER)
    return out


_RATE_SUFFIXES = ("deadline_miss_rate", "slo_attainment")
# percent-scale metrics ([0, 100]): same floor logic as rates but in
# percentage points (floor = 100 * min_rate), so a 0.3% -> 1.2%
# tracer-overhead wobble is noise while 0.3% -> 40% still fails
_PCT_SUFFIXES = ("overhead_pct", "overhead_pct_lb")
# dimensionless cross-kernel ratios (``<kernel>_vs_<other>_x``): already
# machine-normalized by construction, so they bypass both the µs noise
# floor and the drift correction — these are the rows that keep a
# kernel from silently regressing relative to its own oracle (the old
# gate let aig_sim sit 210x over its jnp ref because both sides of the
# diff carried the same slow number). Floored at ``min_ratio`` so
# "fast, got slightly less fast" (0.04x -> 0.1x) is noise while
# "comparable, got 10x slower" still fails.
_RATIO_SUFFIX = "_x"


def _is_rate(name: str) -> bool:
    return name.endswith(_RATE_SUFFIXES)


def _is_pct(name: str) -> bool:
    return name.endswith(_PCT_SUFFIXES)


def _is_ratio(name: str) -> bool:
    return name.endswith(_RATIO_SUFFIX)


def compare(base: Dict[str, Tuple[float, str]],
            fresh: Dict[str, Tuple[float, str]],
            tolerance: float, min_us: float,
            normalize: bool = True, max_drift: float = 3.0,
            min_rate: float = 0.05, min_ratio: float = 0.5):
    """Returns (regressions, checked, only_one_side, drift).

    ``checked`` rows are (name, base, fresh, raw_ratio, residual,
    direction); a row regresses when its drift-normalized residual
    exceeds 1 + tolerance. ``residual`` is oriented so that > 1 always
    means "worse", whichever direction the metric prefers.

    Rate metrics ([0, 1] fractions: deadline-miss rate, SLO attainment)
    are floored at ``min_rate`` on both sides instead of being skipped
    at zero — a miss rate's *healthy* value is exactly 0.0, and the
    generic zero-skip would make a regression from a clean baseline
    (0.0 -> 0.4) invisible. The floor doubles as the noise tolerance:
    0.0 -> 0.03 compares as 1x, 0.0 -> 0.4 as 8x.

    Dimensionless ``*_x`` ratios (kernel-vs-oracle) get the same
    treatment with ``min_ratio``: floored, ungated by ``min_us``, and
    never drift-corrected — both sides of a ratio ran on the same
    machine, so any movement is the kernel's own."""
    effective: Dict[str, float] = {}
    rows = []
    for name in sorted(set(base) | set(fresh)):
        if name not in base or name not in fresh:
            rows.append((name, None))
            continue
        bv, direction = base[name]
        fv = fresh[name][0]
        if _is_rate(name):
            cb, cf = max(bv, min_rate), max(fv, min_rate)
        elif _is_pct(name):
            cb = max(bv, 100.0 * min_rate)
            cf = max(fv, 100.0 * min_rate)
        elif _is_ratio(name):
            cb, cf = max(bv, min_ratio), max(fv, min_ratio)
        else:
            if direction == LOWER and max(bv, fv) < min_us:
                continue                     # sub-floor: timer noise
            if bv <= 0 or fv <= 0:
                continue
            cb, cf = bv, fv
        ratio = cf / cb
        effective[name] = ratio if direction == LOWER else 1.0 / ratio
        rows.append((name, (bv, fv, ratio, direction)))

    drift = 1.0
    # drift estimates the uniform machine-speed factor — from timing
    # metrics only; rates are fractions of offered load and neither
    # inform nor receive the correction
    timing = [v for n, v in effective.items()
              if not _is_rate(n) and not _is_pct(n) and not _is_ratio(n)]
    if normalize and len(timing) >= 3:       # too few metrics to estimate
        drift = median(timing)
        drift = min(max(drift, 1.0 / max_drift), max_drift)

    regressions, checked, only_one = [], [], []
    for name, payload in rows:
        if payload is None:
            only_one.append(name)
            continue
        bv, fv, ratio, direction = payload
        residual = effective[name] / (
            1.0 if _is_rate(name) or _is_pct(name) or _is_ratio(name)
            else drift)
        row = (name, bv, fv, ratio, residual, direction)
        checked.append(row)
        if residual > 1.0 + tolerance:
            regressions.append(row)
    return regressions, checked, only_one, drift


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when fresh benchmark JSONs regress past "
                    "tolerance vs committed baselines")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative slowdown after drift "
                         "normalization (0.25 = 25%%)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="skip latency metrics where both sides are "
                         "below this (timer noise)")
    ap.add_argument("--min-rate", type=float, default=0.05,
                    help="floor for rate metrics (miss rate / "
                         "attainment): values below it compare as "
                         "equal, so a clean 0.0 baseline still catches "
                         "a real regression without noise-failing")
    ap.add_argument("--min-ratio", type=float, default=0.5,
                    help="floor for dimensionless *_x cross-kernel "
                         "ratios: both sides below it compare as equal "
                         "(already fast), above it the ratio is gated "
                         "raw with no drift correction")
    ap.add_argument("--no-normalize", action="store_true",
                    help="compare raw ratios (no median machine-speed "
                         "drift correction)")
    ap.add_argument("--max-drift", type=float, default=3.0,
                    help="clamp for the drift estimate: an "
                         "across-the-board slowdown beyond this still "
                         "fails")
    ap.add_argument("--files", default=",".join(DEFAULT_FILES),
                    help="comma list of BENCH json names")
    ap.add_argument("--fresh-dir", default=REPO_ROOT,
                    help="directory holding the freshly written JSONs")
    ap.add_argument("--baseline-dir", default=None,
                    help="baseline directory (default: git show HEAD:)")
    args = ap.parse_args(argv)

    any_regression = False
    any_checked = False
    for name in args.files.split(","):
        name = name.strip()
        fresh_path = os.path.join(args.fresh_dir, name)
        if not os.path.exists(fresh_path):
            print(f"[regress] {name}: no fresh file — skipped")
            continue
        try:
            with open(fresh_path) as f:
                fresh_doc = json.load(f)
        except json.JSONDecodeError as e:
            print(f"[regress] ERROR: fresh file {fresh_path} is not "
                  f"valid JSON ({e}). The benchmark step that writes it "
                  f"likely crashed mid-write — re-run it before the "
                  f"regression gate.")
            return 2
        try:
            base_doc = load_baseline(name, args.baseline_dir)
        except BaselineError as e:
            print(f"[regress] ERROR: {e}")
            return 2
        if base_doc is None:
            print(f"[regress] {name}: no baseline at "
                  f"{'HEAD' if not args.baseline_dir else args.baseline_dir}"
                  f" — skipped (new benchmark file?)")
            continue
        regs, checked, only_one, drift = compare(
            extract_metrics(base_doc), extract_metrics(fresh_doc),
            args.tolerance, args.min_us,
            normalize=not args.no_normalize, max_drift=args.max_drift,
            min_rate=args.min_rate, min_ratio=args.min_ratio)
        any_checked = any_checked or bool(checked)
        print(f"[regress] {name}: {len(checked)} metrics checked "
              f"(drift x{drift:.2f}), {len(only_one)} one-sided "
              f"(ignored), {len(regs)} regression(s) at tolerance "
              f"{args.tolerance:.0%}")
        for row in checked:
            mname, bv, fv, ratio, residual, direction = row
            flag = "  REGRESSION" if row in regs else ""
            print(f"  {mname}: {bv:.1f} -> {fv:.1f} (x{ratio:.2f} raw, "
                  f"x{residual:.2f} vs drift, {direction} better){flag}")
        if regs:
            any_regression = True
    if not any_checked:
        print("[regress] WARNING: no overlapping metrics found anywhere")
    if any_regression:
        print("[regress] FAIL: benchmark regression(s) past tolerance")
        return 1
    print("[regress] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
