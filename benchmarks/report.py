"""Render the EXPERIMENTS.md roofline tables (baseline vs optimized)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.roofline import load_rows, roofline_row


def _key(r):
    return (r["arch"], r["shape"], r["mesh"])


def markdown_tables(base_dir="benchmarks/results/dryrun",
                    opt_dir="benchmarks/results/dryrun_opt") -> str:
    base = {_key(r): r for r in load_rows(base_dir)}
    opt = {_key(r): r for r in load_rows(opt_dir)} \
        if os.path.isdir(opt_dir) else {}

    lines = []
    lines.append("| arch | shape | comp(s) | mem(s) | coll(s) | dominant |"
                 " useful | roofline | best coll(s) | best roofline |"
                 " gain | strategy |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for k in sorted(base):
        if k[2] != "single":
            continue
        r = base[k]
        o = opt.get(k)

        def step(row):
            return max(row["t_compute_s"], row["t_memory_s"],
                       row["t_collective_s"])

        # per-cell strategy choice: optimized layout unless the baseline
        # 2-D fsdp+tensor layout is already better (dense prefill).
        chosen, label = r, "baseline-2D"
        if o and step(o) < step(r):
            chosen, label = o, "optimized"
        lines.append(
            f"| {k[0]} | {k[1]} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{100*r['roofline_fraction']:.1f}% | "
            f"{chosen['t_collective_s']:.3f} | "
            f"{100*chosen['roofline_fraction']:.1f}% | "
            f"{step(r)/step(chosen):.1f}x | {label} |")
    # multi-pod summary
    n_multi_b = sum(1 for k in base if k[2] == "multi")
    n_multi_o = sum(1 for k in opt if k[2] == "multi")
    lines.append("")
    lines.append(f"Multi-pod (2x16x16 = 512 chips): {n_multi_b} baseline "
                 f"and {n_multi_o} optimized cells lowered+compiled OK.")
    return "\n".join(lines)


def dryrun_summary(base_dir="benchmarks/results/dryrun") -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(base_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        rows.append(rec)
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    lines = ["| arch | shape | mesh | compile(s) | flops/dev | coll bytes/dev"
             " | args(GiB/dev) | temps(GiB/dev) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in ok:
        e = r.get("extrapolated", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {r['compile_s']} | {e.get('flops_per_device', 0):.2e} |"
            f" {e.get('collective_total_bytes', 0):.2e} |"
            f" {r.get('argument_size_in_bytes', 0)/2**30/r['n_devices']:.2f} |"
            f" {r.get('temp_size_in_bytes', 0)/2**30:.1f} |")
    lines.append("")
    lines.append(f"{len(ok)} cells compiled OK; {len(skipped)} skipped "
                 "(full-attention archs at 500k context, per DESIGN.md §4).")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run summary\n")
    print(dryrun_summary())
    print("\n## Roofline\n")
    print(markdown_tables())
