"""Append-only perf-trajectory ledger across benchmark runs.

``check_regression.py`` answers "did this PR regress vs HEAD?" — a
two-point diff. This module keeps the whole trajectory: every
``BENCH_<section>.json`` appended here becomes one ledger entry keyed
by its ``meta`` provenance block (git sha, UTC timestamp, device), so
"when did p95 start creeping?" is answerable from the repo itself
instead of from CI archaeology.

Ledger format: JSONL at ``benchmarks/results/history.jsonl``, one
entry per (section, run) —

    {"section": "serve", "meta": {...bench_meta...},
     "metrics": {"serve/bitplane/open_loop/qps": [183422.0, "higher"],
                 ...}}

Entries are flattened through ``check_regression.extract_metrics`` so
the ledger stores exactly the direction-aware metric set the
regression gate diffs — the two tools agree on what a "metric" is by
construction. Appends are idempotent per (section, git_sha,
timestamp): re-running a CI step never duplicates an entry.

  python benchmarks/history.py append BENCH_serve.json
  python benchmarks/history.py report --section serve --last 20
  python benchmarks/history.py report --metric serve/sequential/p95_us
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:               # `python benchmarks/history.py`
    sys.path.insert(0, REPO_ROOT)

from benchmarks.check_regression import LOWER, extract_metrics  # noqa: E402

DEFAULT_LEDGER = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "results", "history.jsonl")

SPARK = "▁▂▃▄▅▆▇█"


def _entry_key(entry: Dict) -> tuple:
    meta = entry.get("meta") or {}
    return (entry.get("section"), meta.get("git_sha"),
            meta.get("timestamp_utc"))


def load_history(path: str = DEFAULT_LEDGER) -> List[Dict]:
    """All ledger entries in append order; unparsable lines are skipped
    (a half-written line from a killed CI job must not poison every
    later report)."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


def append_entry(doc: Dict, path: str = DEFAULT_LEDGER) -> Optional[Dict]:
    """Append one BENCH json's metrics to the ledger.

    Returns the entry written, or None when an entry with the same
    (section, git sha, timestamp) provenance already exists — appends
    are idempotent so a retried CI job cannot double-count a run."""
    entry = {
        "section": doc.get("section", "?"),
        "meta": doc.get("meta") or {},
        "metrics": {name: [value, direction]
                    for name, (value, direction)
                    in sorted(extract_metrics(doc).items())},
    }
    if not entry["metrics"]:
        return None
    key = _entry_key(entry)
    if any(_entry_key(e) == key for e in load_history(path)):
        return None
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(entry, default=str) + "\n")
    return entry


def append_file(bench_path: str, path: str = DEFAULT_LEDGER
                ) -> Optional[Dict]:
    with open(bench_path) as f:
        return append_entry(json.load(f), path=path)


def _spark(values: List[float]) -> str:
    lo, hi = min(values), max(values)
    if hi <= lo:
        return SPARK[0] * len(values)
    return "".join(
        SPARK[int((v - lo) / (hi - lo) * (len(SPARK) - 1))] for v in values)


def trajectory(entries: List[Dict], section: Optional[str] = None,
               metric: Optional[str] = None, last: int = 0) -> Dict:
    """Per-metric trajectory over the ledger: ordered points plus
    first/last/best/worst and the direction-aware net change (positive
    ``change_pct`` always means "got worse")."""
    series: Dict[str, Dict] = {}
    for e in entries:
        if section and e.get("section") != section:
            continue
        meta = e.get("meta") or {}
        sha = (meta.get("git_sha") or "?")[:9]
        ts = meta.get("timestamp_utc")
        for name, (value, direction) in (e.get("metrics") or {}).items():
            if metric and name != metric:
                continue
            s = series.setdefault(name, {"direction": direction,
                                         "points": []})
            s["points"].append({"value": float(value), "git_sha": sha,
                                "timestamp_utc": ts})
    for name, s in series.items():
        pts = s["points"][-last:] if last else s["points"]
        s["points"] = pts
        vals = [p["value"] for p in pts]
        lower = s["direction"] == LOWER
        s["n"] = len(vals)
        s["first"], s["last"] = vals[0], vals[-1]
        s["best"] = min(vals) if lower else max(vals)
        s["worst"] = max(vals) if lower else min(vals)
        delta = vals[-1] - vals[0]
        worse = delta if lower else -delta
        s["change_pct"] = (100.0 * worse / abs(vals[0])
                           if vals[0] else 0.0)
    return series


def format_report(series: Dict, threshold_pct: float = 10.0) -> str:
    if not series:
        return "[history] ledger empty — nothing to report"
    lines = [f"perf trajectory ({max(s['n'] for s in series.values())} "
             "run(s) in ledger):",
             f"  {'metric':<44}{'n':>4}{'first':>12}{'last':>12}"
             f"{'net':>9}  trend"]
    for name in sorted(series):
        s = series[name]
        flag = ("  <-- drifting" if s["change_pct"] > threshold_pct
                else "")
        lines.append(
            f"  {name:<44}{s['n']:>4}{s['first']:>12.1f}"
            f"{s['last']:>12.1f}{s['change_pct']:>+8.1f}%  "
            f"{_spark([p['value'] for p in s['points']])}{flag}")
    lines.append("  (net > 0 = worse in that metric's direction; "
                 "trend bars low->high by raw value)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="append-only benchmark-trajectory ledger")
    ap.add_argument("--ledger", default=DEFAULT_LEDGER,
                    help="ledger path (default benchmarks/results/"
                         "history.jsonl)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_app = sub.add_parser("append",
                           help="append BENCH_*.json file(s) to the ledger")
    p_app.add_argument("files", nargs="+")
    p_rep = sub.add_parser("report", help="print the trajectory report")
    p_rep.add_argument("--section", default=None)
    p_rep.add_argument("--metric", default=None)
    p_rep.add_argument("--last", type=int, default=0,
                       help="only the most recent N runs per metric")
    p_rep.add_argument("--json", action="store_true",
                       help="machine-readable trajectory instead of text")
    p_rep.add_argument("--threshold-pct", type=float, default=10.0,
                       help="flag metrics whose net change is worse than "
                            "this percentage")
    args = ap.parse_args(argv)

    if args.cmd == "append":
        for name in args.files:
            if not os.path.exists(name):
                print(f"[history] {name}: missing — skipped")
                continue
            try:
                entry = append_file(name, path=args.ledger)
            except (json.JSONDecodeError, OSError) as e:
                print(f"[history] ERROR: cannot append {name}: {e}")
                return 2
            if entry is None:
                print(f"[history] {name}: duplicate provenance or no "
                      "metrics — skipped")
            else:
                print(f"[history] {name}: appended "
                      f"{len(entry['metrics'])} metric(s) "
                      f"@ {(entry['meta'].get('git_sha') or '?')[:9]}")
        return 0

    series = trajectory(load_history(args.ledger), section=args.section,
                        metric=args.metric, last=args.last)
    if args.json:
        print(json.dumps(series, indent=1, default=str))
    else:
        print(format_report(series, threshold_pct=args.threshold_pct))
    return 0


if __name__ == "__main__":
    sys.exit(main())
