"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)   [raw + analytic]
    collective term = wire_bytes / (chips x 50 GB/s)

HLO_FLOPs / HLO_bytes / collective bytes come from the dry-run's
scan-corrected extrapolation (launch/dryrun.py). Wire bytes apply ring
algorithm factors per collective type. CPU-backend ``bytes accessed`` is
fusion-pessimistic (every unfused elementwise op counts HBM traffic a
TPU would keep in registers/VMEM), so the memory term is reported BOTH
raw and via an analytic HBM model (params + moments + activation
residency); dominance uses compute/collective/analytic-memory.

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for
inference steps.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

# ring-algorithm wire factors (n = ring size; use n=16 nominal)
_ALGO_FACTOR = {
    "all-reduce": 2.0 * 15 / 16,
    "all-gather": 15 / 16,
    "reduce-scatter": 15 / 16,
    "all-to-all": 15 / 16,
    "collective-permute": 1.0,
}


def model_flops(rec: Dict) -> float:
    """6*N_active*tokens for train; 2*N_active*tokens for inference."""
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per row


def analytic_memory_bytes(rec: Dict) -> float:
    """Per-device HBM traffic model for one step (see EXPERIMENTS.md)."""
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    n = cfg.param_count()
    d = cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        # params: bf16 read fwd + bwd(x2: wgrad+igrad passes) + remat fwd
        w = 2.0 * n * 4
        # optimizer: read p,m,v f32 + grads f32; write p,m,v
        opt = n * 4 * 7
        # activations: ~14 tensor-residencies/layer (stored + re-read in
        # bwd), bf16
        act = cfg.n_layers * tokens * d * 14 * 2
        return (w + opt + act) / n_dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        w = n * 2
        act = cfg.n_layers * tokens * d * 6 * 2
        cache = kv_cache_bytes(cfg, shape)
        return (w + act + cache) / n_dev
    # decode: weights once + full KV/SSM cache read + write of one slot
    w = 2.0 * cfg.active_param_count()
    cache = kv_cache_bytes(cfg, shape)
    return (w + cache) / n_dev


def kv_cache_bytes(cfg, shape) -> float:
    from repro.models.lm import cache_len
    b = shape.global_batch
    total = 0.0
    if cfg.family != "ssm" and cfg.n_kv_heads:
        w = cache_len(cfg, shape.seq_len)
        total += (2 * cfg.n_layers * b * w * cfg.n_kv_heads
                  * cfg.head_dim * 2)
    if cfg.family in ("ssm", "hybrid"):
        total += cfg.n_layers * b * cfg.d_inner * cfg.ssm_state * 4
    return total


def roofline_row(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    ex = rec["extrapolated"]
    n_dev = rec["n_devices"]
    flops = ex["flops_per_device"]
    raw_bytes = ex["bytes_accessed_per_device"]
    wire = sum(v * _ALGO_FACTOR[k] for k, v in ex["collective_bytes"].items())

    t_compute = flops / PEAK_FLOPS
    t_mem_raw = raw_bytes / HBM_BW
    t_mem = analytic_memory_bytes(rec) / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute": t_compute, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    step = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_mem,
        "t_memory_raw_s": t_mem_raw, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_total": mf,
        "hlo_flops_total": flops * n_dev,
        "useful_flops_ratio": mf / max(flops * n_dev, 1.0),
        "roofline_fraction": (mf / n_dev / PEAK_FLOPS) / max(step, 1e-12),
        "collective_bytes_per_dev": wire,
    }


def load_rows(dryrun_dir: str = "benchmarks/results/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def format_table(rows: List[Dict], mesh: str = "single") -> str:
    hdr = (f"{'arch':24} {'shape':12} {'comp(s)':>9} {'mem(s)':>9} "
           f"{'coll(s)':>9} {'dominant':>10} {'useful':>7} {'roofl%':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"{r['arch']:24} {r['shape']:12} {r['t_compute_s']:9.4f} "
            f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
            f"{r['dominant']:>10} {r['useful_flops_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:6.1f}%")
    return "\n".join(lines)


def run(dryrun_dir: str = "benchmarks/results/dryrun") -> List[Dict]:
    rows = load_rows(dryrun_dir)
    if not rows:
        print("[roofline] no dry-run results found — run "
              "`python -m repro.launch.dryrun` first")
        return rows
    print(format_table(rows, "single"))
    n_multi = sum(r["mesh"] == "multi" for r in rows)
    print(f"\n[roofline] {len(rows) - n_multi} single-pod rows above; "
          f"{n_multi} multi-pod cells compiled OK (table in EXPERIMENTS.md)")
    return rows


if __name__ == "__main__":
    run()
