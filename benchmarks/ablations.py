"""Ablations over the paper's two training-side contributions.

  1. Activation selection (paper §QAT): the per-layer rule vs forcing a
     mismatched quantizer family everywhere.
  2. FCP schedule (paper §FCP): gradual (Zhu–Gupta) vs ADMM vs one-shot
     post-training projection.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.configs.jsc import JSC_S  # noqa: F401
from repro.configs.jsc import JSC_DEMO
from repro.data.jsc import train_test
from repro.models.mlp import MLPConfig
from repro.train.jsc_trainer import train_jsc

CFG = dataclasses.replace(JSC_DEMO, features=(32, 16, 5),
                          fanins=(4, 4, 4), act_bits=(2, 2, 3))
DATA = train_test(12000, 4000, seed=0)


def act_selection() -> Dict:
    """Correct rule (signed — JSC features take both signs) vs
    binary-everywhere vs 1-bit sign (capacity ablation)."""
    out = {}
    for tag, in_bits, bits in [("rule_signed2", 2, (2, 2, 3)),
                               ("sign_1bit", 1, (1, 1, 3)),
                               ("signed_3bit", 3, (3, 3, 3))]:
        cfg = dataclasses.replace(CFG, in_bits=in_bits, act_bits=bits)
        res = train_jsc(cfg, steps=700, data=DATA)
        out[tag] = round(res.test_acc, 4)
        print(f"[ablation/act] {tag}: acc={res.test_acc:.4f}", flush=True)
    return out


def fcp_schedules() -> Dict:
    out = {}
    for tag, kwargs in [("gradual", {"fcp": "gradual"}),
                        ("admm", {"fcp": "admm"}),
                        ("oneshot", {"fcp": "gradual",
                                     "fcp_begin_frac": 0.95,
                                     "fcp_end_frac": 0.96})]:
        res = train_jsc(CFG, steps=700, data=DATA, **kwargs)
        out[tag] = round(res.test_acc, 4)
        print(f"[ablation/fcp] {tag}: acc={res.test_acc:.4f}", flush=True)
    return out


def run() -> Dict:
    return {"activation_selection": act_selection(),
            "fcp_schedule": fcp_schedules()}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
