"""Run metadata stamped into every BENCH_*.json.

A benchmark number without its provenance (commit, device, jax
version, when it ran) cannot be compared across PRs with any
confidence. ``bench_meta()`` collects that context; writers attach it
as a top-level ``meta`` block, which ``check_regression.py`` tolerates
(it diffs only ``rows``/``results``).
"""
from __future__ import annotations

import datetime
import os
import platform
import subprocess
from typing import Dict, Optional

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, timeout=10,
            capture_output=True, text=True)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def bench_meta(seed: Optional[int] = None) -> Dict:
    """Provenance block for a benchmark JSON: git sha, UTC timestamp,
    jax + device info, python version, and the run seed (if any).
    Every field degrades to None rather than raising — metadata must
    never be the reason a benchmark run fails."""
    meta: Dict = {
        "git_sha": _git_sha(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    if seed is not None:
        meta["seed"] = int(seed)
    try:
        import jax
        meta["jax_version"] = jax.__version__
        meta["jax_backend"] = jax.default_backend()
        meta["device"] = str(jax.devices()[0])
        meta["n_devices"] = jax.device_count()
    except Exception:                   # jax missing or no backend
        meta["jax_version"] = None
    return meta
