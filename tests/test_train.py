"""Training substrate: optimizer, checkpoint/restore, fault tolerance,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.dist import compress as C
from repro.dist.fault import Heartbeat, StepWatchdog, retry_step
from repro.train import checkpoint as ckpt
from repro.train.loop import Trainer, init_state, make_train_step
from repro.train.optim import AdamW, SGD, global_norm
from repro.train.schedules import warmup_cosine


def _quadratic_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


def test_adamw_converges():
    params, loss, target = _quadratic_problem()
    opt = AdamW(lr=0.1)
    st = opt.init(params)
    g = jax.jit(jax.grad(loss))
    for _ in range(300):
        params, st = opt.update(g(params), st, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_sgd_converges():
    params, loss, target = _quadratic_problem()
    opt = SGD(lr=0.05, momentum=0.9)
    st = opt.init(params)
    g = jax.jit(jax.grad(loss))
    for _ in range(200):
        params, st = opt.update(g(params), st, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    st = opt.init(params)
    big = {"w": jnp.full(4, 100.0)}
    _, st2 = opt.update(big, st, params)
    assert float(global_norm(st2.mu)) <= 0.1 * 1.0 + 1e-6  # (1-b1)*clipped


def test_warmup_cosine_shape():
    s = warmup_cosine(1e-3, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(s(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(s(55)) < float(s(20))


def test_lm_loss_decreases_smoke():
    cfg = get_arch("phi4-mini-3.8b", smoke=True)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    from repro.data.tokens import lm_batch
    losses = []
    for t in range(30):
        toks, labels = lm_batch(cfg, 4, 128, 0, t)
        state, m = step(state, {"tokens": jnp.asarray(toks),
                                "labels": jnp.asarray(labels)})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    path = ckpt.save(str(tmp_path), 7, t)
    got = ckpt.restore(path, t)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), t, got)


def test_checkpoint_latest_and_gc(tmp_path):
    t = _tree()
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ac.save(s, t)
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 4
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]  # gc keeps 2


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp dir must never be picked up as a checkpoint."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_00000002.tmp")  # simulated crash
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_trainer_resume(tmp_path):
    cfg = get_arch("phi4-mini-3.8b", smoke=True)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    from repro.data.tokens import lm_batch

    def batches():
        t = 0
        while True:
            toks, labels = lm_batch(cfg, 2, 64, 0, t)
            t += 1
            yield {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    st = init_state(cfg, opt, jax.random.PRNGKey(0))
    tr1 = Trainer(step, st, ckpt_dir=str(tmp_path), ckpt_every=5)
    tr1.run(batches(), 10, log_every=100, log_fn=lambda *_: None)
    # new trainer resumes at step 10
    st2 = init_state(cfg, opt, jax.random.PRNGKey(1))
    tr2 = Trainer(step, st2, ckpt_dir=str(tmp_path), ckpt_every=5)
    assert tr2.step == 10


def test_emergency_checkpoint(tmp_path):
    cfg = get_arch("phi4-mini-3.8b", smoke=True)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    st = init_state(cfg, opt, jax.random.PRNGKey(0))
    tr = Trainer(step, st, ckpt_dir=str(tmp_path), ckpt_every=1000)

    def bad_batches():
        yield {"tokens": jnp.zeros((2, 64), jnp.int32),
               "labels": jnp.zeros((2, 64), jnp.int32)}
        raise RuntimeError("node failure")

    with pytest.raises(RuntimeError):
        tr.run(bad_batches(), 5, log_fn=lambda *_: None)
    assert ckpt.latest_step(str(tmp_path)) is not None  # emergency saved


# ---------------------------------------------------------------------------
# fault hooks
# ---------------------------------------------------------------------------

def test_watchdog_flags_straggler():
    wd = StepWatchdog(min_steps=10, k_sigma=3.0)
    flagged = [wd.record(1.0 + 0.01 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert wd.record(10.0)  # 10x step time -> straggler


def test_retry_step_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, max_retries=3, backoff_s=0.0)() == "ok"
    assert calls["n"] == 3


def test_heartbeat_stale(tmp_path):
    hb1 = Heartbeat(str(tmp_path), 0)
    hb2 = Heartbeat(str(tmp_path), 1)
    hb1.beat(5)
    hb2.beat(5)
    assert hb1.stale_hosts(timeout_s=60) == []
    # host 1 stops beating
    import json
    with open(tmp_path / "host_1.json") as f:
        info = json.load(f)
    info["time"] -= 120
    with open(tmp_path / "host_1.json", "w") as f:
        json.dump(info, f)
    assert hb1.stale_hosts(timeout_s=60) == [1]


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_topk_error_feedback_conserves_mass():
    """sparse + residual == accumulated gradient (EF identity)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32, 32)),
                          jnp.float32)}
    ef = C.init_ef(g)
    sparse, ef2 = C.topk_compress(g, ef, frac=0.1)
    np.testing.assert_allclose(
        np.asarray(sparse["w"] + ef2.residual["w"]), np.asarray(g["w"]),
        rtol=1e-6)
    nz = float(jnp.mean(sparse["w"] != 0))
    assert nz <= 0.12


def test_sign_compress_two_values():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=64),
                          jnp.float32)}
    ef = C.init_ef(g)
    q, ef2 = C.sign_compress(g, ef)
    vals = np.unique(np.round(np.abs(np.asarray(q["w"])), 6))
    assert len(vals) <= 2  # {scale} (and possibly 0)
    np.testing.assert_allclose(np.asarray(q["w"] + ef2.residual["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


def test_compressed_training_still_converges():
    params, loss, target = _quadratic_problem()
    opt = AdamW(lr=0.05)
    st = opt.init(params)
    ef = C.init_ef(params)
    g = jax.jit(jax.grad(loss))
    for _ in range(400):
        grads, ef = C.topk_compress(g(params), ef, frac=0.4)
        params, st = opt.update(grads, st, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=5e-2)


def test_grad_accum_matches_full_batch():
    """grad_accum=N must reproduce the full-batch gradients (linearity)."""
    cfg = get_arch("phi4-mini-3.8b", smoke=True)
    opt = AdamW(lr=1e-3)
    from repro.data.tokens import lm_batch
    toks, labels = lm_batch(cfg, 8, 64, 0, 0)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    st = init_state(cfg, opt, jax.random.PRNGKey(0))
    step1 = jax.jit(make_train_step(cfg, opt, grad_accum=1))
    step4 = jax.jit(make_train_step(cfg, opt, grad_accum=4))
    s1, m1 = step1(st, batch)
    s4, m4 = step4(st, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-3)
    # parameters after one update must agree closely
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s1.params, s4.params)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-3
