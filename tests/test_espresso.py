"""espresso-lite: correctness + quality properties."""
import numpy as np
from hyp_compat import given, settings, st

from repro.core import espresso as esp


@settings(max_examples=60, deadline=None)
@given(k=st.integers(1, 10), density=st.floats(0.05, 0.95),
       seed=st.integers(0, 10_000))
def test_minimize_correct(k, density, seed):
    """Property: the cover realises exactly the on-set."""
    rng = np.random.default_rng(seed)
    onset = rng.random(1 << k) < density
    cov = esp.minimize(onset)
    assert esp.verify(cov, onset)
    assert cov.n_cubes <= int(onset.sum())  # never worse than minterms


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 8), seed=st.integers(0, 1000))
def test_minimize_with_dc(k, seed):
    rng = np.random.default_rng(seed)
    onset = rng.random(1 << k) < 0.3
    dc = (rng.random(1 << k) < 0.2) & ~onset
    cov = esp.minimize(onset, dc)
    assert esp.verify(cov, onset, dc)


def test_constants():
    assert esp.minimize(np.zeros(8, bool)).n_cubes == 0
    cov = esp.minimize(np.ones(8, bool))
    assert cov.n_cubes == 1 and cov.n_literals == 0


def test_known_minimization():
    # f = x0 XOR-free case: f(x) = x0 (onset where bit0 set), 3 vars
    onset = np.array([(i >> 0) & 1 == 1 for i in range(8)])
    cov = esp.minimize(onset)
    assert cov.n_cubes == 1
    assert cov.n_literals == 1


def test_and_or_absorption():
    # f = x0 & x1 | x0 -> minimises to just x0
    onset = np.array([bool(i & 1) for i in range(4)])
    cov = esp.minimize(onset)
    assert cov.n_cubes == 1 and cov.n_literals == 1


def test_sop_string():
    onset = np.array([False, True, False, True])  # f = x0 (2 vars)
    s = esp.cover_to_sop_str(esp.minimize(onset))
    assert s == "(x0)"
