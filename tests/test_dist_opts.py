"""The §Perf sharding strategies must be semantics-preserving.

Runs tests/dist_check.py in a subprocess with 8 forced host devices on a
(data=2, model=4) mesh and asserts each optimized layout reproduces the
unsharded outputs: shard_map MoE, fsdp_pure training, grouped-GQA/
seq-sharded-cache decode.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "dist_check.py")
_ENV = {**os.environ,
        "PYTHONPATH": os.path.join(
            os.path.dirname(os.path.dirname(__file__)), "src")}


@pytest.mark.parametrize("which", ["moe", "fsdp", "decode", "elastic",
                                   "pipeline"])
def test_dist_opt_semantics(which):
    res = subprocess.run(
        [sys.executable, _SCRIPT, which], env=_ENV,
        capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, res.stdout + res.stderr
    assert f"{which} ok" in res.stdout
