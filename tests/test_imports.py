"""Import-integrity regression test.

The seed shipped with models/train/launch importing a package that did
not exist, which surfaced as 11 separate collection errors. This test
walks src/repro/ and imports every module, so a broken import chain
fails as ONE test with the offending module named.
"""
import importlib
import pkgutil

import pytest

import repro

# repro is a namespace package (no __init__.py), so walk __path__
_SRC = list(repro.__path__)


def _all_modules():
    names = []
    for info in pkgutil.walk_packages(_SRC, prefix="repro."):
        names.append(info.name)
    return sorted(names)


@pytest.mark.parametrize("name", _all_modules())
def test_module_imports(name):
    if name == "repro.launch.dryrun":
        # importing dryrun sets XLA_FLAGS for 512 forced host devices;
        # harmless after jax init, but skip to keep this suite hermetic.
        pytest.skip("dryrun mutates XLA_FLAGS at import (launcher-only)")
    importlib.import_module(name)


def test_dist_api_surface():
    """The exact repro.dist surface the rest of the codebase calls."""
    from repro.dist import compress, fault, pipeline, shardings
    for attr in ("use_mesh", "active_mesh", "OPTS", "set_opts",
                 "param_pspec", "_path_str", "_dp_for", "params_shardings",
                 "batch_shardings", "cache_pspec", "constraint",
                 "constrain_hidden", "constrain_heads", "constrain_logits",
                 "batch_axes"):
        assert hasattr(shardings, attr), attr
    for attr in ("EFState", "init_ef", "topk_compress", "sign_compress"):
        assert hasattr(compress, attr), attr
    for attr in ("Heartbeat", "StepWatchdog", "retry_step"):
        assert hasattr(fault, attr), attr
    assert hasattr(pipeline, "pipeline_lm_forward")
