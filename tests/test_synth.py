"""repro.synth: AIG construction, rewriting, k-LUT mapping, bit-parallel
simulation, the bitplane executor, and the end-to-end JSC-S equivalence
of the mapped netlist against the truth-table oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.synth import (AIG, CONST0, CONST1, compile_logic_network,
                         emit_verilog, exhaustive_equiv, execute_packed,
                         input_patterns, lit_not, map_aig, network_to_aig,
                         optimize, pack_bits, random_equiv, random_words,
                         simulate, synthesize, unpack_bits)
from repro.synth.from_sop import table_to_aig
from repro.synth.rewrite import balance, rewrite


def _tt_onset(tt: int, n: int) -> np.ndarray:
    return np.array([(tt >> r) & 1 for r in range(1 << n)], bool)


def _build_tt(tt: int, n: int) -> AIG:
    aig = AIG(n)
    aig.outputs = [table_to_aig(aig, _tt_onset(tt, n), None,
                                [2 * (i + 1) for i in range(n)])]
    return aig


# ---------------------------------------------------------------------------
# AIG invariants
# ---------------------------------------------------------------------------

def test_aig_constant_propagation_and_hashing():
    aig = AIG(2)
    a, b = 2, 4
    assert aig.and2(a, CONST0) == CONST0
    assert aig.and2(a, CONST1) == a
    assert aig.and2(a, a) == a
    assert aig.and2(a, lit_not(a)) == CONST0
    n1 = aig.and2(a, b)
    n2 = aig.and2(b, a)            # operand order canonicalised
    assert n1 == n2
    assert aig.n_ands == 1
    assert aig.or2(lit_not(a), lit_not(b)) == lit_not(n1)  # shared via strash
    assert aig.n_ands == 1


def test_aig_simulation_semantics():
    aig = AIG(2)
    a, b = 2, 4
    aig.outputs = [aig.and2(a, b), aig.or2(a, b), aig.xor2(a, b),
                   lit_not(aig.and2(a, b))]
    out = unpack_bits(simulate(aig, input_patterns(2)), 4)
    np.testing.assert_array_equal(out[0], [0, 0, 0, 1])   # and
    np.testing.assert_array_equal(out[1], [0, 1, 1, 1])   # or
    np.testing.assert_array_equal(out[2], [0, 1, 1, 0])   # xor
    np.testing.assert_array_equal(out[3], [1, 1, 1, 0])   # nand


def test_compact_drops_dead_nodes():
    aig = AIG(3)
    a, b, c = 2, 4, 6
    keep = aig.and2(a, b)
    aig.and2(b, c)                 # dead
    aig.outputs = [keep]
    small = aig.compact()
    assert small.n_ands == 1 and aig.n_ands == 2
    assert random_equiv(aig, small, n_words=4)


# ---------------------------------------------------------------------------
# Rewriting / balancing
# ---------------------------------------------------------------------------

def test_balance_reduces_chain_depth():
    aig = AIG(8)
    acc = 2
    for i in range(1, 8):          # a linear AND chain, depth 7
        acc = aig.and2(acc, 2 * (i + 1))
    aig.outputs = [acc]
    assert aig.depth() == 7
    bal = balance(aig)
    assert bal.depth() == 3        # balanced 8-leaf tree
    assert random_equiv(aig, bal, n_words=8)


def test_rewrite_preserves_function_and_size(rng):
    n = 8
    tt = int.from_bytes(rng.bytes(32), "little")
    aig = _build_tt(tt, n)
    opt = optimize(aig)
    assert opt.n_ands <= aig.n_ands
    assert exhaustive_equiv(opt, [tt])


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 6), data=st.data())
def test_tt_pipeline_property(n, data):
    """Random K<=6 truth tables survive SOP -> AIG -> rewrite -> 6-LUT
    mapping with exhaustive-simulation equivalence (and fit one 6-LUT)."""
    tt = data.draw(st.integers(0, (1 << (1 << n)) - 1))
    aig = _build_tt(tt, n)
    assert exhaustive_equiv(aig, [tt])
    opt = optimize(aig)
    assert exhaustive_equiv(opt, [tt])
    mapped = synthesize(aig)
    assert mapped.n_luts <= 1
    got = unpack_bits(execute_packed(mapped, input_patterns(n)), 1 << n)
    np.testing.assert_array_equal(got[0], _tt_onset(tt, n).astype(np.uint8))


# ---------------------------------------------------------------------------
# Mapping + executor + Verilog
# ---------------------------------------------------------------------------

def test_multi_lut_mapping_exhaustive(rng):
    n = 9
    onset = rng.random(1 << n) < 0.4
    tt = sum(int(v) << r for r, v in enumerate(onset))
    aig = _build_tt(tt, n)
    mapped = synthesize(aig)
    assert mapped.n_luts > 1
    assert all(len(l.leaves) <= 6 for l in mapped.luts)
    assert mapped.depth >= 2
    got = unpack_bits(execute_packed(mapped, input_patterns(n)), 1 << n)
    np.testing.assert_array_equal(got[0], onset.astype(np.uint8))


def test_verilog_emission(rng):
    n = 8
    tt = int.from_bytes(rng.bytes(32), "little")
    mapped = synthesize(_build_tt(tt, n))
    v = emit_verilog(mapped, "tiny_mapped")
    assert "module tiny_mapped" in v
    assert v.count("_init = 64'h") == mapped.n_luts
    assert f"[{n - 1}:0] x" in v


def test_pallas_aig_sim_matches_numpy(rng):
    n = 7
    tts = [int.from_bytes(rng.bytes(16), "little") for _ in range(3)]
    aig = AIG(n)
    aig.outputs = [table_to_aig(aig, _tt_onset(t, n), None,
                                [2 * (i + 1) for i in range(n)])
                   for t in tts]
    words = random_words(n, 8, seed=5)
    np.testing.assert_array_equal(
        simulate(aig, words, use_pallas=False),
        simulate(aig, words, use_pallas=True))


def test_pack_unpack_roundtrip(rng):
    bits = (rng.random((5, 100)) < 0.5).astype(np.uint8)
    np.testing.assert_array_equal(unpack_bits(pack_bits(bits), 100), bits)


# ---------------------------------------------------------------------------
# End-to-end: JSC-S mapped netlist vs the truth-table oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def jsc_s():
    from repro.configs.jsc import JSC_S
    from repro.data.jsc import train_test
    from repro.models.mlp import to_logic
    from repro.train.jsc_trainer import train_jsc
    data = train_test(3000, 800, seed=1)
    res = train_jsc(JSC_S, steps=200, batch=128, data=data)
    net = to_logic(JSC_S, res.params, res.masks, res.bn_state)
    return net, data


def test_jsc_s_mapped_netlist_matches_oracle(jsc_s):
    """The paper-flow acceptance check: the synthesized+mapped 6-LUT
    netlist reproduces LogicNetwork.__call__ bit-exactly on real data."""
    net, data = jsc_s
    bit = compile_logic_network(net, effort=1)
    assert bit.mapped.n_luts > 0 and bit.mapped.depth >= 1
    assert all(len(l.leaves) <= 6 for l in bit.mapped.luts)
    (xte, _) = data[1]
    x = jnp.asarray(xte[:700])
    np.testing.assert_array_equal(bit(x), np.asarray(net(x)))


def test_jsc_s_structural_report(jsc_s):
    from repro.core.lutmap import structural_report
    net, _ = jsc_s
    rep, per_layer, backend = structural_report(net)
    assert backend == "synth"
    assert rep.luts > 0 and rep.depth >= 1 and rep.ffs > 0
    assert len(per_layer) == len(net.layers)
    assert rep.luts == sum(r.luts for r in per_layer)


def test_jsc_s_bitplane_engine_matches_gather(jsc_s):
    """gather, numpy-bitplane and pallas-bitplane backends are
    argmax-identical end to end, including the ragged final flush
    through the aggregator's ``pad_rows`` (600 = 4*128 + 88)."""
    from repro.serving.engine import LogicEngine
    net, data = jsc_s
    (xte, _) = data[1]
    gather = LogicEngine(net, 5, max_batch=128)
    bitplane = LogicEngine(net, 5, max_batch=128, backend="bitplane")
    pallas = LogicEngine(net, 5, max_batch=128, backend="bitplane",
                         engine="pallas")
    want = gather.classify(xte[:600])
    np.testing.assert_array_equal(want, bitplane.classify(xte[:600]))
    np.testing.assert_array_equal(want, pallas.classify(xte[:600]))


def test_jsc_s_pallas_engine_bit_identical(jsc_s):
    """The fused lut_eval device pipeline is *bit*-identical to the
    numpy fold (codes and packed words, not just argmax)."""
    from repro.synth.executor import BitplaneNetwork
    from repro.synth.simulate import pack_bits
    net, data = jsc_s
    bit = compile_logic_network(net, effort=1)
    dev = BitplaneNetwork(net, bit.mapped, engine="pallas")
    (xte, _) = data[1]
    for n in (64, 97):                       # full + ragged lane words
        codes = np.asarray(net.quantize_inputs(jnp.asarray(xte[:n])))
        np.testing.assert_array_equal(bit.apply_codes(codes),
                                      dev.apply_codes(codes))
        planes = np.empty((codes.shape[1] * bit.in_bits, n), np.uint8)
        for b in range(bit.in_bits):
            planes[b::bit.in_bits] = ((codes >> b) & 1).T
        words = pack_bits(planes)
        np.testing.assert_array_equal(
            bit.classify_packed(words, n, 5),
            dev.classify_packed(words, n, 5))


def test_emit_mapped_network(jsc_s):
    from repro.core.netlist import emit_mapped_network
    net, _ = jsc_s
    v = emit_mapped_network(net, "jsc_s_mapped", effort=0)
    assert "module jsc_s_mapped" in v
    assert "_init = 64'h" in v
