"""Flash-attention Pallas kernel: shape/dtype/mask sweeps vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_ref)
from repro.models import layers as L


def _run(b, sq, sk, h, kv, dh, causal, win, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, sk, kv, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, sk, kv, dh)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=win)
    ref = L.full_attention(q, k, v, causal=causal, window=win)
    return np.asarray(got, np.float32), np.asarray(ref, np.float32)


@pytest.mark.parametrize("b,sq,sk,h,kv,dh,causal,win", [
    (2, 64, 64, 4, 2, 16, True, 0),
    (1, 128, 128, 2, 2, 64, True, 0),     # exact blocks
    (2, 100, 100, 4, 1, 32, True, 24),    # window + padding
    (1, 33, 70, 4, 4, 8, False, 0),       # cross-attention-like
    (1, 257, 257, 2, 1, 128, True, 0),    # >2 blocks, dh 128
])
def test_flash_matches_full_attention(b, sq, sk, h, kv, dh, causal, win):
    got, ref = _run(b, sq, sk, h, kv, dh, causal, win)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    got, ref = _run(1, 64, 64, 2, 2, 32, True, 0, dtype=jnp.bfloat16)
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)


def test_flash_matches_chunked():
    """Same math as the XLA chunked attention the LM uses."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 96, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 96, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 96, 2, 16)), jnp.float32)
    a = flash_attention(q, k, v, causal=True)
    b = L.chunked_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
