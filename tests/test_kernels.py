"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.kernels.fanin_matmul import (dense_equivalent, fanin_matmul,
                                        fanin_matmul_ref)
from repro.kernels.lut_layer import lut_layer, lut_layer_ref
from repro.kernels.xnor_popcount import (pack_bipolar, xnor_matmul,
                                         xnor_matmul_ref)


@pytest.mark.parametrize("B,n_in,N,K,L", [
    (8, 16, 32, 3, 2),
    (130, 20, 50, 4, 2),     # non-multiple of blocks
    (64, 64, 128, 6, 2),     # exact block
    (33, 10, 7, 2, 4),       # multi-level codes
    (16, 24, 200, 5, 3),
])
def test_lut_layer_sweep(B, n_in, N, K, L):
    rng = np.random.default_rng(B * 7 + N)
    codes = jnp.asarray(rng.integers(0, L, (B, n_in)), jnp.int32)
    idx = jnp.asarray(rng.integers(0, n_in, (N, K)), jnp.int32)
    tables = jnp.asarray(rng.integers(0, 8, (N, L ** K)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(lut_layer(codes, idx, tables, L)),
        np.asarray(lut_layer_ref(codes, idx, tables, L)))


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 40), n_in=st.integers(4, 40), N=st.integers(1, 40),
       K=st.integers(1, 6), seed=st.integers(0, 99))
def test_lut_layer_property(B, n_in, N, K, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2, (B, n_in)), jnp.int32)
    idx = jnp.asarray(rng.integers(0, n_in, (N, K)), jnp.int32)
    tables = jnp.asarray(rng.integers(0, 2, (N, 2 ** K)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(lut_layer(codes, idx, tables, 2)),
        np.asarray(lut_layer_ref(codes, idx, tables, 2)))


@pytest.mark.parametrize("B,n,N", [
    (8, 32, 16),
    (17, 100, 33),      # ragged everything
    (128, 4096, 128),   # full blocks, 1 full packed-word tile
    (1, 7, 1),          # tiny
    (40, 129, 250),
])
def test_xnor_sweep(B, n, N):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.choice([-1.0, 1.0], (B, n)), jnp.float32)
    w = jnp.asarray(rng.choice([-1.0, 1.0], (N, n)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(xnor_matmul(x, w)), np.asarray(xnor_matmul_ref(x, w)))


def test_pack_bipolar_bits():
    x = jnp.asarray([[1.0, -1.0, 1.0, 1.0] + [-1.0] * 28])
    p = np.asarray(pack_bipolar(x))
    assert p.shape == (1, 1)
    assert p[0, 0] == 0b1101


@pytest.mark.parametrize("B,n,N,K,dtype", [
    (8, 32, 16, 3, jnp.float32),
    (19, 64, 40, 5, jnp.float32),
    (128, 128, 128, 7, jnp.float32),
    (5, 16, 200, 2, jnp.float32),
])
def test_fanin_matmul_sweep(B, n, N, K, dtype):
    rng = np.random.default_rng(B + N)
    x = jnp.asarray(rng.normal(size=(B, n)), dtype)
    idx = jnp.asarray(rng.integers(0, n, (N, K)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(N, K)), dtype)
    bias = jnp.asarray(rng.normal(size=(N,)), dtype)
    np.testing.assert_allclose(
        np.asarray(fanin_matmul(x, idx, w, bias)),
        np.asarray(fanin_matmul_ref(x, idx, w, bias)),
        rtol=1e-5, atol=1e-5)


def test_fanin_matmul_matches_dense(rng):
    """Gather-matmul == dense matmul with the masked weight matrix."""
    from repro.core.fcp import fanin_indices, topk_row_mask
    B, n, N, K = 16, 32, 12, 4
    w_dense = jnp.asarray(rng.normal(size=(N, n)), jnp.float32)
    mask = topk_row_mask(w_dense, K)
    w_masked = jnp.where(mask, w_dense, 0.0)
    idx, _ = fanin_indices(np.asarray(mask), K)
    w_k = jnp.take_along_axis(w_masked, idx, axis=1)
    x = jnp.asarray(rng.normal(size=(B, n)), jnp.float32)
    bias = jnp.zeros((N,), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(fanin_matmul(x, idx, w_k, bias)),
        np.asarray(dense_equivalent(x, w_masked, bias)),
        rtol=1e-4, atol=1e-4)
