"""repro.check.sat: the formal engine must PROVE what sampling can only
sample.

The load-bearing scenarios are mutations on >20-PI netlists whose
discriminating minterm is a single rare non-corner pattern — random
sampling (even with corner seeding) misses them at 2^-26 density, and
the SAT miter must still return a ``SAT`` verdict with a counterexample
that replays bit-exactly through the packed bitplane simulator.  Clean
pipelines must prove ``UNSAT``; an exhausted budget must surface as
``UNPROVEN``, never as a silent pass.
"""
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.check import (equiv_aig_mapped, equiv_aigs,
                         find_duplicate_lut_outputs,
                         merge_duplicate_lut_outputs, prove_aig_equiv,
                         prove_aig_mapped, prove_mapped_equiv)
from repro.check.sat import CareSet, prove_pairs
from repro.check.sat.cnf import CNF, eval_cubes, isop, lut_clauses
from repro.check.sat.engine import (UNet, _normalize, import_aig,
                                    import_mapped)
from repro.check.sat.solver import Solver, luby
from repro.synth import AIG, lit, map_aig, optimize
from repro.synth.executor import execute_packed
from repro.synth.lutmap import MappedLUT, MappedNetwork
from repro.synth.simulate import input_patterns, pack_bits


def random_aig(seed, n_pis=26, n_ands=150, n_outs=4):
    rng = np.random.default_rng(seed)
    a = AIG(n_pis)
    lits = [lit(p + 1) for p in range(n_pis)]
    for _ in range(n_ands):
        i, j = rng.integers(0, len(lits), 2)
        lits.append(a.and2(lits[i] ^ int(rng.integers(2)),
                           lits[j] ^ int(rng.integers(2))))
    a.outputs = lits[-n_outs:]
    return a


def rare_minterm_net(n=26):
    """(aig, mutated mapped, target bits): output is 1 on exactly one
    non-corner input (x1..x24 & ~x25 & ~x26); the mutation flips the
    mapped INIT row selected by that input, so the two sides differ on
    a single minterm out of 2^26."""
    a = AIG(n)
    acc = lit(1)
    for p in range(2, n - 1):
        acc = a.and2(acc, lit(p))
    acc = a.and2(acc, lit(n - 1) ^ 1)
    acc = a.and2(acc, lit(n) ^ 1)
    a.outputs = [acc]
    mapped = map_aig(a)
    target = np.array([1] * (n - 2) + [0, 0], np.uint8)
    wirevals = {p: int(target[p - 1]) for p in range(1, n + 1)}
    for l in mapped.luts:
        row = sum(wirevals[leaf] << j for j, leaf in enumerate(l.leaves))
        wirevals[l.root] = (l.tt >> row) & 1
    root_i = next(i for i, l in enumerate(mapped.luts)
                  if l.root == (mapped.outputs[0] >> 1))
    l = mapped.luts[root_i]
    row = sum(wirevals[leaf] << j for j, leaf in enumerate(l.leaves))
    luts = list(mapped.luts)
    luts[root_i] = MappedLUT(l.root, l.leaves, l.tt ^ (1 << row))
    bad = MappedNetwork(mapped.n_pis, mapped.k, luts, mapped.outputs)
    return a, mapped, bad, target


# ---------------------------------------------------------------------------
# the CDCL solver
# ---------------------------------------------------------------------------

def _brute_sat(n, clauses):
    for m in range(1 << n):
        if all(any(((m >> (l >> 1)) & 1) ^ (l & 1) for l in c)
               for c in clauses):
            return True
    return False


def test_solver_matches_brute_force():
    rng = np.random.default_rng(0)
    for _ in range(200):
        n = int(rng.integers(3, 9))
        clauses = [[2 * int(v) | int(rng.integers(2))
                    for v in rng.choice(n, int(rng.integers(1, 4)),
                                        replace=False)]
                   for _ in range(int(rng.integers(4, 40)))]
        s = Solver(n)
        for c in clauses:
            s.add_clause(c)
        verdict = s.solve()
        assert verdict == ("SAT" if _brute_sat(n, clauses) else "UNSAT")
        if verdict == "SAT":
            m = s.model()
            assert all(any(m[l >> 1] ^ (l & 1) for l in c)
                       for c in clauses)


def test_solver_budget_yields_unknown():
    # 8-hole pigeonhole: hard UNSAT; 1-conflict budget cannot finish
    n_p, n_h = 9, 8
    s = Solver(n_p * n_h)
    v = lambda p, h: p * n_h + h
    for p in range(n_p):
        s.add_clause([2 * v(p, h) for h in range(n_h)])
    for h in range(n_h):
        for p1 in range(n_p):
            for p2 in range(p1 + 1, n_p):
                s.add_clause([2 * v(p1, h) ^ 1, 2 * v(p2, h) ^ 1])
    assert s.solve(conflict_budget=1) == "UNKNOWN"


def test_luby_sequence():
    assert [luby(i) for i in range(1, 10)] == [1, 1, 2, 1, 1, 2, 4, 1, 1]


# ---------------------------------------------------------------------------
# CNF encodings
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=60)
@given(st.integers(min_value=2, max_value=4), st.integers(min_value=0))
def test_isop_cover_matches_table(m, tt_seed):
    tt = tt_seed % (1 << (1 << m))
    cubes = isop(tt, m)
    for r in range(1 << m):
        assert eval_cubes(cubes, r) == ((tt >> r) & 1)


@pytest.mark.parametrize("mode", ["isop", "rows"])
def test_lut_clauses_exact(mode):
    """Force every input assignment; the out var must be forced to the
    table row — both encodings, all 3-input tables."""
    rng = np.random.default_rng(1)
    for tt in list(range(16)) + [int(rng.integers(0, 256))
                                 for _ in range(20)]:
        m = 3 if tt >= 16 else 2
        tt %= 1 << (1 << m)
        for assign in range(1 << m):
            cnf = CNF()
            ins = [2 * cnf.new_var() for _ in range(m)]
            out = 2 * cnf.new_var()
            lut_clauses(cnf, out, ins, tt, mode=mode)
            for j, l in enumerate(ins):
                cnf.add(l ^ (0 if (assign >> j) & 1 else 1))
            want = (tt >> assign) & 1
            cnf.add(out ^ (0 if want else 1))
            assert cnf.solver().solve() == "SAT", (tt, assign, mode)
            cnf.add(out ^ (1 if want else 0))
            assert cnf.solver().solve() == "UNSAT", (tt, assign, mode)


def test_normalize_preserves_function():
    rng = np.random.default_rng(2)
    for _ in range(50):
        un = UNet(4)
        m = int(rng.integers(2, 5))
        fans = [int(f) for f in rng.integers(2, 10, m)]  # PI literals
        tt = int(rng.integers(0, 1 << (1 << m)))
        out = un.add(fans, tt)
        vals = un.simulate(input_patterns(4))
        got = vals[out >> 1] ^ (np.uint32(0xFFFFFFFF) if out & 1 else 0)
        want = np.zeros_like(got)
        for r in range(16):
            row = 0
            for j, f in enumerate(fans):
                bit = ((int(vals[f >> 1][0]) >> r) & 1) ^ (f & 1)
                row |= bit << j
            if (tt >> row) & 1:
                want[0] |= np.uint32(1 << r)
        assert int(got[0]) & 0xFFFF == int(want[0]) & 0xFFFF


# ---------------------------------------------------------------------------
# UNet import fidelity
# ---------------------------------------------------------------------------

def test_unet_simulate_matches_execute_packed():
    for seed in range(4):
        a = optimize(random_aig(seed, n_pis=8, n_ands=40), rounds=1)
        mapped = map_aig(a, k=4)
        un = UNet(8)
        outs = import_mapped(un, mapped)
        words = input_patterns(8)
        vals = un.simulate(words)
        ref = execute_packed(mapped, words)
        for o, r in zip(outs, ref):
            got = vals[o >> 1] ^ (np.uint32(0xFFFFFFFF) if o & 1 else 0)
            np.testing.assert_array_equal(got, r)


# ---------------------------------------------------------------------------
# proofs on clean wide pipelines
# ---------------------------------------------------------------------------

def test_clean_wide_pipeline_proves_unsat():
    for seed in range(3):
        a = random_aig(seed)
        opt = optimize(a, rounds=1)
        mapped = map_aig(opt)
        assert prove_aig_equiv(a, opt).verdict == "UNSAT"
        res = prove_aig_mapped(opt, mapped)
        assert res.verdict == "UNSAT"
        assert res.stats["outputs"] == len(opt.outputs)


def test_constant_output_leg_regression():
    """Miter leg that is a bare constant: the const-FALSE unit clause
    must still be emitted (a spurious SAT here once poisoned the whole
    verdict to UNPROVEN via the bad-cex guard)."""
    a = AIG(4)
    t1 = a.and2(lit(1), lit(2))
    t2 = a.and2(lit(1), lit(2) ^ 1)
    a.outputs = [a.and2(t1, t2) ^ 1]        # semantically const-true
    const_true = MappedNetwork(4, 6, [], [1])
    assert prove_aig_mapped(a, const_true).verdict == "UNSAT"
    const_false = MappedNetwork(4, 6, [], [0])
    assert prove_aig_mapped(a, const_false).verdict == "SAT"


# ---------------------------------------------------------------------------
# mutation kill-rate beyond the exhaustive limit
# ---------------------------------------------------------------------------

def test_rare_minterm_flip_missed_by_sampling_caught_by_sat():
    a, _clean, bad, target = rare_minterm_net()
    rep = equiv_aig_mapped(a, bad)              # sampled only
    assert rep.ok                               # sampling misses the bug
    rep = equiv_aig_mapped(a, bad, formal=True)
    assert not rep.ok
    cexs = [i.counterexample for i in rep.errors if i.counterexample]
    assert cexs and cexs[0].formal
    res = prove_aig_mapped(a, bad)
    assert res.verdict == "SAT"
    assert res.cex == tuple(int(b) for b in target)


def test_counterexample_replays_through_bitplane_sim():
    a, clean, bad, _ = rare_minterm_net()
    res = prove_aig_mapped(a, bad)
    words = pack_bits(np.array(res.cex, np.uint8)[:, None])
    got = execute_packed(bad, words)
    want = execute_packed(clean, words)
    assert any(int(g[0] & 1) != int(w[0] & 1)
               for g, w in zip(got, want))


def test_wide_mutations_all_yield_sat():
    """INIT flip / leaf swap / dropped LUT on a 26-PI mapped net: every
    functional mutation must come back SAT with a replayable cex."""
    a = optimize(random_aig(7), rounds=1)
    mapped = map_aig(a)
    base = list(mapped.luts)

    def differs(m2):
        words = np.random.default_rng(5).integers(
            0, 1 << 32, (mapped.n_pis, 64), dtype=np.uint32)
        x, y = execute_packed(mapped, words), execute_packed(m2, words)
        return any(not np.array_equal(g, w) for g, w in zip(x, y))

    muts = []
    l = base[-1]
    muts.append(("init-flip", base[:-1]
                 + [MappedLUT(l.root, l.leaves, l.tt ^ 4)]))
    if len(l.leaves) >= 2:
        sw = (l.leaves[1], l.leaves[0]) + l.leaves[2:]
        muts.append(("leaf-swap", base[:-1]
                     + [MappedLUT(l.root, sw, l.tt)]))
    for name, luts in muts:
        bad = MappedNetwork(mapped.n_pis, mapped.k, luts, mapped.outputs)
        if not differs(bad):        # symmetric table etc. — not a mutation
            continue
        res = prove_aig_mapped(a, bad)
        assert res.verdict == "SAT", name
        words = pack_bits(np.array(res.cex, np.uint8)[:, None])
        x = execute_packed(mapped, words)
        y = execute_packed(bad, words)
        assert any(int(g[0] & 1) != int(w[0] & 1)
                   for g, w in zip(x, y)), name


def test_dropped_lut_detected():
    a = optimize(random_aig(9), rounds=1)
    mapped = map_aig(a)
    victim = mapped.outputs[0] >> 1
    luts = [l for l in mapped.luts if l.root != victim]
    if len(luts) == len(mapped.luts):
        pytest.skip("output fed directly by a PI")
    # rewire the dropped root to a PI so the netlist stays well-formed
    outs = [(2 * 1) | (o & 1) if (o >> 1) == victim else o
            for o in mapped.outputs]
    bad = MappedNetwork(mapped.n_pis, mapped.k, luts, outs)
    assert prove_mapped_equiv(mapped, bad).verdict == "SAT"


# ---------------------------------------------------------------------------
# budget exhaustion and care sets
# ---------------------------------------------------------------------------

def test_budget_zero_reports_unproven_and_falls_back():
    a = optimize(random_aig(3), rounds=1)
    mapped = map_aig(a)
    rep = equiv_aig_mapped(a, mapped, formal=True, conflict_budget=0)
    assert rep.ok                       # sampled fallback found nothing
    assert any(i.severity == "warning" and "UNPROVEN" in i.message
               for i in rep.issues)
    assert rep.info["formal[aig-mapped]"]["verdict"] == "UNPROVEN"


def test_care_set_excludes_invalid_codes():
    """Two sides that differ ONLY on an invalid input code: SAT without
    the care set, UNSAT with it — exactly espresso's don't-care story."""
    n = 22                              # > exhaustive limit
    un = UNet(n)
    tail = 2 * 3
    for p in range(4, n + 1):
        tail = un.and2(tail, 2 * p)
    pair = un.and2(2 * 1, 2 * 2)        # 1 only on the invalid code 3
    side_a = un.and2(pair ^ 1, tail)
    side_b = tail                       # drops the (pair^1) factor
    care = CareSet((((0, 1), 3),))      # PIs 1,2 encode a 3-level code
    res = prove_pairs(un, [side_a], [side_b])
    assert res.verdict == "SAT"
    assert res.cex[0] == 1 and res.cex[1] == 1    # the invalid code
    assert prove_pairs(un, [side_a], [side_b],
                       care=care).verdict == "UNSAT"


# ---------------------------------------------------------------------------
# SAT sweep: duplicate LUT outputs
# ---------------------------------------------------------------------------

def _dup_mapped(negated=False):
    """Two LUTs computing the same (or complemented) function of the
    same PIs, plus an unrelated one."""
    tt = 0b1000_0110_0110_1000  # some 4-input function
    full = (1 << 16) - 1
    luts = [
        MappedLUT(5, (1, 2, 3, 4), tt),
        MappedLUT(6, (1, 2, 3, 4), (~tt & full) if negated else tt),
        MappedLUT(7, (2, 3), 0b0110),
    ]
    return MappedNetwork(4, 6, luts, [2 * 5, 2 * 6, 2 * 7])


@pytest.mark.parametrize("negated", [False, True])
def test_duplicate_lut_outputs_found_and_merged(negated):
    mapped = _dup_mapped(negated)
    pairs, stats = find_duplicate_lut_outputs(mapped)
    assert len(pairs) == 1
    keep, dup, neg = pairs[0]          # LUT indices, not root wires
    assert {keep, dup} == {0, 1} and neg == negated
    swept = merge_duplicate_lut_outputs(mapped, pairs)
    assert swept.n_luts == mapped.n_luts - 1
    words = input_patterns(4)
    np.testing.assert_array_equal(execute_packed(mapped, words),
                                  execute_packed(swept, words))


def test_no_false_duplicates():
    a = optimize(random_aig(11), rounds=1)
    mapped = map_aig(a)
    pairs, _ = find_duplicate_lut_outputs(mapped)
    swept = merge_duplicate_lut_outputs(mapped, pairs)
    words = np.random.default_rng(0).integers(
        0, 1 << 32, (mapped.n_pis, 32), dtype=np.uint32)
    x, y = execute_packed(mapped, words), execute_packed(swept, words)
    for g, w in zip(x, y):
        np.testing.assert_array_equal(g, w)
