"""Import-or-stub shim for the optional ``hypothesis`` dev dependency.

``from hyp_compat import given, settings, st`` instead of importing
hypothesis directly: with hypothesis installed these are the real
objects; without it, @given marks just that test as skipped — the
plain (non-property) tests in the same module keep running, unlike a
module-level ``pytest.importorskip`` which would silence them all.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import pytest

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="needs the optional hypothesis dev dependency "
                       "(pip install -r requirements-dev.txt)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Accept any strategy construction at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
