"""Fanin-constrained pruning: masks, schedules, ADMM."""
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.core import fcp


@settings(max_examples=25, deadline=None)
@given(out_dim=st.integers(1, 12), in_dim=st.integers(1, 24),
       fanin=st.integers(1, 8))
def test_topk_mask_row_budget(out_dim, in_dim, fanin):
    rng = np.random.default_rng(out_dim * 100 + in_dim)
    w = jnp.asarray(rng.normal(size=(out_dim, in_dim)), jnp.float32)
    mask = fcp.topk_row_mask(w, fanin)
    rows = np.asarray(fcp.row_fanins(mask))
    assert np.all(rows == min(fanin, in_dim))


def test_projection_keeps_largest(rng):
    w = jnp.asarray([[3.0, -1.0, 0.5, 2.0]])
    p = fcp.project_fanin(w, 2)
    np.testing.assert_allclose(np.asarray(p), [[3.0, 0, 0, 2.0]])


def test_projection_idempotent(rng):
    w = jnp.asarray(rng.normal(size=(6, 10)), jnp.float32)
    p1 = fcp.project_fanin(w, 3)
    p2 = fcp.project_fanin(p1, 3)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2))


def test_gradual_schedule_monotone():
    sched = fcp.GradualFCP(target_fanin=4, begin_step=0, end_step=100)
    f = [int(sched.fanin_at(t, 64)) for t in range(0, 120, 10)]
    assert f[0] == 64
    assert f[-1] == 4
    assert all(a >= b for a, b in zip(f, f[1:]))


def test_admm_drives_to_fanin(rng):
    """ADMM on a least-squares toy: W converges near the fanin-K set."""
    import jax
    t = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    t = fcp.project_fanin(t, 3)  # ground truth is fanin-3
    x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    y = x @ t.T
    admm = fcp.AdmmFCP(target_fanin=3, rho=0.05, dual_freq=10)
    w = jnp.asarray(rng.normal(size=(8, 16)) * 0.1, jnp.float32)
    z, u = admm.init_state(w)

    def loss(w, z, u):
        return jnp.mean((x @ w.T - y) ** 2) + admm.penalty(w, z, u)

    g = jax.jit(jax.grad(loss))
    for i in range(300):
        w = w - 0.05 * g(w, z, u)
        if i % 10 == 9:
            z, u = admm.dual_update(w, z, u)
    w_f, mask = admm.finalize(w)
    # off-support mass should be tiny vs on-support mass
    off = float(jnp.sum(jnp.abs(w * (1 - mask))))
    on = float(jnp.sum(jnp.abs(w * mask)))
    assert off / on < 0.15
    rows = np.asarray(fcp.row_fanins(mask))
    assert np.all(rows <= 3)


def test_fanin_indices_padding():
    mask = jnp.asarray([[1, 0, 1, 0], [0, 0, 0, 1]], bool)
    idx, valid = fcp.fanin_indices(mask, 3)
    assert idx.shape == (2, 3)
    assert np.asarray(valid).sum(1).tolist() == [2, 1]
    # padded entries repeat a valid index (weight 0 keeps semantics)
    assert int(idx[1, 1]) == 3
