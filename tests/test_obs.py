"""repro.obs: request-lifecycle tracing under a fake clock (span
presence/nesting, flush reasons, shed/reject terminal events, disabled-
tracer zero-footprint), ring-buffer overflow accounting, export
round-trips, the metrics registry, LatencyHistogram edge cases, the
trace-schema validation pass, kernel latency-table estimation, and
EWMA seeding from calibrated estimates."""
import json

import numpy as np
import pytest

from repro.check.tracecheck import (check_trace, check_trace_file,
                                    synthetic_trace_events)
from repro.obs import (FLUSH_REASONS, LatencyTable, MetricsRegistry,
                       NULL_TRACER, SpanTracer, TraceEvent,
                       load_trace_events, to_chrome_trace, to_jsonl,
                       write_chrome_trace, write_jsonl)
from repro.serve import (FakeClock, MicroBatchScheduler, ReplicaSet,
                         RequestRejected, SchedConfig)
from repro.serve.metrics import LatencyHistogram, ServeMetrics


def _traced_sched(cfg=None, capacity=4096):
    clk = FakeClock()
    tracer = SpanTracer(clock=clk, capacity=capacity)
    s = MicroBatchScheduler(
        lambda x: x.sum(axis=-1),
        cfg or SchedConfig(max_batch=4, max_wait_us=200.0),
        clock=clk, tracer=tracer)
    return clk, tracer, s


def _by(events, ph=None, name=None, cat=None):
    return [e for e in events
            if (ph is None or e.ph == ph)
            and (name is None or e.name == name)
            and (cat is None or e.cat == cat)]


# ---------------------------------------------------------------------------
# Request lifecycle under FakeClock
# ---------------------------------------------------------------------------

def test_full_lifecycle_spans_size_flush():
    clk, tracer, s = _traced_sched()
    futs = [s.submit(np.full((1, 3), i, np.float32)) for i in range(4)]
    assert s.poll() == 4
    evs = tracer.events()

    # every request opened + closed both async spans, outcome ok
    ids = {f.trace_id for f in futs}
    assert len(ids) == 4 and 0 not in ids
    for f in futs:
        begins = [e for e in _by(evs, ph="b") if e.scope_id == f.trace_id]
        ends = [e for e in _by(evs, ph="e") if e.scope_id == f.trace_id]
        assert [e.name for e in begins] == ["request", "queue_wait"]
        assert [e.name for e in ends] == ["queue_wait", "request"]
        qw, req = ends
        # the dispatch-path queue_wait end carries no args (the flush
        # reason lives on the batch_form span; wait is ts delta)
        assert qw.args is None
        assert req.args["outcome"] == "ok"
        assert req.args["latency_us"] >= 0.0

    # the scheduler thread recorded its X spans with the right cats
    assert _by(evs, ph="X", name="batch_form", cat="batch")
    assert _by(evs, ph="X", name="exec", cat="exec")
    assert _by(evs, ph="X", name="scatter", cat="sched")
    form = _by(evs, ph="X", name="batch_form")[0]
    assert form.args["flush_reason"] == "size" and form.args["rows"] == 4


def test_max_wait_flush_reason_and_wait_time():
    clk, tracer, s = _traced_sched()
    f = s.submit(np.ones((2, 3), np.float32))
    assert s.poll() == 0
    clk.advance_us(200.0)
    assert s.poll() == 1
    f.result(0)
    evs = tracer.events()
    (qw,) = _by(evs, ph="e", name="queue_wait")
    (qb,) = [e for e in _by(evs, ph="b", name="queue_wait")]
    assert qw.ts_us - qb.ts_us == 200.0     # wait == end-begin ts delta
    form = _by(evs, ph="X", name="batch_form")[0]
    assert form.args["flush_reason"] == "max_wait"


def test_shed_and_reject_terminal_events():
    clk, tracer, s = _traced_sched(
        SchedConfig(max_batch=4, n_priorities=1, lane_slo_us=(100.0,)))
    f = s.submit(np.ones((1, 3), np.float32))
    clk.advance_us(500.0)                # expire past the lane SLO
    s.drain()
    with pytest.raises(RequestRejected):
        f.result(0)
    evs = tracer.events()
    (qw,) = _by(evs, ph="e", name="queue_wait")
    (req,) = _by(evs, ph="e", name="request")
    assert qw.args["flush_reason"] == "shed"
    assert req.args["outcome"] == "shed" and req.args["lane"] == 0

    # admission reject: an instant only, never an async begin
    with pytest.raises(RequestRejected):
        s.submit(np.ones((99, 3), np.float32))
    rej = _by(tracer.events(), ph="i", name="reject")
    assert len(rej) == 1 and rej[0].cat == "admission"
    assert rej[0].args["reason"] == "too_large"
    # no new async span was opened for the rejected submission
    assert {e.scope_id for e in _by(tracer.events(), ph="b")} == \
        {f.trace_id}


def test_drain_on_stop_closes_spans_as_shutdown():
    clk, tracer, s = _traced_sched()
    f = s.submit(np.ones((1, 3), np.float32))
    s.stop(drain=False)
    with pytest.raises(RequestRejected):
        f.result(0)
    (req,) = _by(tracer.events(), ph="e", name="request")
    assert req.args["outcome"] == "shutdown"


def test_disabled_tracer_records_nothing():
    clk = FakeClock()
    tracer = SpanTracer(clock=clk, enabled=False)
    s = MicroBatchScheduler(lambda x: x.sum(axis=-1),
                            SchedConfig(max_batch=2), clock=clk,
                            tracer=tracer)
    futs = [s.submit(np.ones((1, 3), np.float32)) for _ in range(2)]
    s.poll()
    assert all(f.result(0) == 3.0 for f in futs)
    assert tracer.events() == [] and tracer.n_recorded == 0
    assert futs[0].trace_id is None      # ids not even allocated
    # the default NULL_TRACER has the same surface and also stays empty
    assert NULL_TRACER.events() == [] and not NULL_TRACER.enabled


def test_ring_buffer_overflow_keeps_latest():
    tracer = SpanTracer(clock=FakeClock(), capacity=4)
    for i in range(10):
        tracer.instant(f"ev{i}")
    assert tracer.n_recorded == 10 and tracer.n_dropped == 6
    assert [e.name for e in tracer.events()] == ["ev6", "ev7", "ev8", "ev9"]
    tracer.clear()
    assert tracer.events() == [] and tracer.n_recorded == 0


# ---------------------------------------------------------------------------
# Export round-trips
# ---------------------------------------------------------------------------

def _sample_events():
    clk = FakeClock()
    t = SpanTracer(clock=clk)
    rid = t.new_id()
    t.abegin("request", rid, args={"lane": 0})
    clk.advance_us(5.0)
    with t.span("exec", cat="exec", args={"rows": 2}):
        clk.advance_us(10.0)
    t.aend("request", rid, args={"outcome": "ok"})
    return t


def test_chrome_trace_shape_and_roundtrip(tmp_path):
    t = _sample_events()
    doc = to_chrome_trace(t, other_data={"k": 1})
    assert doc["traceEvents"][0]["ph"] == "M"       # process_name meta
    assert doc["otherData"] == {"k": 1}
    xs = [r for r in doc["traceEvents"] if r["ph"] == "X"]
    assert xs[0]["dur"] == 10.0 and xs[0]["ts"] == 5.0
    asyncs = [r for r in doc["traceEvents"] if r["ph"] in "be"]
    assert all(isinstance(r["id"], str) for r in asyncs)

    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, t, other_data={"k": 1})
    back = load_trace_events(path)
    orig = t.events()
    assert len(back) == len(orig)        # M dropped on load
    for a, b in zip(orig, back):
        assert (a.ph, a.name, a.cat, a.ts_us, a.dur_us, a.scope_id) == \
               (b.ph, b.name, b.cat, b.ts_us, b.dur_us, b.scope_id)


def test_jsonl_roundtrip(tmp_path):
    t = _sample_events()
    path = str(tmp_path / "trace.jsonl")
    write_jsonl(path, t)
    assert len(to_jsonl(t).splitlines()) == len(t.events())
    back = load_trace_events(path)
    for a, b in zip(t.events(), back):
        assert a.ph == b.ph and a.ts_us == b.ts_us and a.args == b.args


# ---------------------------------------------------------------------------
# Metrics registry + histogram edge cases
# ---------------------------------------------------------------------------

def test_registry_snapshot_all_instrument_kinds():
    reg = MetricsRegistry()
    reg.counter("sched.completed").inc(3)
    assert reg.counter("sched.completed") is reg.counter("sched.completed")
    reg.gauge("depth").set(7.0)
    reg.gauge("live", fn=lambda: 42.0)
    h = reg.histogram("lat")
    for v in (10.0, 20.0, 30.0):
        h.record(v)
    reg.register("comp", lambda: {"a": 1})
    snap = reg.snapshot()
    assert snap["counters"] == {"sched.completed": 3}
    assert snap["gauges"] == {"depth": 7.0, "live": 42.0}
    assert snap["histograms"]["lat"]["n"] == 3
    assert snap["histograms"]["lat"]["mean_us"] == 20.0
    assert snap["comp"] == {"a": 1}


def test_serve_metrics_publish_into_registry():
    m = ServeMetrics(FakeClock())
    reg = MetricsRegistry()
    m.publish(reg, "serve")
    snap = reg.snapshot()
    assert "serve" in snap and snap["serve"]["completed"] == 0


def test_histogram_empty_and_percentile_clamp():
    h = LatencyHistogram()
    assert h.percentile(50) == 0.0 and h.mean() == 0.0   # empty
    for v in (1.0, 2.0, 3.0):
        h.record(v)
    assert h.percentile(-10) == 1.0      # clamped to p0 = min
    assert h.percentile(250) == 3.0      # clamped to p100 = max
    assert h.mean() == 2.0


def test_histogram_counts_only_mode():
    h = LatencyHistogram(max_samples=0)
    for v in (5.0, 15.0):
        h.record(v)                      # must not divide by zero
    assert h.n == 2 and h.samples == []
    assert h.percentile(99) == 0.0       # no reservoir -> 0.0
    assert h.mean() == 10.0              # counts/total still tracked
    assert LatencyHistogram(max_samples=-3).max_samples == 0


# ---------------------------------------------------------------------------
# Trace-schema validation pass
# ---------------------------------------------------------------------------

def _ev(ph, name, ts, dur=0.0, tid=1, sid=None, args=None, cat="request"):
    return TraceEvent(ph, name, cat, ts, dur, tid, sid, args)


def test_tracecheck_clean_on_live_scheduler_trace():
    events, n_dropped = synthetic_trace_events()
    rep = check_trace(events, n_dropped=n_dropped)
    assert rep.ok, rep.format()
    assert rep.checked > 0
    reasons = {e.args["flush_reason"] for e in events
               if e.args and "flush_reason" in e.args}
    assert reasons >= {"size", "max_wait", "shed"}
    assert reasons <= set(FLUSH_REASONS)


def test_tracecheck_rejects_violations():
    def errs(evs, **kw):
        return {i.code for i in check_trace(evs, **kw).errors}

    assert "orphan-end" in errs(
        [_ev("e", "request", 1.0, sid=1, args={"outcome": "ok"})])
    assert "unterminated-span" in errs([_ev("b", "request", 1.0, sid=1)])
    assert "bad-flush-reason" in errs(
        [_ev("i", "x", 1.0, args={"flush_reason": "vibes"})])
    assert "negative-dur" in errs([_ev("X", "exec", 5.0, dur=-1.0)])
    assert "bad-phase" in errs([_ev("Z", "x", 1.0)])
    assert "bad-outcome" in errs(
        [_ev("b", "request", 0.0, sid=1),
         _ev("e", "request", 1.0, sid=1, args={"outcome": "maybe"})])
    assert "time-regression" in errs(
        [_ev("b", "request", 5.0, sid=1),
         _ev("e", "request", 1.0, sid=1, args={"outcome": "ok"})])
    assert "end-mismatch" in errs(
        [_ev("b", "request", 0.0, sid=1),
         _ev("b", "queue_wait", 1.0, sid=1),
         _ev("e", "request", 2.0, sid=1, args={"outcome": "ok"})])
    # partially-overlapping same-thread X spans cannot come from
    # lexical `with` nesting
    assert "span-overlap" in errs(
        [_ev("X", "a", 0.0, dur=10.0), _ev("X", "b", 5.0, dur=10.0)])
    # disjoint + properly nested spans are fine
    assert not errs([_ev("X", "a", 0.0, dur=10.0),
                     _ev("X", "inner", 2.0, dur=3.0),
                     _ev("X", "later", 20.0, dur=5.0)])


def test_tracecheck_truncated_buffer_downgrades_to_warnings():
    evs = [_ev("e", "request", 1.0, sid=7, args={"outcome": "ok"})]
    rep = check_trace(evs, n_dropped=3)
    assert rep.ok                        # warnings, not errors
    assert any(i.code == "orphan-end" for i in rep.warnings)


def test_tracecheck_file_roundtrip(tmp_path):
    events, _ = synthetic_trace_events()
    path = str(tmp_path / "t.json")
    write_chrome_trace(path, events)
    rep = check_trace_file(path)
    assert rep.ok, rep.format()
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump({"traceEvents": [
            {"ph": "e", "name": "request", "cat": "request", "ts": 1.0,
             "id": "1", "args": {"outcome": "ok"}}]}, f)
    assert not check_trace_file(bad).ok


# ---------------------------------------------------------------------------
# Kernel latency table (model only; device timing covered by benchmarks)
# ---------------------------------------------------------------------------

def _grid_table():
    rows = [{"source": "grid", "level_width": w, "k": 6, "fanin": f,
             "device_us": float(w * (1.0 if f <= 3 else 2.0)),
             "w_words": 128}
            for w in (4, 16) for f in (2, 4)]
    return LatencyTable(rows=rows, meta={"backend": "cpu"})


def test_latency_table_interpolation_and_extrapolation():
    t = _grid_table()
    assert t.estimate_level_us(4, fanin=2) == 4.0       # exact grid point
    assert t.estimate_level_us(10, fanin=2) == 10.0     # linear in width
    assert t.estimate_level_us(32, fanin=2) == 32.0     # extrapolated
    assert t.estimate_level_us(4, fanin=6) == 8.0       # nearest fanin = 4
    with pytest.raises(ValueError):
        t.estimate_level_us(4, fanin=2, k=4)            # no k=4 rows


def test_latency_table_artifact_roundtrip(tmp_path):
    t = _grid_table()
    path = str(tmp_path / "lut_table.json")
    t.save(path)
    back = LatencyTable.load(path)
    assert back.rows == t.rows and back.meta == t.meta
    with open(path) as f:
        assert json.load(f)["kind"] == "lut_level_latency_table"
    other = str(tmp_path / "not_table.json")
    with open(other, "w") as f:
        json.dump({"kind": "something_else"}, f)
    with pytest.raises(ValueError):
        LatencyTable.load(other)


# ---------------------------------------------------------------------------
# Calibrated-estimate seeding of the execution EWMAs
# ---------------------------------------------------------------------------

def test_sched_ewma_seeded_from_estimate():
    clk = FakeClock()

    def ex(x):
        clk.advance_us(100.0)
        return x.sum(axis=-1)

    s = MicroBatchScheduler(ex, SchedConfig(max_batch=1,
                                            exec_estimate_us=500.0),
                            clock=clk)
    assert s._exec_ewma_us == 500.0 and s._ewma_seeded
    s.submit(np.ones((1, 3), np.float32))
    s.poll()
    # first measurement blends into the seed instead of replacing it
    assert s._exec_ewma_us == pytest.approx(0.8 * 500.0 + 0.2 * 100.0)


def test_sched_ewma_unseeded_first_sample_wins():
    clk = FakeClock()

    def ex(x):
        clk.advance_us(100.0)
        return x.sum(axis=-1)

    s = MicroBatchScheduler(ex, SchedConfig(max_batch=1), clock=clk)
    assert not s._ewma_seeded
    s.submit(np.ones((1, 3), np.float32))
    s.poll()
    assert s._exec_ewma_us == pytest.approx(100.0)


def test_replicaset_exec_seed():
    clk = FakeClock()

    def ex(x):
        clk.advance_us(40.0)
        return x.sum(axis=-1)

    rs = ReplicaSet([ex], policy="rr", clock=clk, exec_seed_us=300.0)
    st = rs.stats()[0]
    assert st["ewma_us"] == 300.0 and st["ewma_seeded"]
    rs(np.ones((1, 3), np.float32))
    assert rs.stats()[0]["ewma_us"] == pytest.approx(
        0.8 * 300.0 + 0.2 * 40.0)
    # unseeded: first real sample overwrites the zero cold-start
    rs2 = ReplicaSet([ex], policy="rr", clock=clk)
    rs2(np.ones((1, 3), np.float32))
    assert rs2.stats()[0]["ewma_us"] == pytest.approx(40.0)
    assert not rs2.stats()[0]["ewma_seeded"]
