"""repro.serve: scheduler semantics under a fake clock, backpressure,
priority lanes, per-lane SLO deadlines (EDF formation, expiry shedding,
miss-rate accounting), replica failover, bitplane aggregation, and
cross-backend bit-identity of scheduled results on JSC-S."""
import numpy as np
import pytest

from repro.serve import (AllReplicasDown, BitplaneAggregator, FakeClock,
                         MicroBatchScheduler, RejectReason, ReplicaSet,
                         RequestRejected, SchedConfig)
from repro.serve.sched import BoundedPriorityQueue, ServeFuture, ServeRequest


def _sum_executor(log):
    def ex(x):
        log.append(x.shape[0])
        return x.sum(axis=-1)
    return ex


# ---------------------------------------------------------------------------
# Batch formation: deadline flush vs full-batch flush
# ---------------------------------------------------------------------------

def test_full_batch_flushes_without_deadline():
    clk, log = FakeClock(), []
    s = MicroBatchScheduler(_sum_executor(log),
                            SchedConfig(max_batch=4, max_wait_us=1e6),
                            clock=clk)
    futs = [s.submit(np.full((3,), i, np.float32)) for i in range(4)]
    # four 1-row requests = max_batch: flush immediately, no time passed
    assert s.poll() == 4
    assert log == [4]
    assert [f.result(0) for f in futs] == [0.0, 3.0, 6.0, 9.0]


def test_deadline_flush_partial_batch():
    clk, log = FakeClock(), []
    s = MicroBatchScheduler(_sum_executor(log),
                            SchedConfig(max_batch=64, max_wait_us=200.0),
                            clock=clk)
    f = s.submit(np.ones((2, 3), np.float32))
    assert s.poll() == 0                 # under max_batch, deadline not hit
    clk.advance_us(199.0)
    assert s.poll() == 0                 # 1 us early
    clk.advance_us(1.0)
    assert s.poll() == 1                 # exactly at max_wait_us
    assert log == [2]
    np.testing.assert_allclose(f.result(0), [3.0, 3.0])
    assert f.latency_us == 200.0         # true enqueue->complete time


def test_multirow_requests_never_split_and_fill_batches():
    clk, log = FakeClock(), []
    s = MicroBatchScheduler(_sum_executor(log),
                            SchedConfig(max_batch=4, max_wait_us=10.0),
                            clock=clk)
    fa = s.submit(np.ones((3, 2)))
    fb = s.submit(np.ones((2, 2)))       # does not fit with fa: 5 > 4
    clk.advance_us(11.0)
    assert s.poll() == 2                 # two batches, FIFO preserved
    assert log == [3, 2]
    assert fa.result(0).shape == (3,) and fb.result(0).shape == (2,)


# ---------------------------------------------------------------------------
# Admission control / backpressure
# ---------------------------------------------------------------------------

def test_backpressure_typed_reject():
    s = MicroBatchScheduler(_sum_executor([]),
                            SchedConfig(max_batch=8, max_queue=3),
                            clock=FakeClock())
    for _ in range(3):
        s.submit(np.ones(2))
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones(2))
    assert e.value.reason == RejectReason.QUEUE_FULL
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones((9, 2)))        # more rows than one batch
    assert e.value.reason == RejectReason.TOO_LARGE
    snap = s.metrics.snapshot()
    assert snap["rejected"] == 2
    assert snap["rejected_by_reason"] == {"queue_full": 1, "too_large": 1}
    assert s.drain() == 3                # queued work still completes


def test_shutdown_rejects_new_submissions():
    s = MicroBatchScheduler(_sum_executor([]), SchedConfig(),
                            clock=FakeClock())
    s.start()
    s.stop(drain=True)
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones(2))
    assert e.value.reason == RejectReason.SHUTDOWN


# ---------------------------------------------------------------------------
# Shutdown: stop/submit race + drain=False typed rejection
# ---------------------------------------------------------------------------

def test_stop_submit_race_rejected_not_hung():
    """A submit racing with stop()'s final drain must get a typed
    SHUTDOWN reject, not be accepted into a queue nobody serves (the
    old order set _shutdown only *after* the drain, so the racing
    request's future hung forever)."""
    clk = FakeClock()
    holder = {}

    def ex(x):
        # runs inside stop()'s final drain — exactly the race window
        try:
            holder["fut"] = s.submit(np.ones(2))
        except RequestRejected as e:
            holder["exc"] = e
        return x.sum(axis=-1)

    s = MicroBatchScheduler(ex, SchedConfig(max_batch=8), clock=clk)
    f = s.submit(np.ones(2))
    s.stop(drain=True)
    assert "fut" not in holder, "racing submit was accepted and will hang"
    assert holder["exc"].reason == RejectReason.SHUTDOWN
    assert f.result(0) == 2.0            # pre-stop work still served


def test_stop_without_drain_rejects_queued():
    s = MicroBatchScheduler(_sum_executor([]), SchedConfig(),
                            clock=FakeClock())
    f = s.submit(np.ones(2))
    s.stop(drain=False)
    with pytest.raises(RequestRejected) as e:
        f.result(0)                      # resolved, not hung
    assert e.value.reason == RejectReason.SHUTDOWN


# ---------------------------------------------------------------------------
# Admission shape validation: one bad request must not poison a batch
# ---------------------------------------------------------------------------

def test_bad_shape_rejected_at_admission_batch_survives():
    clk, log = FakeClock(), []

    def ex(x):
        log.append(x.shape[0])
        return x.sum(axis=-1)

    ex.n_features = 3
    s = MicroBatchScheduler(ex, SchedConfig(max_batch=8), clock=clk)
    good = [s.submit(np.ones(3)) for _ in range(2)]
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones((2, 4)))        # wrong width: would break concat
    assert e.value.reason == RejectReason.BAD_SHAPE
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones((2, 2, 3)))     # wrong rank
    assert e.value.reason == RejectReason.BAD_SHAPE
    assert s.drain() == 2                # the good batch executes cleanly
    assert [f.result(0) for f in good] == [3.0, 3.0]
    assert s.metrics.snapshot()["rejected_by_reason"]["bad_shape"] == 2


def test_width_pinned_from_first_request_without_executor_hint():
    s = MicroBatchScheduler(_sum_executor([]), SchedConfig(),
                            clock=FakeClock())
    s.submit(np.ones(2))                 # pins batch width to 2
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones(5))
    assert e.value.reason == RejectReason.BAD_SHAPE
    assert s.drain() == 1


# ---------------------------------------------------------------------------
# Per-lane SLO deadlines: expiry shedding, EDF, miss-rate accounting
# ---------------------------------------------------------------------------

def test_deadline_expiry_shed_with_typed_reject():
    clk, log = FakeClock(), []
    s = MicroBatchScheduler(_sum_executor(log),
                            SchedConfig(max_batch=8, max_wait_us=1e6,
                                        n_priorities=1,
                                        lane_slo_us=(100.0,)), clock=clk)
    f = s.submit(np.ones(2))
    clk.advance_us(150.0)                # past the lane-0 SLO
    assert s.drain() == 1                # resolved by shedding, not served
    assert log == []                     # never reached the executor
    with pytest.raises(RequestRejected) as e:
        f.result(0)
    assert e.value.reason == RejectReason.DEADLINE_EXCEEDED
    snap = s.metrics.snapshot()
    assert snap["shed"] == 1 and snap["completed"] == 0
    assert snap["deadline_miss_rate"] == 1.0
    assert snap["lanes"]["0"]["shed"] == 1


def test_explicit_deadline_overrides_lane_slo():
    clk, log = FakeClock(), []
    s = MicroBatchScheduler(_sum_executor(log),
                            SchedConfig(max_batch=8, max_wait_us=1e6,
                                        n_priorities=1,
                                        lane_slo_us=(100.0,)), clock=clk)
    f = s.submit(np.ones(2), deadline_us=500.0)
    clk.advance_us(150.0)                # past the lane SLO, within budget
    assert s.poll() == 0                 # not expired, not yet due
    clk.advance_us(350.0)
    assert s.poll() == 1                 # flushed at its own deadline
    assert f.result(0) == 2.0


def test_nonpositive_budget_rejected_at_admission():
    s = MicroBatchScheduler(_sum_executor([]), SchedConfig(),
                            clock=FakeClock())
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones(2), deadline_us=-5.0)
    assert e.value.reason == RejectReason.DEADLINE_EXCEEDED


def test_edf_ordering_within_lane_vs_fifo():
    clk, order = FakeClock(), []

    def ex(x):
        order.extend(int(v) for v in x[:, 0])
        return x[:, 0]

    s = MicroBatchScheduler(ex, SchedConfig(max_batch=1, n_priorities=1),
                            clock=clk)
    s.submit(np.full((1, 1), 1.0), deadline_us=500.0)
    s.submit(np.full((1, 1), 2.0), deadline_us=100.0)  # tighter, later
    s.drain()
    assert order == [2, 1]               # EDF, not arrival FIFO

    order.clear()
    s2 = MicroBatchScheduler(ex, SchedConfig(max_batch=1, n_priorities=1),
                             clock=clk)
    s2.submit(np.full((1, 1), 1.0))      # no deadlines: FIFO preserved
    s2.submit(np.full((1, 1), 2.0))
    s2.drain()
    assert order == [1, 2]


def test_per_lane_miss_rate_accounting():
    clk = FakeClock()

    def slow_ex(x):                      # execution outlives the tight SLO
        clk.advance_us(150.0)
        return x.sum(axis=-1)

    s = MicroBatchScheduler(slow_ex,
                            SchedConfig(max_batch=8, max_wait_us=1e6,
                                        n_priorities=2,
                                        lane_slo_us=(100.0, 10_000.0)),
                            clock=clk)
    tight = s.submit(np.ones(2), priority=0)
    loose = s.submit(np.ones(2), priority=1)
    assert s.drain() == 2
    assert tight.result(0) == 2.0 and loose.result(0) == 2.0
    snap = s.metrics.snapshot()
    # lane 0 completed but 50 µs past its deadline: a served-late miss
    assert snap["lanes"]["0"]["missed"] == 1
    assert snap["lanes"]["0"]["deadline_miss_rate"] == 1.0
    assert snap["lanes"]["1"]["missed"] == 0
    assert snap["lanes"]["1"]["deadline_miss_rate"] == 0.0
    assert snap["lanes"]["1"]["mean_slack_us"] == pytest.approx(9850.0)
    # now an expiry shed on the tight lane joins the miss accounting
    f = s.submit(np.ones(2), priority=0)
    clk.advance_us(200.0)
    s.drain()
    with pytest.raises(RequestRejected):
        f.result(0)
    snap = s.metrics.snapshot()
    assert snap["lanes"]["0"]["shed"] == 1
    assert snap["deadline_miss_rate"] == pytest.approx(2 / 3)


def test_next_deadline_wakes_on_slo_not_arrival_age():
    clk = FakeClock(1000.0)
    s = MicroBatchScheduler(_sum_executor([]),
                            SchedConfig(max_wait_us=1e6, n_priorities=1,
                                        lane_slo_us=(100.0,)), clock=clk)
    assert s.next_deadline_us() is None
    s.submit(np.ones(2))
    assert s.next_deadline_us() == 1100.0    # the SLO, not enqueue+1e6

    s2 = MicroBatchScheduler(_sum_executor([]),
                             SchedConfig(max_wait_us=200.0), clock=clk)
    s2.submit(np.ones(2))
    assert s2.next_deadline_us() == 1200.0   # no SLO: arrival age cap


# ---------------------------------------------------------------------------
# Deadline-aware replica dispatch
# ---------------------------------------------------------------------------

def test_replica_failover_restamps_remaining_budget():
    clk = FakeClock()

    def crash_slowly(x):
        clk.advance_us(200.0)            # the failure ate the whole budget
        raise RuntimeError("replica crash")

    rs = ReplicaSet([crash_slowly, lambda x: x.sum(axis=-1)], policy="rr",
                    clock=clk)
    with pytest.raises(RequestRejected) as e:
        rs(np.ones((1, 2)), deadline_us=100.0)
    assert e.value.reason == RejectReason.DEADLINE_EXCEEDED
    # the healthy replica is still up: budget-free traffic flows on
    np.testing.assert_allclose(rs(np.ones((1, 2))), [2.0])
    assert [r["healthy"] for r in rs.stats()] == [False, True]


def test_replica_failover_within_budget_still_retries():
    clk = FakeClock()

    def crash_fast(x):
        clk.advance_us(10.0)
        raise RuntimeError("replica crash")

    rs = ReplicaSet([crash_fast, lambda x: x.sum(axis=-1)], policy="rr",
                    clock=clk)
    np.testing.assert_allclose(rs(np.ones((1, 2)), deadline_us=100.0), [2.0])


def test_least_slack_policy_picks_smallest_expected_completion():
    rs = ReplicaSet([lambda x: x, lambda x: x], policy="least_slack")
    rs.replicas[0].ewma_us, rs.replicas[0].inflight = 100.0, 1
    rs.replicas[1].ewma_us, rs.replicas[1].inflight = 300.0, 0
    picked = rs._pick()                  # (1+1)*100 = 200 < (0+1)*300
    assert picked.rid == 0
    rs.replicas[0].inflight -= 1


# ---------------------------------------------------------------------------
# Priority lanes
# ---------------------------------------------------------------------------

def test_priority_ordering_within_flush():
    clk, order = FakeClock(), []

    def ex(x):
        order.extend(int(v) for v in x[:, 0])
        return x[:, 0]

    s = MicroBatchScheduler(ex, SchedConfig(max_batch=2, max_wait_us=10.0,
                                            n_priorities=2), clock=clk)
    lo = s.submit(np.full((1, 1), 9.0), priority=1)
    hi = [s.submit(np.full((1, 1), float(i)), priority=0) for i in range(3)]
    clk.advance_us(11.0)
    s.poll()
    # lane 0 drains FIFO first; the lone low-priority request flushes last
    assert order == [0, 1, 2, 9]
    assert lo.result(0) == 9.0 and hi[0].result(0) == 0.0


def test_bad_priority_rejected():
    s = MicroBatchScheduler(_sum_executor([]),
                            SchedConfig(n_priorities=2), clock=FakeClock())
    with pytest.raises(RequestRejected) as e:
        s.submit(np.ones(2), priority=5)
    assert e.value.reason == RejectReason.BAD_PRIORITY


def test_bounded_priority_queue_is_lm_admission_core():
    q = BoundedPriorityQueue(max_queue=2, n_priorities=3)

    def req(p):
        return ServeRequest(x=None, rows=1, priority=p, t_enqueue_us=0.0,
                            future=ServeFuture())

    q.push(req(2))
    q.push(req(0))
    with pytest.raises(RequestRejected) as e:
        q.push(req(1))
    assert e.value.reason == RejectReason.QUEUE_FULL
    (first,) = q.pop_batch(1)
    assert first.priority == 0           # freed slot admits high lane first


# ---------------------------------------------------------------------------
# Executor failure + replica failover
# ---------------------------------------------------------------------------

def test_executor_error_fails_batch_not_scheduler():
    clk = FakeClock()
    calls = []

    def flaky(x):
        calls.append(x.shape[0])
        if len(calls) == 1:
            raise RuntimeError("boom")
        return x.sum(axis=-1)

    s = MicroBatchScheduler(flaky, SchedConfig(max_batch=2), clock=clk)
    bad = [s.submit(np.ones(2)) for _ in range(2)]
    assert s.poll() == 2                 # resolved, but with the error set
    for f in bad:
        with pytest.raises(RuntimeError):
            f.result(0)
    good = [s.submit(np.ones(2)) for _ in range(2)]
    s.poll()
    assert [f.result(0) for f in good] == [2.0, 2.0]
    assert s.metrics.snapshot()["errors"] == 2


def test_replica_failover_marks_down_and_retries():
    down = {"n": 0}

    def bad(x):
        down["n"] += 1
        raise RuntimeError("replica crash")

    rs = ReplicaSet([bad, lambda x: x.sum(axis=-1)], policy="rr")
    np.testing.assert_allclose(rs(np.ones((2, 3))), [3.0, 3.0])
    assert down["n"] == 1
    rs(np.ones((1, 3)))                  # dead replica skipped, not retried
    assert down["n"] == 1
    stats = rs.stats()
    assert [r["healthy"] for r in stats] == [False, True]
    assert stats[1]["served"] == 2 and stats[0]["failures"] == 1


def test_all_replicas_down_raises_through_scheduler():
    def bad(x):
        raise RuntimeError("dead")

    rs = ReplicaSet([bad, bad])
    s = MicroBatchScheduler(rs, SchedConfig(max_batch=1), clock=FakeClock())
    f = s.submit(np.ones(2))
    s.poll()
    with pytest.raises(AllReplicasDown):
        f.result(0)


def test_least_loaded_prefers_idle_replica():
    rs = ReplicaSet([lambda x: x, lambda x: x], policy="least_loaded")
    rs.replicas[0].inflight = 3          # simulate a busy replica
    picked = rs._pick()
    assert picked.rid == 1
    rs.replicas[1].inflight -= 1


# ---------------------------------------------------------------------------
# Scheduled serving on JSC-S: all backends, bit-identical to classify
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def jsc_small():
    from repro.configs.jsc import JSC_S
    from repro.data.jsc import train_test
    from repro.models.mlp import to_logic
    from repro.train.jsc_trainer import train_jsc
    data = train_test(2000, 400, seed=2)
    res = train_jsc(JSC_S, steps=120, batch=128, data=data)
    net = to_logic(JSC_S, res.params, res.masks, res.bn_state)
    return net, data[1][0]


@pytest.mark.parametrize("backend", ["gather", "pallas", "bitplane"])
def test_scheduled_matches_direct_classify(jsc_small, backend):
    from repro.serving.engine import LogicEngine
    net, xte = jsc_small
    eng = LogicEngine(net, 5, max_batch=64, backend=backend)
    want = eng.classify(xte[:96])
    clk = FakeClock()
    s = MicroBatchScheduler(eng.scheduler_executor(),
                            SchedConfig(max_batch=64, max_wait_us=100.0,
                                        max_queue=200), clock=clk)
    futs = [s.submit(xte[i]) for i in range(96)]   # single-sample requests
    assert s.drain() == 96
    got = np.array([int(f.result(0)) for f in futs], np.int32)
    np.testing.assert_array_equal(got, want)
    snap = s.metrics.snapshot()
    assert snap["n_batches"] == 2                  # 96 rows / max_batch 64
    assert snap["mean_batch_occupancy"] == pytest.approx(0.75)


def test_bitplane_aggregator_packs_requests_into_lanes(jsc_small):
    from repro.serving.engine import LogicEngine
    net, xte = jsc_small
    eng = LogicEngine(net, 5, max_batch=64, backend="bitplane")
    agg = BitplaneAggregator(eng.bitnet, 5)
    got = agg(xte[:40])
    np.testing.assert_array_equal(got, eng.classify(xte[:40]))
    # 40 requests -> 2 lane-words per input wire (32 + 8 lanes)
    n_wires = net.n_inputs * eng.bitnet.in_bits
    assert agg.pack_requests(xte[:40]).shape == (n_wires, 2)
    assert agg.mean_lane_occupancy == pytest.approx(40 / 64)


def test_aggregator_occupancy_counts_real_rows_under_pad_rows(jsc_small):
    from repro.serving.engine import LogicEngine
    net, xte = jsc_small
    eng = LogicEngine(net, 5, max_batch=64, backend="bitplane")
    agg = BitplaneAggregator(eng.bitnet, 5, pad_rows=64)
    got = agg(xte[:16])
    np.testing.assert_array_equal(got, eng.classify(xte[:16]))
    # 16 real rows in one lane-word: occupancy is 16/32, not deflated by
    # the 48 shape-stability pad rows (which get their own counter)
    assert agg.n_evals == 1 and agg.n_rows == 16
    assert agg.mean_lane_occupancy == pytest.approx(16 / 32)
    assert agg.n_pad_rows == 48
    assert agg.n_partial_packs == 1
    assert agg.n_features == net.n_inputs


def test_serve_queue_wrapper_reports_true_latency(jsc_small):
    from repro.serving.engine import LogicEngine
    net, xte = jsc_small
    eng = LogicEngine(net, 5, max_batch=64, backend="gather")
    reqs = [xte[i * 32: (i + 1) * 32] for i in range(4)]
    results, stats = eng.serve_queue(reqs)
    assert len(results) == 4
    np.testing.assert_array_equal(np.concatenate(results),
                                  eng.classify(xte[:128]))
    for key in ("p50_us", "p95_us", "p99_us", "mean_us", "qps",
                "mean_batch_occupancy"):
        assert key in stats
    assert stats["p95_us"] >= stats["p50_us"] > 0.0


def test_threaded_driver_end_to_end(jsc_small):
    from repro.serving.engine import LogicEngine
    net, xte = jsc_small
    eng = LogicEngine(net, 5, max_batch=64, backend="gather")
    s = MicroBatchScheduler(eng.scheduler_executor(),
                            SchedConfig(max_batch=64, max_wait_us=500.0,
                                        max_queue=400)).start()
    futs = [s.submit(xte[i]) for i in range(200)]
    got = np.array([int(f.result(timeout=30)) for f in futs], np.int32)
    s.stop(drain=True)
    np.testing.assert_array_equal(got, eng.classify(xte[:200]))
    assert s.metrics.snapshot()["completed"] == 200


# ---------------------------------------------------------------------------
# LM admission behind the scheduler queue
# ---------------------------------------------------------------------------

def test_lm_engine_admission_backpressure_and_priority():
    import jax

    from repro.configs import get_arch
    from repro.models import lm
    from repro.serving.engine import LMEngine, LMRequest

    cfg = get_arch("glm4-9b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = LMEngine(cfg, params, n_slots=1, max_seq=32, max_pending=2)
    rng = np.random.default_rng(0)

    def req():
        return LMRequest(prompt=rng.integers(0, cfg.vocab_size, 4,
                                             dtype=np.int32),
                         max_new_tokens=2)

    lo, hi = req(), req()
    lo_fut = eng.submit(lo, priority=1)
    hi_fut = eng.submit(hi, priority=0)
    with pytest.raises(RequestRejected) as e:
        eng.submit(req())
    assert e.value.reason == RejectReason.QUEUE_FULL
    done = eng.run()
    assert len(done) == 2
    # single slot: the high-priority request must have been admitted first
    assert done[0] is hi and done[1] is lo
    assert all(len(r.out_tokens) == 2 for r in done)
    # the futures resolve to the finished requests with real latencies
    assert hi_fut.result(0) is hi and lo_fut.result(0) is lo
    assert lo_fut.latency_us >= hi_fut.latency_us > 0.0
