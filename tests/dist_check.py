"""Multi-device semantic checks, run in a subprocess with 8 forced host
devices (tests/test_dist_opts.py drives this).

Verifies the §Perf sharding strategies are SEMANTICS-PRESERVING:
  moe      — shard_map MoE == single-device vmap MoE
  fsdp     — fsdp_pure train step loss == baseline layout loss
  decode   — decode logits on mesh == decode logits without mesh
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_arch
from repro.dist import shardings as sh
from repro.models import layers as L
from repro.models import lm

MESH = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))


def check_moe():
    import dataclasses
    cfg = dataclasses.replace(get_arch("mixtral-8x22b", smoke=True),
                              n_experts=4, capacity_factor=8.0)
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                          jnp.float32)
    ref = L.moe(x, p, cfg)                      # no mesh -> vmap path
    sh.set_opts(moe_ep=True)
    with sh.use_mesh(MESH):
        got = jax.jit(lambda x, p: L.moe(x, p, cfg))(x, p)
    sh.set_opts(moe_ep=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
    print("moe ok")


def check_fsdp():
    from repro.train.loop import init_state, make_train_step
    from repro.train.optim import AdamW
    cfg = get_arch("phi4-mini-3.8b", smoke=True)
    opt = AdamW(lr=1e-3)
    step = make_train_step(cfg, opt)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    _, m_ref = jax.jit(step)(state, batch)

    sh.set_opts(fsdp_pure=True)
    with sh.use_mesh(MESH):
        _, m_got = jax.jit(step)(state, batch)
    sh.set_opts(fsdp_pure=False)
    np.testing.assert_allclose(float(m_got["loss"]), float(m_ref["loss"]),
                               rtol=3e-2)
    print("fsdp ok")


def check_decode():
    cfg = get_arch("glm4-9b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                              cfg.vocab_size)
    _, cache = lm.prefill(cfg, params, tokens=toks[:, :32], max_seq=40)
    pos = jnp.full((4,), 32, jnp.int32)
    ref, _ = lm.decode_step(cfg, params, cache, toks[:, 32:33], pos)

    sh.set_opts(serve_tp_only=True)
    with sh.use_mesh(MESH):
        got, _ = jax.jit(
            lambda p, c, t, q: lm.decode_step(cfg, p, c, t, q))(
                params, cache, toks[:, 32:33], pos)
    sh.set_opts(serve_tp_only=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)
    assert (np.asarray(got).argmax(-1) == np.asarray(ref).argmax(-1)).all()
    print("decode ok")


def check_elastic():
    """Checkpoint written under one mesh restores onto a DIFFERENT mesh
    (elastic rescale / degraded-pod restart path)."""
    import tempfile

    from repro.train import checkpoint as ckpt
    cfg = get_arch("glm4-9b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    mesh_a = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                  ("data", "model"))
    mesh_b = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                  ("data", "model"))
    sh_a = sh.params_shardings(mesh_a, params)
    placed = jax.device_put(params, sh_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, placed)
        sh_b = sh.params_shardings(mesh_b, params)
        restored = ckpt.restore_latest(d, params, shardings=sh_b)
    for (pa, a), (pb, bb) in zip(
            jax.tree_util.tree_flatten_with_path(placed)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
    # restored leaves carry the NEW mesh's sharding
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding.mesh.shape["data"] == 4
    print("elastic ok")


def check_pipeline():
    """GPipe pipeline over 4 stages == plain scan forward, and grads
    flow through the ppermute schedule."""
    from repro.dist.pipeline import pipeline_lm_forward
    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    cfg = get_arch("glm4-9b", smoke=True)  # 2 layers -> pad to 4 stages
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=4)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg.vocab_size)
    hidden_ref, _, _ = lm.forward(cfg, params, tokens=toks)
    with sh.use_mesh(mesh):
        hidden_pp = jax.jit(
            lambda p, t: pipeline_lm_forward(cfg, p, t, mesh, n_micro=2)
        )(params, toks)
    np.testing.assert_allclose(
        np.asarray(hidden_pp, np.float32),
        np.asarray(hidden_ref, np.float32), rtol=5e-2, atol=5e-2)

    def loss(p):
        h = pipeline_lm_forward(cfg, p, toks, mesh, n_micro=2)
        return lm.lm_loss(cfg, p, h, toks)

    with sh.use_mesh(mesh):
        g = jax.jit(jax.grad(loss))(params)
    gn = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.square(x.astype(jnp.float32)))),
        g, 0.0)
    assert np.isfinite(gn) and gn > 0
    print("pipeline ok")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("pipeline", "all"):
        check_pipeline()
    if which in ("moe", "all"):
        check_moe()
    if which in ("fsdp", "all"):
        check_fsdp()
    if which in ("decode", "all"):
        check_decode()
    if which in ("elastic", "all"):
        check_elastic()
    print("DIST CHECKS PASSED")
