"""Serving engines: logic micro-batching + LM continuous batching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import lm
from repro.serving.engine import LMEngine, LMRequest


def test_lm_engine_matches_single_request():
    """Continuous batching must produce the same tokens as a dedicated
    single-request decode loop (greedy)."""
    cfg = get_arch("glm4-9b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
               for _ in range(3)]

    # reference: sequential greedy decode per prompt
    def greedy(prompt, n_new):
        toks = jnp.asarray(prompt[None, :])
        logits, cache = lm.prefill(cfg, params, tokens=toks, max_seq=64)
        out = [int(jnp.argmax(logits[0]))]
        pos = prompt.shape[0]
        for _ in range(n_new - 1):
            nt = jnp.asarray([[out[-1]]], jnp.int32)
            logits, cache = lm.decode_step(cfg, params, cache, nt,
                                           jnp.asarray([pos], jnp.int32))
            out.append(int(jnp.argmax(logits[0])))
            pos += 1
        return out

    want = [greedy(p, 5) for p in prompts]

    eng = LMEngine(cfg, params, n_slots=2, max_seq=64)
    reqs = [LMRequest(prompt=p, max_new_tokens=5) for p in prompts]
    done = eng.run(reqs)
    got = {id(r): r.out_tokens for r in done}
    for r, w in zip(reqs, want):
        assert got[id(r)] == w


def test_lm_engine_slot_reuse():
    """More requests than slots: all must complete."""
    cfg = get_arch("falcon-mamba-7b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [LMRequest(prompt=rng.integers(0, cfg.vocab_size, 8,
                                          dtype=np.int32),
                      max_new_tokens=3) for _ in range(5)]
    eng = LMEngine(cfg, params, n_slots=2, max_seq=32)
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.out_tokens) == 3 for r in done)
