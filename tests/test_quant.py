"""QAT primitives: STE quantizers, per-layer activation selection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core import quant as Q


def test_sign_ste_values_and_grad():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    y = Q.sign_ste(x)
    assert set(np.asarray(y).tolist()) <= {-1.0, 1.0}
    g = jax.grad(lambda x: jnp.sum(Q.sign_ste(x)))(x)
    # clipped-identity STE: grad 1 inside [-1, 1], 0 outside
    np.testing.assert_allclose(np.asarray(g), [0, 1, 1, 1, 0])


def test_pact_clips_and_quantizes():
    x = jnp.linspace(-1, 8, 100)
    y = Q.pact(x, jnp.asarray(5.0), bits=2)
    lv = np.asarray(Q.pact_levels(5.0, 2))
    assert np.all(np.isin(np.round(np.asarray(y), 5), np.round(lv, 5)))
    assert float(y.max()) == pytest.approx(5.0)
    assert float(y.min()) == 0.0


def test_pact_alpha_gradient():
    # d/dalpha is 1 where x >= alpha (PACT paper), ~0 well below clip
    f = lambda a, x: jnp.sum(Q.pact(x, a, bits=4))
    g_hi = jax.grad(f)(jnp.asarray(2.0), jnp.asarray([5.0, 7.0]))
    assert float(g_hi) == pytest.approx(2.0, rel=0.2)


def test_signed_uniform_bits1_is_bipolar():
    x = jnp.asarray([-3.0, 0.2, 4.0])
    y = Q.signed_uniform(x, 1.5, bits=1)
    np.testing.assert_allclose(np.asarray(y), [-1.5, 1.5, 1.5])


def test_selection_rule():
    # the paper's rule: non-negative -> PACT; both signs -> sign/signed
    assert Q.select_activation(True, 4).kind == "pact"
    assert Q.select_activation(True, 1).kind == "binary"
    assert Q.select_activation(False, 1).kind == "sign"
    assert Q.select_activation(False, 3).kind == "signed"


@settings(max_examples=30, deadline=None)
@given(kind=st.sampled_from(["sign", "binary", "pact", "signed"]),
       bits=st.integers(1, 4), alpha=st.floats(0.5, 4.0))
def test_encode_decode_roundtrip(kind, bits, alpha):
    """Property: quantize -> encode -> decode is the identity on the
    quantized value set (the contract truth-table extraction relies on)."""
    if kind in ("sign", "binary") and bits != 1:
        bits = 1
    spec = Q.ActQuantSpec(kind, bits)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 2, 64), jnp.float32)
    q = Q.apply_act_quant(spec, x, jnp.asarray(alpha, jnp.float32))
    codes = Q.encode_levels(spec, q, alpha)
    assert int(codes.min()) >= 0 and int(codes.max()) < spec.n_levels
    decoded = Q.decode_levels(spec, codes, alpha)
    np.testing.assert_allclose(np.asarray(decoded), np.asarray(q),
                               rtol=1e-5, atol=1e-5)


def test_dorefa_weights():
    w = jnp.asarray(np.random.default_rng(1).normal(size=(8, 8)),
                    jnp.float32)
    w1 = Q.dorefa_weight(w, 1)
    scale = float(jnp.mean(jnp.abs(w)))
    assert np.allclose(np.abs(np.asarray(w1)), scale, rtol=1e-5)
    w2 = Q.dorefa_weight(w, 2)
    assert len(np.unique(np.round(np.asarray(w2), 5))) <= 4


def test_fold_bn_equivalence(rng):
    w = jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
    gamma = jnp.asarray(rng.uniform(0.5, 2, 4), jnp.float32)
    beta = jnp.asarray(rng.normal(size=4), jnp.float32)
    mean = jnp.asarray(rng.normal(size=4), jnp.float32)
    var = jnp.asarray(rng.uniform(0.5, 2, 4), jnp.float32)
    x = jnp.asarray(rng.normal(size=(16, 6)), jnp.float32)
    y_bn = (x @ w.T + b - mean) / jnp.sqrt(var + 1e-5) * gamma + beta
    w2, b2 = Q.fold_bn(w, b, gamma, beta, mean, var)
    np.testing.assert_allclose(np.asarray(x @ w2.T + b2), np.asarray(y_bn),
                               rtol=1e-4, atol=1e-4)
