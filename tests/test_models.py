"""Per-arch smoke + decode-vs-forward consistency + MoE semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKES, get_arch
from repro.configs.base import SHAPES
from repro.models import layers as L
from repro.models import lm


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_arch_smoke_forward(name):
    """One forward/train step on CPU: output shapes + no NaNs."""
    cfg = get_arch(name, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jnp.zeros((B, S // 8, cfg.d_model), jnp.bfloat16)
    hidden, _, _ = lm.forward(cfg, params, tokens=tokens, **kw)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden.astype(jnp.float32))))
    loss = lm.lm_loss(cfg, params, hidden, tokens)
    assert bool(jnp.isfinite(loss))

    def lf(p):
        h, _, _ = lm.forward(cfg, p, tokens=tokens, **kw)
        return lm.lm_loss(cfg, p, h, tokens)

    g = jax.grad(lf)(params)
    gn = jax.tree_util.tree_reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), g, 0.0)
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("name", ["glm4-9b", "falcon-mamba-7b",
                                  "hymba-1.5b", "mixtral-8x22b",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_forward(name):
    """prefill(x[:s]) + decode_step(x[s]) logits == forward(x[:s+1]) last
    logits — validates KV/ring/SSM caches against the sequence path.

    MoE runs with a large capacity factor: capacity-based token dropping
    is sequence-length-dependent by construction, so drop-free routing is
    the regime where decode and forward must agree exactly."""
    cfg = get_arch(name, smoke=True)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 48
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encdec:
        kw["enc_embeds"] = jnp.asarray(
            jax.random.normal(key, (B, (S + 1) // 8, cfg.d_model)) * 0.1,
            jnp.bfloat16)

    hidden, _, _ = lm.forward(cfg, params, tokens=tokens, **kw)
    ref_logits = lm.logits_head(cfg, params, hidden[:, -1])

    kw_p = dict(kw)
    if cfg.is_encdec:  # same encoder context for both paths
        kw_p["enc_embeds"] = kw["enc_embeds"]
    _, cache = lm.prefill(cfg, params, tokens=tokens[:, :S], max_seq=S + 8, **kw_p)
    got_logits, _ = lm.decode_step(
        cfg, params, cache, tokens[:, S:S + 1],
        jnp.full((B,), S, jnp.int32))

    ref = np.asarray(ref_logits, np.float32)
    got = np.asarray(got_logits, np.float32)
    # bf16 paths; compare top-1 and numerics loosely
    np.testing.assert_allclose(got, ref, rtol=0.1, atol=0.15)
    np.testing.assert_array_equal(got.argmax(-1), ref.argmax(-1))


def test_swa_ring_cache_long_decode():
    """Hybrid ring cache stays finite and consistent past the window."""
    cfg = get_arch("hymba-1.5b", smoke=True)  # window 64
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 96  # prompt past the window
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab_size)
    hidden, _, _ = lm.forward(cfg, params, tokens=tokens)
    ref_logits = lm.logits_head(cfg, params, hidden[:, -1])
    _, cache = lm.prefill(cfg, params, tokens=tokens[:, :S], max_seq=S + 8)
    assert cache["k"].shape[2] == cfg.window  # ring-bounded
    got, _ = lm.decode_step(cfg, params, cache, tokens[:, S:],
                            jnp.full((B,), S, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got).argmax(-1),
                                  np.asarray(ref_logits).argmax(-1))


def test_moe_matches_dense_when_experts_identical():
    """If all experts share weights, top-k MoE == that dense MLP."""
    cfg = dataclasses.replace(get_arch("mixtral-8x22b", smoke=True),
                              capacity_factor=4.0)  # no token dropping
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg)
    # replicate expert 0 everywhere
    for k in ("w1", "w2", "w3"):
        p[k] = jnp.broadcast_to(p[k][:1], p[k].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    got = L.moe(x, p, cfg)
    dense_p = {"w1": p["w1"][0], "w2": p["w2"][0], "w3": p["w3"][0]}
    want = L.mlp(x, dense_p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """With tiny capacity, overflow tokens get zero output (not NaN)."""
    cfg = dataclasses.replace(get_arch("mixtral-8x22b", smoke=True),
                              capacity_factor=0.05)
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    out = L.moe(x, p, cfg)
    assert not bool(jnp.any(jnp.isnan(out)))
    # some token rows must be exactly zero (dropped)
    norms = np.asarray(jnp.sum(jnp.abs(out), axis=-1))[0]
    assert (norms == 0).sum() > 0


def test_mamba_step_matches_forward():
    from repro.models import mamba as M
    cfg = get_arch("falcon-mamba-7b", smoke=True)
    p = M.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model),
                          jnp.float32)
    y_seq, states = M.mamba_forward(x, p, cfg, return_state=True)
    # replay sequentially through mamba_step
    cache = M.init_mamba_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(24):
        y, cache = M.mamba_step(x[:, t], cache, p, cfg)
        outs.append(y)
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(states["ssm"]),
                               np.asarray(cache["ssm"]), rtol=2e-3,
                               atol=2e-3)


def test_param_count_magnitudes():
    """Analytic param counts land near the published sizes."""
    expect = {"glm4-9b": 9.4e9, "deepseek-67b": 67e9,
              "falcon-mamba-7b": 7.3e9, "mixtral-8x22b": 141e9,
              "chameleon-34b": 34e9, "dbrx-132b": 132e9,
              "nemotron-4-340b": 340e9, "phi4-mini-3.8b": 3.8e9,
              "hymba-1.5b": 1.5e9}
    for name, want in expect.items():
        got = ARCHS[name].param_count()
        assert 0.75 * want < got < 1.35 * want, (name, got, want)


def test_long_500k_support_matrix():
    runnable = {a.name for a in ARCHS.values()
                if a.supports_shape(SHAPES["long_500k"])}
    assert runnable == {"falcon-mamba-7b", "hymba-1.5b", "mixtral-8x22b"}


def test_mamba_chunked_scan_matches_flat():
    """Chunked linear scan == flat associative scan (any S multiple)."""
    from repro.models.mamba import _chunked_linear_scan
    rng = np.random.default_rng(4)
    for s, chunk in [(64, 16), (48, 16), (100, 16), (32, 64)]:
        a = jnp.asarray(rng.uniform(0.5, 1.0, (2, s, 4, 3)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(2, s, 4, 3)), jnp.float32)
        got = _chunked_linear_scan(a, b, chunk=chunk)
        def comb(l, r):
            return l[0] * r[0], r[1] + r[0] * l[1]
        _, ref = jax.lax.associative_scan(comb, (a, b), axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
