"""End-to-end paper flow on a reduced JSC config: QAT+FCP train ->
logic compile -> bit-exact serving -> hardware report."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.jsc import JSC_DEMO
from repro.data.jsc import train_test
from repro.models.mlp import final_masks, mlp_forward, to_logic
from repro.serving.engine import LogicEngine
from repro.train.jsc_trainer import train_jsc

CFG = JSC_DEMO
DATA = train_test(4000, 1000, seed=0)


@pytest.fixture(scope="module")
def trained():
    return train_jsc(CFG, steps=400, batch=128, data=DATA)


def test_training_reaches_signal(trained):
    assert trained.test_acc > 0.5  # far above 20% chance


def test_fanin_budget_respected(trained):
    for i, m in enumerate(trained.masks):
        rows = np.asarray(m).sum(1)
        assert rows.max() <= CFG.fanins[i]


def test_logic_equals_qat_network(trained):
    """Compiled logic network is bit-exact vs the quantized MLP."""
    net = to_logic(CFG, trained.params, trained.masks, trained.bn_state)
    (xte, yte) = DATA[1]
    x = jnp.asarray(xte[:512])
    scores_mlp, _ = mlp_forward(CFG, trained.params, trained.masks,
                                trained.bn_state, x, train=False)
    pred_mlp = np.asarray(jnp.argmax(scores_mlp[:, :5], -1))
    out = net(x)
    pred_logic = np.asarray(jnp.argmax(out[:, :5], -1))
    np.testing.assert_array_equal(pred_mlp, pred_logic)


def test_logic_engine_serving(trained):
    net = to_logic(CFG, trained.params, trained.masks, trained.bn_state)
    eng = LogicEngine(net, 5, max_batch=128)
    (xte, yte) = DATA[1]
    pred = eng.classify(xte[:300])
    acc = float((pred == yte[:300]).mean())
    assert abs(acc - trained.test_acc) < 0.1


def test_hardware_report_structure(trained):
    from repro.core.logic_infer import hardware_report
    net = to_logic(CFG, trained.params, trained.masks, trained.bn_state)
    rep, per_layer = hardware_report(net)
    assert rep.luts > 0 and rep.depth >= 1 and rep.ffs > 0
    assert rep.fmax_mhz > 100
    assert len(per_layer) == CFG.n_layers
    base, _ = hardware_report(net, minimize_logic=False)
    assert rep.luts <= base.luts
