"""Online telemetry: trace analytics (per-phase attribution +
reconciliation), streaming windowed metrics, the SLO burn-rate monitor
and its scheduler degradation hook, online continuous profiling,
latency-table hardening, the Prometheus pull endpoint, and the
perf-trajectory ledger."""
import json
import urllib.request

import numpy as np
import pytest

from repro.check.tracecheck import (check_phase_reconciliation,
                                    synthetic_trace_events)
from repro.obs import (BucketRing, BurnRateMonitor, EmptyLatencyTable,
                       LatencyTable, LatencyTableError, MetricsRegistry,
                       MetricsServer, OnlineProfiler, SpanTracer,
                       TraceEvent, WindowedMetrics, analyze_events,
                       analyze_trace, to_prometheus_text,
                       write_chrome_trace)
from repro.obs.analyze import (diff_reports, format_diff, format_report,
                               main as analyze_main)
from repro.serve import (FakeClock, MicroBatchScheduler, RejectReason,
                         ReplicaSet, RequestRejected, SchedConfig)


def _ev(ph, name, ts, dur=0.0, tid=1, sid=None, args=None, cat="request"):
    return TraceEvent(ph, name, cat, ts, dur, tid, sid, args)


def _traced_run(exec_us=100.0, n=8, gap_us=10.0):
    """FakeClock scheduler run: n requests in size-4 batches, every
    timestamp deterministic, so phase sums reconcile exactly."""
    clk = FakeClock()
    tracer = SpanTracer(clock=clk, capacity=8192)

    def ex(x):
        clk.advance_us(exec_us)
        return x.sum(axis=-1)

    s = MicroBatchScheduler(ex, SchedConfig(max_batch=4,
                                            max_wait_us=500.0),
                            clock=clk, tracer=tracer)
    futs = []
    for i in range(n):
        futs.append(s.submit(np.full((1, 3), i, np.float32)))
        clk.advance_us(gap_us)
        s.poll()
    s.poll(force=True)
    for f in futs:
        f.result(0)
    return clk, tracer, s


# ---------------------------------------------------------------------------
# Trace analytics: reconciliation + phase attribution
# ---------------------------------------------------------------------------

def test_analyze_reconciles_fakeclock_trace_exactly():
    _, tracer, _ = _traced_run()
    rpt = analyze_events(tracer.events())
    rec = rpt.reconciliation()
    assert rec["n_checked"] == 8
    assert rec["ok"] and rec["max_rel_err"] == 0.0
    # every ok request got full per-phase attribution and its phases
    # (minus post-completion scatter) sum to its measured latency
    for r in rpt.requests:
        ph = r.phases_us()
        assert ph is not None and r.outcome == "ok"
        attributed = sum(v for p, v in ph.items() if p != "scatter")
        assert attributed == pytest.approx(r.latency_us)
    summary = rpt.phase_summary()
    assert summary["dispatch"]["mean_us"] == pytest.approx(100.0)
    text = format_report(rpt)
    assert "where did the time go" in text and "reconciliation" in text


def test_analyze_cli_roundtrip(tmp_path, capsys):
    _, tracer, _ = _traced_run()
    path = str(tmp_path / "t.json")
    write_chrome_trace(path, tracer)
    assert analyze_main(["--trace", path]) == 0
    capsys.readouterr()
    assert analyze_main(["--trace", path, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["reconciliation"]["ok"] and doc["n_requests"] == 8
    assert analyze_trace(path).reconciliation()["ok"]


def test_analyze_trace_diff_attributes_regression():
    _, t_fast, _ = _traced_run(exec_us=100.0)
    _, t_slow, _ = _traced_run(exec_us=300.0)
    d = diff_reports(analyze_events(t_slow.events()),
                     analyze_events(t_fast.events()))
    # the executor got 3x slower and nothing else moved: the diff must
    # pin the regression on the executor-time phase
    assert d["attribution"] == "dispatch"
    assert d["phases"]["dispatch"]["direction"] == "regressed"
    assert d["phases"]["dispatch"]["delta_us"] == pytest.approx(200.0)
    assert "dispatch" in format_diff(d)


def test_analyze_truncated_trace_reports_not_crashes():
    # ring-buffer truncation: ends whose begins were dropped
    evs = [
        _ev("e", "queue_wait", 50.0, sid=1,
            args={"flush_reason": "size", "wait_us": 50.0}),
        _ev("X", "batch_form", 50.0, dur=0.0, cat="batch",
            args={"flush_reason": "size", "rows": 1, "n_requests": 1}),
        _ev("X", "exec", 50.0, dur=100.0, cat="exec"),
        _ev("e", "request", 150.0, sid=1,
            args={"outcome": "ok", "latency_us": 150.0}),
        _ev("e", "request", 160.0, sid=2, args={"outcome": "shed"}),
    ]
    rpt = analyze_events(evs)
    assert rpt.counts["orphan_ends"] >= 1
    truncated = [r for r in rpt.requests if r.truncated]
    assert truncated
    # truncated lifecycles are excluded from reconciliation, never
    # counted as failures
    assert rpt.reconciliation()["ok"]
    format_report(rpt)                   # must render


def test_analyze_zero_request_trace():
    rpt = analyze_events([])
    assert rpt.requests == [] and rpt.batches == []
    rec = rpt.reconciliation()
    assert rec["ok"] and rec["n_checked"] == 0
    assert "no completed requests" in format_report(rpt)


def test_analyze_shed_heavy_trace():
    # the synthetic check fixture covers every lifecycle edge: size and
    # max-wait flushes, expiry shed, admission reject, shutdown drain
    events, _ = synthetic_trace_events()
    rpt = analyze_events(events)
    d = rpt.to_dict()
    assert d["outcomes"].get("shed", 0) >= 1
    assert d["counts"]["rejects"] >= 1
    assert d["reconciliation"]["ok"]
    for r in rpt.requests:               # shed requests never rode a batch
        if r.outcome == "shed":
            assert r.phases_us() is None
    format_report(rpt)


def test_check_phase_reconciliation_pass():
    _, tracer, _ = _traced_run()
    rep = check_phase_reconciliation(tracer.events())
    assert rep.ok and rep.checked > 0
    assert rep.info["phase_recon"]["ok"]
    # a request claiming far more latency than its phases account for
    # is a broken trace — the pass must say so
    bad = [
        _ev("b", "request", 0.0, sid=1, args={"lane": 0, "rows": 1}),
        _ev("b", "queue_wait", 0.0, sid=1),
        _ev("e", "queue_wait", 10.0, sid=1,
            args={"flush_reason": "size", "wait_us": 10.0}),
        _ev("X", "batch_form", 10.0, dur=0.0, cat="batch",
            args={"flush_reason": "size", "rows": 1, "n_requests": 1}),
        _ev("X", "exec", 10.0, dur=100.0, cat="exec"),
        _ev("e", "request", 1000.0, sid=1,
            args={"outcome": "ok", "latency_us": 1000.0}),
    ]
    rep = check_phase_reconciliation(bad)
    assert not rep.ok
    assert any(i.code == "phase-reconcile" for i in rep.errors)
    # same trace from a truncated ring buffer: warning, not error
    rep = check_phase_reconciliation(bad, n_dropped=5)
    assert rep.ok
    assert any(i.code == "phase-reconcile" for i in rep.warnings)


# ---------------------------------------------------------------------------
# Streaming windowed metrics
# ---------------------------------------------------------------------------

def test_bucket_ring_tumbling_and_merged():
    ring = BucketRing(window_us=1000.0, n_windows=4)
    ring.add_done(100.0, 50.0, ok=True)
    ring.add_done(1100.0, 70.0, ok=False)
    ring.add_shed(1200.0)
    rows = ring.series()
    assert [r["t_us"] for r in rows] == [0.0, 1000.0]
    assert rows[0]["n"] == 1 and rows[0]["slo_attainment"] == 1.0
    assert rows[1]["shed"] == 1 and rows[1]["slo_attainment"] == 0.0
    m = ring.merged(1500.0, 2000.0).record(0.0, 2000.0)
    assert m["n"] == 2 and m["shed"] == 1
    assert m["slo_attainment"] == pytest.approx(1 / 3)
    # eviction: writes far in the future drop ancient buckets
    ring.add_done(100_000.0, 1.0, ok=True)
    assert all(r["t_us"] >= 97_000.0 or r["n"] == 0
               for r in ring.series()[:-1]) or len(ring.series()) <= 4


def test_windowed_metrics_as_scheduler_sink():
    clk = FakeClock()
    wm = WindowedMetrics(window_us=1000.0)

    def ex(x):
        clk.advance_us(200.0)
        return x.sum(axis=-1)

    s = MicroBatchScheduler(ex, SchedConfig(max_batch=2), clock=clk)
    s.metrics.add_sink(wm)
    for i in range(6):
        s.submit(np.full((1, 3), i, np.float32))
        s.poll()
        clk.advance_us(800.0)
    ser = wm.series()
    assert ser["window_us"] == 1000.0
    lane0 = ser["lanes"]["0"]
    assert sum(r["n"] for r in lane0) == 6
    assert all(r["slo_attainment"] is None for r in lane0)  # no deadlines
    assert sum(b["n_batches"] for b in ser["batches"]) == 3
    assert ser["batches"][0]["mean_exec_us"] == pytest.approx(200.0)
    slid = wm.sliding(10_000.0)
    assert slid["0"]["n"] == 6 and slid["0"]["p99_us"] > 0
    reg = MetricsRegistry()
    wm.publish(reg, "windows")
    assert reg.snapshot()["windows"]["lanes"]["0"]


# ---------------------------------------------------------------------------
# SLO burn-rate monitor + scheduler degradation
# ---------------------------------------------------------------------------

def _mk_monitor(**kw):
    kw.setdefault("slo_target", 0.9)
    kw.setdefault("long_window_us", 8_000.0)
    kw.setdefault("short_window_us", 1_000.0)
    kw.setdefault("threshold", 2.0)
    kw.setdefault("clear_threshold", 1.0)
    kw.setdefault("min_events", 10)
    return BurnRateMonitor(**kw)


def test_burn_rate_monitor_validation():
    with pytest.raises(ValueError):
        BurnRateMonitor(slo_target=1.5)
    with pytest.raises(ValueError):
        BurnRateMonitor(long_window_us=10.0, short_window_us=10.0)
    with pytest.raises(ValueError):
        BurnRateMonitor(threshold=2.0, clear_threshold=3.0)
    with pytest.raises(ValueError):
        _mk_monitor().check()            # no now_us and no clock bound


def test_burn_rate_fire_and_clear_with_hysteresis():
    mon = _mk_monitor()
    seen = []
    mon.on_alert(seen.append)
    t = 0.0
    for _ in range(20):                  # all-miss traffic: burn = 10x
        mon.record_done(lane=0, latency_us=500.0, now_us=t, ok=False,
                        deadline_us=t - 1.0)
        t += 50.0
    # deadline-free traffic must not dilute the burn
    mon.record_done(lane=0, latency_us=1.0, now_us=t, ok=True,
                    deadline_us=None)
    fired = mon.check(t)
    assert [a.kind for a in fired] == ["fire"]
    assert seen == fired and mon.alerting_lanes() == [0]
    assert fired[0].burn_long > 2.0 and fired[0].burn_short > 2.0
    assert "fire" in str(fired[0])
    assert mon.check(t + 10.0) == []     # still burning: no re-fire
    # traffic recovers; once the short window is clean the alert clears
    t += 3_000.0
    cleared = mon.check(t)
    assert [a.kind for a in cleared] == ["clear"]
    assert mon.alerting_lanes() == []
    assert [a.kind for a in mon.history()] == ["fire", "clear"]
    st = mon.stats(t)
    assert st["alerts_fired"] == 1 and st["lanes"]["0"]["alerting"] is False


def test_burn_rate_needs_min_events():
    mon = _mk_monitor(min_events=50)
    for i in range(20):
        mon.record_done(lane=0, latency_us=500.0, now_us=i * 10.0,
                        ok=False, deadline_us=0.0)
    assert mon.check(200.0) == []        # 20 < 50: noise, not a burn


def test_scheduler_degradation_sheds_loosest_lane():
    clk = FakeClock()
    mon = _mk_monitor()
    fired = []
    mon.on_alert(fired.append)
    s = MicroBatchScheduler(
        lambda x: x.sum(axis=-1),
        SchedConfig(max_batch=4, n_priorities=2,
                    lane_slo_us=(500.0, 5_000.0)),
        clock=clk, slo_monitor=mon)
    assert s._degrade_lane == 1          # largest SLO budget loses first
    # lane 0 burns its budget: 20 deadline misses through the metrics
    # sink path (the monitor is fed by ServeMetrics fan-out)
    for _ in range(20):
        clk.advance_us(20.0)
        s.metrics.record_done(600.0, clk.now_us(), lane=0,
                              deadline_us=clk.now_us() - 1.0)
    # loosest lane (1) is shed with a typed reject while the alert is
    # active; the burning lane itself stays admitted
    with pytest.raises(RequestRejected) as ei:
        s.submit(np.ones((1, 3), np.float32), priority=1)
    assert ei.value.reason == RejectReason.DEGRADED
    assert fired and fired[0].kind == "fire" and fired[0].lane == 0
    assert s.metrics.snapshot()["rejected_by_reason"]["degraded"] == 1
    s.submit(np.ones((1, 3), np.float32), priority=0)
    # burn stops; after a clean short window lane 1 is admitted again
    clk.advance_us(3_000.0)
    f = s.submit(np.ones((1, 3), np.float32), priority=1)
    assert mon.alerting_lanes() == []
    s.poll(force=True)
    f.result(0)


def test_degraded_check_rate_limited():
    clk = FakeClock()
    mon = _mk_monitor()
    s = MicroBatchScheduler(
        lambda x: x.sum(axis=-1),
        SchedConfig(max_batch=64, n_priorities=2,
                    lane_slo_us=(500.0, 5_000.0)),
        clock=clk, slo_monitor=mon)
    calls = []
    orig = mon.check
    mon.check = lambda now_us=None: calls.append(now_us) or orig(now_us)
    for _ in range(10):                  # same instant: one evaluation
        s.submit(np.ones((1, 3), np.float32))
    assert len(calls) == 1
    clk.advance_us(s._monitor_interval_us + 1.0)
    s.submit(np.ones((1, 3), np.float32))
    assert len(calls) == 2


# ---------------------------------------------------------------------------
# Online continuous profiling
# ---------------------------------------------------------------------------

def _grid_table(scale=1.0):
    rows = [{"source": "grid", "level_width": w, "k": 6, "fanin": f,
             "device_us": float(w), "w_words": 128}
            for w in (4, 16) for f in (2, 4)]
    return LatencyTable(rows=rows, meta={}, scale=scale)


class _FakeSched:
    def __init__(self):
        self.pushed = []

    def update_exec_estimate(self, us):
        self.pushed.append(us)


def test_online_profiler_blends_and_pushes():
    t = _grid_table()
    sched = _FakeSched()
    rs = ReplicaSet([lambda x: x], clock=FakeClock(), exec_seed_us=100.0)
    prof = OnlineProfiler(t, predicted_us=100.0, sample_every=2,
                          alpha=0.5).attach(scheduler=sched, replicas=rs)
    prof.observe(200.0, rows=32)         # off-sample: counted, not blended
    assert prof.n_sampled == 0 and t.scale == 1.0
    prof.observe(200.0, rows=32)         # sampled: ratio 2.0 blends in
    assert prof.n_sampled == 1
    assert t.scale == pytest.approx(1.5)
    assert sched.pushed[-1] == pytest.approx(150.0)
    assert rs.stats()[0]["ewma_us"] == pytest.approx(150.0)
    # repeated identical measurements converge on the true ratio
    # instead of compounding (the denominator is scale-normalized)
    for _ in range(40):
        prof.observe(200.0, rows=32)
    assert t.scale == pytest.approx(2.0, rel=1e-3)
    assert prof.estimate_us == pytest.approx(200.0, rel=1e-3)
    st = prof.stats()
    assert st["n_observed"] == 42 and st["last_measured_us"] == 200.0
    reg = MetricsRegistry()
    prof.publish(reg)
    assert reg.snapshot()["online_profile"]["n_sampled"] == st["n_sampled"]


def test_online_profiler_guards():
    with pytest.raises(ValueError):
        OnlineProfiler(_grid_table(), predicted_us=0.0)
    prof = OnlineProfiler(_grid_table(), predicted_us=100.0,
                          sample_every=1, min_rows=8)
    prof.observe(200.0, rows=2)          # under min_rows: ignored
    prof.observe(-5.0, rows=32)          # nonsense measurement: ignored
    assert prof.n_sampled == 0 and prof.table.scale == 1.0
    # scale-normalized construction: a table already blended to 2x and a
    # prediction made at that scale give the same base
    t2 = _grid_table(scale=2.0)
    p2 = OnlineProfiler(t2, predicted_us=200.0, sample_every=1)
    assert p2.estimate_us == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# LatencyTable hardening
# ---------------------------------------------------------------------------

def test_latency_table_empty_and_bad_queries():
    empty = LatencyTable(rows=[], meta={})
    with pytest.raises(EmptyLatencyTable):
        empty.estimate_level_us(4, fanin=2)
    t = _grid_table()
    with pytest.raises(LatencyTableError):
        t.estimate_level_us(float("nan"), fanin=2)
    with pytest.raises(LatencyTableError):
        t.estimate_level_us(4, fanin=float("inf"))
    # EmptyLatencyTable is a LatencyTableError is a ValueError, so
    # existing except-ValueError callers keep working
    assert issubclass(EmptyLatencyTable, LatencyTableError)
    assert issubclass(LatencyTableError, ValueError)


def test_latency_table_out_of_grid_clamps():
    t = _grid_table()
    assert t.estimate_level_us(1, fanin=2) == 4.0    # below grid: clamp
    assert t.estimate_level_us(0, fanin=2) == 4.0
    assert t.estimate_level_us(-3, fanin=2) == 4.0   # negative: clamp to 0
    # above grid: proportional per-LUT scaling, never a 2-point slope
    assert t.estimate_level_us(64, fanin=2) == 64.0


def test_latency_table_scale_blend_and_roundtrip(tmp_path):
    t = _grid_table()
    assert t.blend_scale(2.0, alpha=1.0) == 2.0
    assert t.estimate_level_us(4, fanin=2) == 8.0    # estimates rescale
    t.blend_scale(float("nan"))                      # ignored
    t.blend_scale(-1.0)
    assert t.scale == 2.0
    t.blend_scale(1e9, alpha=1.0)                    # clamped, not poisoned
    assert t.scale == LatencyTable.SCALE_MAX
    path = str(tmp_path / "t.json")
    t.save(path)
    assert LatencyTable.load(path).scale == t.scale


# ---------------------------------------------------------------------------
# Prometheus export + pull endpoint
# ---------------------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("sched.completed").inc(3)
    reg.gauge("queue depth").set(7.0)
    h = reg.histogram("lat")
    for v in (10.0, 20.0, 30.0):
        h.record(v)
    reg.register("replicas", lambda: {"policy": "rr", "n": 2,
                                      "healthy": True})
    return reg


def test_prometheus_text_exposition():
    text = to_prometheus_text(_populated_registry().snapshot())
    assert "# TYPE repro_sched_completed_total counter" in text
    assert "repro_sched_completed_total 3" in text
    assert "repro_queue_depth 7" in text              # sanitized name
    assert "repro_lat_count 3" in text
    assert "repro_lat_mean_us 20" in text
    assert 'repro_lat_bucket{le="' in text
    assert "repro_replicas_n 2" in text               # provider flattened
    assert "repro_replicas_healthy 1" in text         # bool -> 0/1
    assert "rr" not in text                           # strings dropped
    assert to_prometheus_text({}) == ""


def test_metrics_server_pull_endpoint():
    srv = MetricsServer(_populated_registry(), port=0)
    try:
        with urllib.request.urlopen(srv.url, timeout=5) as r:
            assert r.status == 200
            assert "version=0.0.4" in r.headers["Content-Type"]
            body = r.read().decode()
        assert "repro_sched_completed_total 3" in body
        with urllib.request.urlopen(srv.url + ".json", timeout=5) as r:
            doc = json.loads(r.read())
        assert doc["counters"]["sched.completed"] == 3
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                srv.url.rsplit("/", 1)[0] + "/nope", timeout=5)
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Perf-trajectory ledger
# ---------------------------------------------------------------------------

def _bench_doc(sha, p95, overhead):
    return {"section": "serve",
            "meta": {"git_sha": sha,
                     "timestamp_utc": f"2026-08-08T00:00:0{sha[-1]}Z"},
            "results": {"baseline_sequential": {"p95_us": p95,
                                                "qps": 1000.0},
                        "tracer_overhead": {"overhead_pct": overhead}}}


def test_history_ledger_idempotent_append_and_report(tmp_path):
    from benchmarks import history
    path = str(tmp_path / "ledger.jsonl")
    assert history.append_entry(_bench_doc("a0", 100.0, 1.0),
                                path=path) is not None
    # same provenance again: skipped, the ledger stays single-entry
    assert history.append_entry(_bench_doc("a0", 100.0, 1.0),
                                path=path) is None
    assert history.append_entry(_bench_doc("b1", 150.0, 1.2),
                                path=path) is not None
    entries = history.load_history(path)
    assert len(entries) == 2
    series = history.trajectory(entries, section="serve")
    p95 = series["serve/sequential/p95_us"]
    assert p95["n"] == 2 and p95["first"] == 100.0 and p95["last"] == 150.0
    assert p95["change_pct"] == pytest.approx(50.0)   # lower-better: worse
    qps = series["serve/sequential/qps"]
    assert qps["change_pct"] == 0.0                   # flat
    text = history.format_report(series)
    assert "serve/sequential/p95_us" in text and "drifting" in text
    # corrupt trailing line (killed CI job) must not poison the ledger
    with open(path, "a") as f:
        f.write("{truncated")
    assert len(history.load_history(path)) == 2
    assert history.trajectory([], section="serve") == {}
    assert "empty" in history.format_report({})


def test_history_cli(tmp_path, capsys):
    from benchmarks import history
    bench = tmp_path / "BENCH_serve.json"
    bench.write_text(json.dumps(_bench_doc("c2", 120.0, 0.5)))
    ledger = str(tmp_path / "ledger.jsonl")
    assert history.main(["--ledger", ledger, "append", str(bench)]) == 0
    capsys.readouterr()
    assert history.main(["--ledger", ledger, "report", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["serve/tracer/overhead_pct"]["n"] == 1
    assert history.main(["--ledger", ledger, "report"]) == 0
    assert "serve/tracer/overhead_pct" in capsys.readouterr().out
    assert history.main(["--ledger", ledger, "append",
                         str(tmp_path / "missing.json")]) == 0


# ---------------------------------------------------------------------------
# Regression gate: tracer overhead is diffed direction-aware + floored
# ---------------------------------------------------------------------------

def test_check_regression_tracer_overhead_floored():
    from benchmarks.check_regression import compare, extract_metrics
    base = extract_metrics(_bench_doc("a0", 100.0, 0.3))
    noisy = extract_metrics(_bench_doc("b1", 100.0, 1.2))
    bad = extract_metrics(_bench_doc("c2", 100.0, 40.0))
    assert base["serve/tracer/overhead_pct"] == (0.3, "lower")
    # sub-floor wobble (0.3% -> 1.2%) compares as equal…
    regs, checked, _, _ = compare(base, noisy, tolerance=0.25,
                                  min_us=50.0)
    assert not regs and any(n == "serve/tracer/overhead_pct"
                            for n, *_ in checked)
    # …while a real overhead explosion (0.3% -> 40%) still fails
    regs, _, _, _ = compare(base, bad, tolerance=0.25, min_us=50.0)
    assert any(n == "serve/tracer/overhead_pct" for n, *_ in regs)
