"""repro.check: every pass must flag seeded corruption and stay silent
on clean artifacts.

Mutation style: build a real pipeline artifact (AIG / mapped netlist /
DevicePlan), corrupt it the way a buggy transform would (flip an INIT
bit, swap leaf wires, drop a LUT, point a leaf at the dump row), and
assert the checker reports it — with a *valid* counterexample where the
corruption is functional. Functional mutations are guarded by an
independent exhaustive simulation: a flipped INIT bit on an unreachable
leaf pattern does NOT change the function, and the checker must then
stay silent rather than cry wolf.
"""
import copy
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.check import (CheckFailure, CheckReport, check_concurrency,
                         check_duplicate_definitions, equiv_aig_mapped,
                         equiv_aigs, equiv_mapped_plan,
                         equiv_network_mapped, execute_plan_host, lint_aig,
                         lint_mapped, plan_fingerprint, require_ok,
                         validate_device_plan)
from repro.check.concurrency import check_reject_coverage
from repro.synth import (AIG, CONST0, CONST1, compile_device_plan, lit,
                         lit_var, map_aig, optimize, synthesize)
from repro.synth.executor import execute_packed
from repro.synth.lutmap import MappedLUT
from repro.synth.simulate import input_patterns, pack_bits, simulate

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def random_aig(seed, n_pis=6, n_ands=30):
    rng = np.random.default_rng(seed)
    a = AIG(n_pis)
    lits = [lit(p + 1) for p in range(n_pis)]
    for _ in range(n_ands):
        i, j = rng.choice(len(lits), 2, replace=False)
        lits.append(a.and2(lits[i] ^ int(rng.integers(2)),
                           lits[j] ^ int(rng.integers(2))))
    outs = [l for l in lits[n_pis:] if lit_var(l) != 0][-3:]
    a.outputs = outs or [lits[-1]]
    return a


def mapped_fn(mapped, n_pis):
    """Ground-truth output words of a mapped net on all 2^n inputs."""
    return execute_packed(mapped, input_patterns(n_pis))


def eval_on_bits(fn_words, bits):
    """Evaluate a packed evaluator on one explicit PI bit pattern."""
    words = pack_bits(np.asarray(bits, np.uint8)[:, None])
    return (np.asarray(fn_words(words))[:, 0] & 1).astype(int)


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------

def test_report_merge_errors_and_require_ok():
    r = CheckReport("a")
    r.warn("lint", "w", "just a warning")
    assert r.ok and len(r.warnings) == 1
    r2 = CheckReport("b")
    r2.error("equiv", "stage", "boom", where="lut 3")
    r.merge(r2)
    assert not r.ok and r.errors[0].code == "stage"
    assert "FAIL" in r.format()
    with pytest.raises(CheckFailure) as ei:
        require_ok(r)
    assert "boom" in str(ei.value)


# ---------------------------------------------------------------------------
# pass 1: netlist lint
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_lint_clean_on_unmutated(seed):
    a = random_aig(seed)
    assert lint_aig(a).ok
    opt = optimize(a, rounds=1)
    assert lint_aig(opt).ok
    m = map_aig(opt, k=4)
    rep = lint_mapped(m)
    assert rep.ok, rep.format()


def _codes(rep):
    return {i.code for i in rep.errors}


def test_lint_aig_flags_structural_corruption():
    a = random_aig(1)
    n = a.n_nodes

    bad = copy.deepcopy(a)
    bad._level[n - 1] += 1                       # broken levelization
    assert "level" in _codes(lint_aig(bad))

    bad = copy.deepcopy(a)
    bad._f0[n - 1] = lit(n - 1)                  # self/forward reference
    assert "cycle" in _codes(lint_aig(bad))

    bad = copy.deepcopy(a)
    f0, f1 = bad._f0[n - 1], bad._f1[n - 1]
    bad._f0[n - 1], bad._f1[n - 1] = f1, f0      # de-canonicalised operands
    assert "operand-order" in _codes(lint_aig(bad))

    bad = copy.deepcopy(a)
    bad._f0.append(bad._f0[n - 1])               # strash violation
    bad._f1.append(bad._f1[n - 1])
    bad._level.append(bad._level[n - 1])
    assert "duplicate-and" in _codes(lint_aig(bad))

    bad = copy.deepcopy(a)
    bad._f0[n - 1] = CONST1                      # un-propagated constant
    assert "const-fanin" in _codes(lint_aig(bad))

    bad = copy.deepcopy(a)
    bad.outputs[0] = lit(n + 7)                  # dangling output wire
    assert "bad-output" in _codes(lint_aig(bad))


def test_lint_mapped_flags_corruption():
    m = map_aig(optimize(random_aig(2), rounds=1), k=4)
    assert len(m.luts) >= 2, "need a multi-LUT net for these mutations"

    bad = dataclasses.replace(m, luts=list(m.luts))
    l = bad.luts[-1]
    bad.luts[-1] = MappedLUT(l.root, l.leaves, 1 << (1 << len(l.leaves)))
    assert "init-width" in _codes(lint_mapped(bad))   # INIT wider than 2^m

    bad = dataclasses.replace(m, luts=list(m.luts))
    l0, l1 = bad.luts[0], bad.luts[-1]
    bad.luts[0] = MappedLUT(l0.root, (l1.root,) + l0.leaves[1:],
                            l0.tt)                    # reads a later wire
    assert "undefined-leaf" in _codes(lint_mapped(bad))

    bad = dataclasses.replace(m, luts=list(m.luts))
    l = bad.luts[-1]
    bad.luts[-1] = MappedLUT(bad.luts[0].root, l.leaves, l.tt)
    assert "duplicate-root" in _codes(lint_mapped(bad))

    bad = dataclasses.replace(m, luts=list(m.luts))
    l = bad.luts[0]
    wide = tuple(range(1, m.k + 2))
    bad.luts[0] = MappedLUT(l.root, wide, 0)          # fanin > k
    assert "fanin-width" in _codes(lint_mapped(bad))

    # dropped LUT (a "level edge" removed): its root becomes undefined
    used_roots = {x for l in m.luts for x in l.leaves if x > m.n_pis}
    victim = next(i for i, l in enumerate(m.luts) if l.root in used_roots)
    bad = dataclasses.replace(
        m, luts=[l for i, l in enumerate(m.luts) if i != victim])
    rep = lint_mapped(bad)
    assert {"undefined-leaf", "undefined-output"} & _codes(rep)


# ---------------------------------------------------------------------------
# pass 2: equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_equiv_clean_pipeline(seed):
    a = random_aig(seed)
    opt = optimize(a, rounds=2)
    assert equiv_aigs(a, opt).ok
    m = map_aig(opt, k=4)
    assert equiv_aig_mapped(opt, m).ok
    dp = compile_device_plan(m)
    assert equiv_mapped_plan(m, dp).ok


def test_equiv_reports_valid_exhaustive_counterexample():
    a = random_aig(3)
    dut = copy.deepcopy(a)
    dut.outputs = [dut.outputs[0] ^ 1] + dut.outputs[1:]
    rep = equiv_aigs(a, dut)
    assert not rep.ok
    cex = rep.errors[0].counterexample
    assert cex is not None and cex.exhaustive
    assert len(cex.inputs) == a.n_pis
    # the witness must actually separate the two networks
    got = eval_on_bits(lambda w: simulate(dut, w), cex.inputs)
    want = eval_on_bits(lambda w: simulate(a, w), cex.inputs)
    assert got[cex.output] == cex.got and want[cex.output] == cex.want
    assert cex.got != cex.want


def test_equiv_wide_cone_uses_sampled_vectors():
    a = random_aig(4, n_pis=24, n_ands=60)      # > EXHAUSTIVE_LIMIT
    dut = copy.deepcopy(a)
    dut.outputs = [dut.outputs[0] ^ 1] + dut.outputs[1:]
    rep = equiv_aigs(a, dut)
    assert not rep.ok
    assert rep.errors[0].counterexample is not None
    assert not rep.errors[0].counterexample.exhaustive
    assert equiv_aigs(a, copy.deepcopy(a)).ok   # clean stays clean


def test_equiv_interface_mismatch():
    a, b = random_aig(0, n_pis=4), random_aig(0, n_pis=5)
    assert "aig-rewrite" in _codes(equiv_aigs(a, b))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), which=st.integers(0, 3),
       row=st.integers(0, 63))
def test_mutation_flip_init_bit_killrate(seed, which, row):
    """Flip one INIT bit of one LUT: the miter must flag the corruption
    exactly when the function actually changed (unreachable leaf
    patterns make some flips no-ops — the checker must not cry wolf)."""
    a = optimize(random_aig(seed, n_pis=5, n_ands=25), rounds=1)
    m = map_aig(a, k=4)
    if not m.luts:
        return
    i = which % len(m.luts)
    l = m.luts[i]
    r = row % (1 << len(l.leaves))
    bad = dataclasses.replace(m, luts=list(m.luts))
    bad.luts[i] = MappedLUT(l.root, l.leaves, l.tt ^ (1 << r))
    changed = not np.array_equal(mapped_fn(m, a.n_pis),
                                 mapped_fn(bad, a.n_pis))
    rep = equiv_aig_mapped(a, bad)
    assert rep.ok == (not changed), rep.format()
    if changed:
        cex = rep.errors[0].counterexample
        got = eval_on_bits(lambda w: execute_packed(bad, w), cex.inputs)
        want = eval_on_bits(lambda w: simulate(a, w), cex.inputs)
        assert got[cex.output] != want[cex.output]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), which=st.integers(0, 3))
def test_mutation_swap_leaves_killrate(seed, which):
    """Swap two leaf wires of one LUT (same guard: symmetric truth
    tables make some swaps function-preserving)."""
    a = optimize(random_aig(seed, n_pis=5, n_ands=25), rounds=1)
    m = map_aig(a, k=4)
    multi = [i for i, l in enumerate(m.luts) if len(l.leaves) >= 2]
    if not multi:
        return
    i = multi[which % len(multi)]
    l = m.luts[i]
    leaves = list(l.leaves)
    leaves[0], leaves[1] = leaves[1], leaves[0]
    bad = dataclasses.replace(m, luts=list(m.luts))
    bad.luts[i] = MappedLUT(l.root, tuple(leaves), l.tt)
    changed = not np.array_equal(mapped_fn(m, a.n_pis),
                                 mapped_fn(bad, a.n_pis))
    rep = equiv_aig_mapped(a, bad)
    assert rep.ok == (not changed), rep.format()


@pytest.mark.parametrize("seed", range(6))
def test_mutation_killrate_deterministic(seed):
    """Hypothesis-free version of the kill-rate property (the @given
    variants above skip when the optional dep is absent): every LUT of
    every net gets one INIT-bit flip and one leaf swap, checked with
    the same changed-function guard."""
    a = optimize(random_aig(seed, n_pis=5, n_ands=25), rounds=1)
    m = map_aig(a, k=4)
    ref = mapped_fn(m, a.n_pis)
    for i, l in enumerate(m.luts):
        muts = [MappedLUT(l.root, l.leaves, l.tt ^ 1)]
        if len(l.leaves) >= 2:
            lv = list(l.leaves)
            lv[0], lv[1] = lv[1], lv[0]
            muts.append(MappedLUT(l.root, tuple(lv), l.tt))
        for mut in muts:
            bad = dataclasses.replace(m, luts=list(m.luts))
            bad.luts[i] = mut
            changed = not np.array_equal(ref, mapped_fn(bad, a.n_pis))
            assert equiv_aig_mapped(a, bad).ok == (not changed)


def test_constant_output_network():
    """Constant nets (zero LUTs, outputs on the const wire) must pass
    every pass clean — and the const-vs-const miter path must work."""
    a = AIG(3)
    a.outputs = [CONST0, CONST1, lit(1)]        # const0, const1, pi0
    m = map_aig(a, k=4)
    assert m.n_luts == 0
    assert lint_mapped(m).ok
    assert equiv_aig_mapped(a, m).ok
    dp = compile_device_plan(m)
    assert validate_device_plan(dp, use_cache=False).ok
    assert equiv_mapped_plan(m, dp).ok

    z = AIG(0)                                   # zero-PI network
    z.outputs = [CONST1]
    mz = map_aig(z, k=4)
    assert equiv_aig_mapped(z, mz).ok


# ---------------------------------------------------------------------------
# pass 3: device-plan validation
# ---------------------------------------------------------------------------

def _plan(seed=5, k=4):
    a = optimize(random_aig(seed, n_pis=6, n_ands=40), rounds=1)
    m = map_aig(a, k=k)
    return m, compile_device_plan(m)


def _fresh(dp):
    return validate_device_plan(dp, use_cache=False)


def test_plan_clean_and_cached():
    m, dp = _plan()
    rep = validate_device_plan(dp)
    assert rep.ok and rep.info["vmem_bytes"] > 0
    assert validate_device_plan(dp) is rep          # cache hit by hash
    assert validate_device_plan(dp, use_cache=False) is not rep
    dp2 = compile_device_plan(m)
    assert plan_fingerprint(dp) == plan_fingerprint(dp2)
    dp2.tt_bits[0, 0, 0] ^= 0xFFFFFFFF
    assert plan_fingerprint(dp) != plan_fingerprint(dp2)


def test_plan_corruptions_caught():
    _, dp = _plan()

    bad = copy.deepcopy(dp)
    bad.leaf_idx[0, 0, 0] = bad.n_wires             # reads the dump row
    assert "leaf-range" in _codes(_fresh(bad))

    bad = copy.deepcopy(dp)
    bad.tt_bits[0, 0, 0] = 5                        # not a bitplane mask
    assert "tt-encoding" in _codes(_fresh(bad))

    bad = copy.deepcopy(dp)
    real = np.argwhere(bad.out_wires != bad.n_wires)
    (l0, s0), (l1, s1) = real[0], real[-1]
    bad.out_wires[l1, s1] = bad.out_wires[l0, s0]   # wire written twice
    assert "wire-cover" in _codes(_fresh(bad))

    bad = copy.deepcopy(dp)
    bad.out_idx[0] = bad.n_wires + 3
    assert "out-idx" in _codes(_fresh(bad))

    bad = dataclasses.replace(dp, leaf_idx=dp.leaf_idx.astype(np.int64))
    assert "dtype" in _codes(_fresh(bad))

    rep = validate_device_plan(dp, vmem_budget_bytes=1, use_cache=False)
    assert "vmem-budget" in _codes(rep)


def test_plan_pad_slot_and_level_order():
    _, dp = _plan()
    pads = np.argwhere(dp.out_wires == dp.n_wires)
    if pads.size:                                   # ragged level widths
        l, s = pads[0]
        bad = copy.deepcopy(dp)
        bad.tt_bits[l, s, 0] = 0xFFFFFFFF           # pad slot would write
        assert "pad-slot" in _codes(_fresh(bad))
        bad = copy.deepcopy(dp)
        bad.leaf_idx[l, s, 0] = 2                   # pad slot reads a wire
        assert "pad-slot" in _codes(_fresh(bad))
    # same-level read: point a slot's leaf at a wire its own level writes
    for l in range(dp.n_levels):
        real = np.nonzero(dp.out_wires[l] != dp.n_wires)[0]
        if len(real) >= 2:
            bad = copy.deepcopy(dp)
            bad.leaf_idx[l, real[0], 0] = bad.out_wires[l, real[1]]
            assert "level-order" in _codes(_fresh(bad))
            break


def test_execute_plan_host_is_independent_reference():
    for seed in range(3):
        a = optimize(random_aig(seed, n_pis=6, n_ands=40), rounds=1)
        m = map_aig(a, k=4)
        dp = compile_device_plan(m)
        words = input_patterns(a.n_pis)
        np.testing.assert_array_equal(execute_plan_host(dp, words),
                                      execute_packed(m, words))


# ---------------------------------------------------------------------------
# pass 4: concurrency lint
# ---------------------------------------------------------------------------

_VIOLATING = textwrap.dedent('''
    import threading

    class S:
        _GUARDED_BY = {"_stopping": "_cond"}
        _LOCKED_METHODS = ("_flush_locked",)

        def __init__(self):
            self._cond = threading.Condition()
            self._stopping = False      # __init__ is exempt

        def start(self):
            self._stopping = False      # BUG: write outside the lock

        def loop(self):
            with self._cond:
                ok = self._stopping     # fine
            return self.poll(self._stopping)    # BUG: read outside

        def callback_leak(self):
            with self._cond:
                return lambda: self._stopping   # BUG: runs lock-free later

        def bad_call(self):
            self._flush_locked()        # BUG: requires the lock held

        def _flush_locked(self):
            return self._stopping       # exempt via _LOCKED_METHODS
''')

_CLEAN = textwrap.dedent('''
    import threading

    class S:
        _GUARDED_BY = {"_stopping": "_cond"}
        _LOCKED_METHODS = ("_flush_locked",)

        def __init__(self):
            self._cond = threading.Condition()
            self._stopping = False

        def start(self):
            with self._cond:
                self._stopping = False
                if self._stopping:
                    self._flush_locked()

        def _flush_locked(self):
            return self._stopping
''')


def test_concurrency_lint_flags_violations(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(_VIOLATING)
    rep = check_concurrency(files=[p])
    codes = [(i.code, i.where) for i in rep.errors]
    assert sum(c == "unlocked-access" for c, _ in codes) == 3
    assert sum(c == "unlocked-call" for c, _ in codes) == 1
    lines = {int(w.split(":")[1]) for _, w in codes}
    assert len(lines) == 4              # four distinct source lines


def test_concurrency_lint_silent_on_clean(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(_CLEAN)
    rep = check_concurrency(files=[p])
    assert rep.ok, rep.format()
    assert rep.checked > 0              # it actually looked


def test_reject_reason_coverage(tmp_path):
    serve = tmp_path / "serve"
    tests = tmp_path / "tests"
    serve.mkdir(), tests.mkdir()
    (serve / "sched.py").write_text(textwrap.dedent('''
        class RejectReason:
            QUEUE_FULL = "queue_full"
            GHOST = "ghost"
        def submit():
            raise RuntimeError(RejectReason.QUEUE_FULL)
    '''))
    (tests / "test_s.py").write_text(
        "def test_full():\n    assert 'queue_full'\n")
    rep = CheckReport("rr")
    check_reject_coverage(serve, tests, rep)
    codes = {(i.code, i.where) for i in rep.errors}
    assert ("unraisable-reason", "GHOST") in codes    # no code path
    assert ("untested-reason", "GHOST") in codes      # no test
    assert not any(w == "QUEUE_FULL" for _, w in codes)


def test_real_serve_stack_is_clean():
    rep = check_concurrency()
    assert rep.ok, rep.format()
    assert "MicroBatchScheduler" in rep.info["guarded_classes"]
    assert rep.checked > 10


def test_obs_classes_are_linted():
    """The lint covers repro.obs: the shared-mutable window/burn-rate/
    profiler classes must carry (and satisfy) lock annotations."""
    rep = check_concurrency()
    assert rep.ok, rep.format()
    for cls in ("WindowedMetrics", "BurnRateMonitor", "OnlineProfiler",
                "BucketRing"):
        assert cls in rep.info["guarded_classes"], cls


def test_lock_free_annotation_exempts_field(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(textwrap.dedent('''
        import threading

        class S:
            _GUARDED_BY = {"_q": "_lock"}
            _LOCK_FREE = ("_hwm",)

            def __init__(self):
                self._lock = threading.Lock()
                self._q = []
                self._hwm = 0.0

            def push(self, t):
                self._hwm = max(self._hwm, t)   # declared benign race
                with self._lock:
                    self._q.append(t)
    '''))
    rep = check_concurrency(files=[p])
    assert rep.ok, rep.format()


def test_conflicting_annotation_rejected(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(textwrap.dedent('''
        import threading

        class S:
            _GUARDED_BY = {"_q": "_lock"}
            _LOCK_FREE = ("_q",)            # BUG: both annotations

            def __init__(self):
                self._lock = threading.Lock()
                self._q = []
    '''))
    rep = check_concurrency(files=[p])
    assert any(i.code == "conflicting-annotation" for i in rep.errors)


# ---------------------------------------------------------------------------
# srclint + satellites
# ---------------------------------------------------------------------------

def test_srclint_flags_duplicate_definition(tmp_path):
    src = tmp_path / "pkg"
    src.mkdir()
    (src / "a.py").write_text("LUT_K = 6\n")
    (src / "b.py").write_text("LUT_K = 4\n")
    rep = check_duplicate_definitions(src_dir=src)
    assert "duplicate-definition" in _codes(rep)
    assert not check_duplicate_definitions().errors    # real repo clean


def test_lut_cost_single_source():
    """The dedup satellite: both mappers report through core.lutcost."""
    from repro.core import lutcost, lutmap
    from repro.synth import lutmap as synth_lutmap
    assert lutmap.MapReport is lutcost.MapReport
    assert synth_lutmap.LUT_K is lutcost.LUT_K
    assert lutmap.logicnets_lut_cost is lutcost.logicnets_lut_cost
    m = map_aig(random_aig(0), k=4)
    r = m.report(ffs=7)
    assert (r.luts, r.depth, r.ffs) == (m.n_luts, m.depth, 7)
    assert r.fmax_mhz > 0


def _run_regression(args, cwd=None):
    return subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "benchmarks",
                                      "check_regression.py")] + args,
        capture_output=True, text=True, cwd=cwd or REPO_ROOT)


def test_check_regression_unparsable_baseline_is_actionable(tmp_path):
    (tmp_path / "BENCH_kernels.json").write_text("{nope")
    p = _run_regression(["--files", "BENCH_kernels.json",
                         "--baseline-dir", str(tmp_path)])
    assert p.returncode == 2
    assert "not valid JSON" in p.stdout
    assert "Traceback" not in p.stdout + p.stderr


def test_check_regression_unparsable_fresh_is_actionable(tmp_path):
    (tmp_path / "BENCH_kernels.json").write_text("{nope")
    p = _run_regression(["--files", "BENCH_kernels.json",
                         "--fresh-dir", str(tmp_path)])
    assert p.returncode == 2
    assert "not valid JSON" in p.stdout
    assert "Traceback" not in p.stdout + p.stderr


def test_check_regression_missing_baseline_skips(tmp_path):
    doc = {"section": "kernels", "results": {"x_us": 1.0}}
    (tmp_path / "BENCH_new_thing.json").write_text(json.dumps(doc))
    p = _run_regression(["--files", "BENCH_new_thing.json",
                         "--fresh-dir", str(tmp_path)])
    assert p.returncode == 0
    assert "no baseline" in p.stdout


# ---------------------------------------------------------------------------
# verify= hooks
# ---------------------------------------------------------------------------

def test_verify_flag_passes_clean_and_raises_on_corruption():
    a = random_aig(6)
    m = synthesize(a, effort=1, verify=True)           # should not raise
    dp = compile_device_plan(m, verify=True)
    from repro.check.pipeline import verify_plan
    bad = copy.deepcopy(dp)
    bad.tt_bits[bad.tt_bits != 0] ^= 0xFFFFFFFF        # break every LUT
    with pytest.raises(CheckFailure):
        verify_plan(m, bad)


# ---------------------------------------------------------------------------
# LogicNetwork-level checks (SOP stage + valid-code oracle)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_net():
    jnp = pytest.importorskip("jax.numpy")
    from repro.core import fcp
    from repro.core.logic_infer import LogicNetwork
    from repro.core.quant import ActQuantSpec
    from repro.core.truthtable import extract_layer_tables

    rng = np.random.default_rng(7)
    spec = ActQuantSpec("sign", 1)
    alpha = 2.0
    n_in, n_out, fanin = 6, 4, 3
    w = jnp.asarray(rng.normal(size=(n_out, n_in)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n_out,)) * 0.1, jnp.float32)
    mask = fcp.topk_row_mask(w, fanin)
    lt = extract_layer_tables(w, b, mask, spec, spec, alpha, alpha, fanin)
    return LogicNetwork([lt], spec, alpha, n_in, n_out)


def test_full_pipeline_check_on_logic_network(tiny_net):
    from repro.check import check_synth_pipeline
    rep = check_synth_pipeline(net=tiny_net, fast=True)
    assert rep.ok, rep.format()
    assert rep.checked > 100


def test_network_oracle_catches_mapped_corruption(tiny_net):
    from repro.synth.from_sop import network_to_aig
    a = network_to_aig(tiny_net)
    m = synthesize(a, effort=1)
    assert equiv_network_mapped(tiny_net, m, n_samples=128).ok
    bad = dataclasses.replace(m, outputs=[m.outputs[0] ^ 1]
                              + m.outputs[1:])
    rep = equiv_network_mapped(tiny_net, bad, n_samples=128)
    assert not rep.ok
    cex = rep.errors[0].counterexample
    assert cex is not None
    # the counterexample is an input *code* row; replaying it through
    # the oracle and the netlist must reproduce the disagreement
    codes = np.asarray(cex.inputs)[None, :]
    want = np.asarray(tiny_net.apply_codes(codes))[0]
    from repro.synth.executor import BitplaneNetwork
    got = BitplaneNetwork(tiny_net, bad).apply_codes(codes)[0]
    assert got[cex.output] != want[cex.output]


def test_preflight_on_bitplane_network(tiny_net):
    from repro.check import preflight
    from repro.synth import compile_logic_network
    bn = compile_logic_network(tiny_net, verify=True)  # full verify path
    rep = preflight(bn, n_samples=64)
    assert rep.ok, rep.format()
