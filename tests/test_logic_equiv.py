"""The paper's core claim, testable: MAC+BN+activation of a QAT+FCP layer
collapses into truth tables with BIT-EXACT equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.core import fcp
from repro.core.logic_infer import LogicNetwork, classify, hardware_report
from repro.core.quant import ActQuantSpec, apply_act_quant, encode_levels
from repro.core.truthtable import extract_layer_tables


def _random_layer(rng, n_in, n_out, fanin, in_spec, out_spec, alpha,
                  with_bn=False):
    w = jnp.asarray(rng.normal(size=(n_out, n_in)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n_out,)) * 0.1, jnp.float32)
    mask = fcp.topk_row_mask(w, fanin)
    kw = {}
    if with_bn:
        kw = dict(gamma=jnp.asarray(rng.uniform(0.5, 1.5, n_out), jnp.float32),
                  beta=jnp.asarray(rng.normal(size=n_out) * 0.1, jnp.float32),
                  bn_mean=jnp.asarray(rng.normal(size=n_out) * 0.1, jnp.float32),
                  bn_var=jnp.asarray(rng.uniform(0.5, 2, n_out), jnp.float32))
    lt = extract_layer_tables(w, b, mask, in_spec, out_spec, alpha, alpha,
                              fanin, **kw)
    return w, b, mask, kw, lt


@settings(max_examples=15, deadline=None)
@given(fanin=st.integers(1, 5), in_bits=st.integers(1, 2),
       out_bits=st.integers(1, 3), seed=st.integers(0, 500))
def test_single_layer_bit_exact(fanin, in_bits, out_bits, seed):
    rng = np.random.default_rng(seed)
    n_in, n_out, alpha = 10, 6, 2.0
    in_spec = ActQuantSpec("signed" if in_bits > 1 else "sign", in_bits)
    out_spec = ActQuantSpec("signed" if out_bits > 1 else "sign", out_bits)
    w, b, mask, kw, lt = _random_layer(
        rng, n_in, n_out, fanin, in_spec, out_spec, alpha, with_bn=False)

    net = LogicNetwork([lt], in_spec, alpha, n_in, n_out)
    x = jnp.asarray(rng.normal(0, 2, (64, n_in)), jnp.float32)
    got = net(x)

    # oracle: quantized arithmetic forward
    xq = apply_act_quant(in_spec, x, jnp.asarray(alpha))
    pre = xq @ jnp.where(mask, w, 0.0).T + b
    ref = apply_act_quant(out_spec, pre, jnp.asarray(alpha))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5)


def test_two_layer_with_bn_bit_exact(rng):
    alpha = 2.0
    s1 = ActQuantSpec("sign", 1)
    s2 = ActQuantSpec("signed", 2)
    w1, b1, m1, kw1, lt1 = _random_layer(rng, 12, 8, 4, s1, s1, alpha,
                                         with_bn=True)
    w2, b2, m2, kw2, lt2 = _random_layer(rng, 8, 5, 3, s1, s2, alpha,
                                         with_bn=True)
    net = LogicNetwork([lt1, lt2], s1, alpha, 12, 5)
    x = jnp.asarray(rng.normal(0, 2, (32, 12)), jnp.float32)
    got = net(x)

    def bn(y, kw):
        return ((y - kw["bn_mean"]) / jnp.sqrt(kw["bn_var"] + 1e-5)
                * kw["gamma"] + kw["beta"])

    xq = apply_act_quant(s1, x, jnp.asarray(alpha))
    h = apply_act_quant(s1, bn(xq @ jnp.where(m1, w1, 0).T + b1, kw1),
                        jnp.asarray(alpha))
    ref = apply_act_quant(s2, bn(h @ jnp.where(m2, w2, 0).T + b2, kw2),
                          jnp.asarray(alpha))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_pallas_path_matches_oracle(rng):
    alpha = 2.0
    spec = ActQuantSpec("sign", 1)
    _, _, _, _, lt = _random_layer(rng, 16, 12, 4, spec, spec, alpha)
    net = LogicNetwork([lt], spec, alpha, 16, 12)
    x = jnp.asarray(rng.normal(0, 2, (40, 16)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(net(x, use_pallas=True)),
        np.asarray(net(x, use_pallas=False)))


def test_hardware_report_minimized_not_worse(rng):
    alpha = 2.0
    spec2 = ActQuantSpec("signed", 2)
    _, _, _, _, lt = _random_layer(rng, 16, 8, 4, spec2, spec2, alpha)
    net = LogicNetwork([lt], spec2, alpha, 16, 8)
    mini, _ = hardware_report(net, minimize_logic=True)
    base, _ = hardware_report(net, minimize_logic=False)
    # fanin 4 x 2 bits = 8 input bits > 6 -> baseline LUT cascade costs
    # strictly more than the espresso'd network (the paper's Table I gap)
    assert mini.luts <= base.luts
    assert mini.fmax_mhz >= base.fmax_mhz


def test_netlist_emission(rng):
    from repro.core.netlist import emit_network
    alpha = 2.0
    spec = ActQuantSpec("sign", 1)
    _, _, _, _, lt = _random_layer(rng, 8, 4, 3, spec, spec, alpha)
    net = LogicNetwork([lt], spec, alpha, 8, 4)
    v = emit_network(net, "tiny")
    assert "module layer0" in v and "module tiny" in v
    assert v.count("assign") == 4  # one boolean fn per 1-bit neuron
    assert "posedge clk" in v      # retiming registers present
